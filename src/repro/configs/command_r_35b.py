"""command-r-35b — dense GQA decoder, no biases.

[hf:CohereForAI/c4ai-command-r-v01] 40L, d_model=8192, 64 heads (GQA kv=8),
d_ff=22528, vocab=256000.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    arch_type="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256_000,
    head_dim=128,
    qkv_bias=False,
    tie_embeddings=True,
    sliding_window=8192,
)
