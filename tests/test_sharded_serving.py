"""Mesh-sharded paged serving (PR 8): TP-sharded pools + ShardedServer.

These tests need MULTIPLE jax devices, which on the CPU backend exist
only when ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` is set
BEFORE jax initializes (conftest imports jax at collection, so the flag
must come from the environment — the CI ``mesh-smoke`` job sets it at
the job level).  Without forced devices the whole module skips rather
than fake a mesh: running a (1, 2) mesh over one real device would test
nothing.

What is covered:
  * greedy token identity of the TP-sharded PagedEngine (pool placed by
    ``paged_pool_shardings``, dispatches under ``shard_map`` with the
    KV heads split across 'model') against the single-device engine —
    fp and int8 pools, chunked admissions, speculative rounds;
  * the ShardedServer front end: residency routing, replica pinning,
    and token identity through the full replica/threading path;
  * warm cross-replica admission: a prefix admitted on replica 0 is
    served on replica 1 with ZERO prefix recompute — block-granular
    host promotions and the shared-L2 cross-replica counter move, the
    staging path does not.
"""
from __future__ import annotations

import os

import pytest

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    pytest.skip(
        "sharded serving needs forced host devices: set XLA_FLAGS="
        "--xla_force_host_platform_device_count=8 before pytest",
        allow_module_level=True)

import jax

if jax.device_count() < 4:
    pytest.skip("sharded serving tests need >= 4 devices",
                allow_module_level=True)

from repro.configs import get_config
from repro.launch.mesh import serving_meshes
from repro.launch.serve import ShardedServer
from repro.models import init_params
from repro.serving import ContinuousBatchingScheduler, PagedEngine
from repro.sharding import serving_runtime

CACHED = [
    "the quick brown fox jumps over the lazy dog and keeps running",
    "pack my box with five dozen liquor jugs for the long trip home",
]
REQUESTS = [
    CACHED[0],                                            # exact hit
    CACHED[1][:40] + " then something new happens here",  # partial hit
    "completely fresh prompt with no cached prefix",      # miss
    CACHED[1],                                            # exact hit
]

ENGINE_KW = dict(max_new_tokens=6, max_batch=3, capacity=128,
                 block_size=8, enable_partial=True)


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _serve(engine, prompts, **kw):
    sched = ContinuousBatchingScheduler(engine)
    reqs = [sched.submit(p, **kw) for p in prompts]
    sched.run()
    for r in reqs:
        assert r.error is None, r.error
    return [r.result for r in reqs]


def _run_engine(cfg, params, rt=None, **kw):
    eng = PagedEngine(cfg, params, **(dict(ENGINE_KW) | kw),
                      **({} if rt is None else {"rt": rt}))
    _serve(eng, CACHED, admit=True)
    results = _serve(eng, REQUESTS)
    eng.check_invariants()
    return eng, results


@pytest.mark.parametrize("variant,kw", [
    ("fp_chunked", dict(prefill_mode="chunked")),
    ("int8_chunked", dict(prefill_mode="chunked", kv_quant=True)),
    ("fp_staged", dict(prefill_mode="staged")),
    ("fp_speculative", dict(prefill_mode="chunked", speculative=True,
                            gamma=3)),
    ("int8_speculative", dict(prefill_mode="chunked", kv_quant=True,
                              speculative=True, gamma=3)),
])
def test_tp_sharded_token_identity(stack, variant, kw):
    """TP=2 shard_map dispatch path emits the same greedy tokens as the
    single-device engine on the exact/partial/miss mix."""
    cfg, params = stack
    rt = serving_runtime(serving_meshes(1, 2)[0])
    _, ref = _run_engine(cfg, params, **kw)
    eng, got = _run_engine(cfg, params, rt=rt, **kw)
    assert [r.text for r in got] == [r.text for r in ref], variant
    assert [r.mode for r in got] == [r.mode for r in ref], variant
    # heads really are split: each device holds 1/2 the pool's KV bytes
    assert eng.kv_tp_degree() == 2
    assert eng.device_kv_bytes_per_device() \
        == eng.device_kv_bytes_in_use() // 2


def test_pool_placement_audit_without_allocation(stack):
    """``paged_pool_struct`` + ``paged_pool_shardings`` audit placement
    from shapes alone: K/V (and int8 scale) leaves land head-sharded on
    'model', block tables replicate, and no replication fallback fires
    when heads divide the TP degree."""
    cfg, _ = stack
    from repro.launch.specs import paged_pool_struct
    from repro.sharding import (clear_fallback_log, fallback_log,
                                paged_pool_shardings)
    mesh = serving_meshes(1, 2)[0]
    struct = paged_pool_struct(cfg, 16, 8, 2, 4, kv_quant=True)
    clear_fallback_log()
    sh = paged_pool_shardings(struct, cfg, mesh)
    assert fallback_log() == []

    def check(path, leaf, s):
        name = next(k.key for k in reversed(path) if hasattr(k, "key"))
        spec = tuple(s.spec) + (None,) * (len(leaf.shape) - len(s.spec))
        if name in ("k", "v", "k_tail", "v_tail"):
            assert spec[len(leaf.shape) - 2] == "model", (name, spec)
        elif name in ("k_scale", "v_scale"):
            assert spec[len(leaf.shape) - 1] == "model", (name, spec)
        else:                                  # block tables etc.
            assert all(x is None for x in spec), (name, spec)

    jax.tree_util.tree_map_with_path(check, struct, sh)


def test_sharded_server_identity_and_routing(stack):
    """Full ShardedServer path (2 replicas x TP2, shared L2, residency
    routing) reproduces the single-device engine's tokens."""
    cfg, params = stack
    _, ref = _run_engine(cfg, params, prefill_mode="chunked")
    srv = ShardedServer(cfg, params, replicas=2, tp=2,
                        prefill_mode="chunked", **ENGINE_KW)
    srv.run(CACHED, replica=0, admit=True)
    pinned = srv.run(REQUESTS, replica=1)
    routed = srv.run(REQUESTS)                 # residency + load routing
    srv.check_invariants()
    assert [r.text for r in pinned] == [r.text for r in ref]
    assert [r.text for r in routed] == [r.text for r in ref]
    # the read view sees replica-0 residency for an admitted prefix
    ids = srv.engines[0].tok.encode(CACHED[0])
    assert srv.residency(ids)[0] > 0


def test_warm_cross_replica_admission_promotes_not_recomputes(stack):
    """A prefix admitted on replica 0 serves warm on replica 1: exact
    hits at full reuse depth via block-granular host promotions from the
    shared L2 — no staging prefill, and the cross-replica counter
    records that the entries came from the other replica."""
    cfg, params = stack
    srv = ShardedServer(cfg, params, replicas=2, tp=2,
                        prefill_mode="chunked", **ENGINE_KW)
    srv.run(CACHED, replica=0, admit=True)
    r1 = srv.engines[1]
    assert r1.stats["host_promotions"] == 0
    results = srv.run(CACHED, replica=1)
    srv.check_invariants()
    for prompt, res in zip(CACHED, results):
        m = len(srv.engines[0].tok.encode(prompt))
        assert res.cache_hit and res.mode == "exact_prefix"
        assert res.reuse_depth == m - 1        # full prefix reused
    # promotion counters moved; nothing was recomputed or staged
    assert r1.stats["host_promotions"] == len(CACHED)
    assert r1.stats["staging_prefills"] == 0
    assert srv.shared_stats["cross_replica_promotions"] == len(CACHED)
    # replica 0 never promoted (its copies stayed L1-resident)
    assert srv.engines[0].stats["host_promotions"] == 0
