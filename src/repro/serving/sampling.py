"""Token sampling: deterministic greedy (the paper's do_sample=False) plus
temperature / top-k for the examples.

Every function is batch-shaped: logits (B, V) in, tokens (B,) out — greedy
reduces over the vocab axis only, and ``sample_batched`` draws one
independent categorical per row, so the same functions serve the serial
engine (B=1) and the continuous-batching slot pool (B=max_batch) without a
reshape."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_logits(logits, rng, *, temperature: float = 1.0, top_k: int = 0):
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[..., -1:], -jnp.inf, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)


def sample_batched(logits, rng, *, temperature=0.0, top_k=0,
                   top_k_cap: int = 64):
    """Per-row sampling for the slot/paged pools: logits (B, V) -> (B,).

    ``temperature`` may be a scalar or a per-row (B,) vector — rows at
    temperature 0 decode greedily while others sample, so one pool can mix
    deterministic and sampled requests in a single dispatch.  The rng is
    split per row; pass a fresh key each step.

    ``top_k`` likewise accepts a scalar (static, back-compat) or a per-row
    (B,) int vector: row b keeps its ``top_k[b]`` best logits (0 = no
    filter).  Per-row k is dynamic, so one sort of the top ``top_k_cap``
    (static) logits serves every row; callers that know the batch's max k
    should pass it as the cap (the pool engines do) — a row asking for
    k > top_k_cap is clamped to the cap."""
    if isinstance(temperature, (int, float)) and temperature <= 0.0:
        return greedy(logits)                # static shortcut: trace-safe
    if not isinstance(temperature, jax.core.Tracer):
        # concrete all-greedy batch (every row at temperature 0): plain
        # batched argmax — no rng split, no per-row dynamic top-k sort.
        # Tracer-guarded so the check never forces a value inside jit.
        if not bool(jnp.any(jnp.asarray(temperature) > 0.0)):
            return greedy(logits)
    temperature = jnp.asarray(temperature, jnp.float32)
    t = jnp.broadcast_to(temperature, (logits.shape[0],))
    scaled = logits / jnp.maximum(t, 1e-6)[:, None]
    if isinstance(top_k, (int,)) and top_k:
        vals, _ = jax.lax.top_k(scaled, top_k)
        scaled = jnp.where(scaled < vals[..., -1:], -jnp.inf, scaled)
    elif not isinstance(top_k, int):
        k = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32),
                             (logits.shape[0],))
        cap = min(top_k_cap, logits.shape[-1])
        vals, _ = jax.lax.top_k(scaled, cap)          # (B, cap) sorted desc
        idx = jnp.clip(k, 1, cap) - 1
        thr = jnp.take_along_axis(vals, idx[:, None], axis=1)
        scaled = jnp.where((k > 0)[:, None] & (scaled < thr),
                           -jnp.inf, scaled)
    keys = jax.random.split(rng, logits.shape[0])
    drawn = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(t > 0.0, drawn, greedy(logits))
