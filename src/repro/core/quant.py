"""Shared int8 KV quantization for BOTH cache tiers (beyond paper; cf. its
CacheGen citation §6.1 "host caches grow large").

One scheme, two carriers:

  * **Host (L2) tree quantization** — ``quantize_tree`` /
    ``dequantize_tree`` turn a host numpy cache pytree into a compact
    representation: float leaves become ``{"__q8__": int8, "scale": f32
    per last-dim vector, "dtype": str, ...}``.  Symmetric per-vector int8
    halves bf16 KV bytes (4x for f32) at ~0.4% RMS error.
  * **Device (L1) vector quantization** — ``quantize_vectors_jnp`` is the
    same symmetric per-vector scheme in jnp, used by the dense ``kv_quant``
    slot caches and the int8 paged pool (K/V stored int8 with a per-
    (token, head) f32 scale, dequant fused into the attention gather).

Because both tiers share one scheme (same granularity: one scale per
last-dim vector), an int8 block moves host<->device **without a
dequant/requant round-trip** — quantization error is a one-time event per
vector, at its first write.

Fidelity: raw int8 reuse can flip a greedy argmax (the dequantization
error lands exactly where attention weights are largest — the most recent
positions).  The principled fix, applied at both tiers, is a **full-
precision residual tail**: the last ``residual`` valid positions of every
capacity-axis leaf are stored in their original dtype and only the older
prefix is quantized.  ``quantize_tree(..., length=n, residual=r)`` also
*truncates* the invalid region [n, capacity) — reconstructed as zeros,
which downstream masking (``slot_pos``) never reads — so the residual
tail costs less than it saves.  The paged pool's analogue is the per-row
fp ring tail (``models.attention.init_paged_kv_cache(quant=True)``): the
most recent ``fp_tail_blocks`` blocks are attended in full precision and
older blocks through the fused int8 gather.

Invariants (tests/test_quant.py, hypothesis):
  * round-trip relative RMS error of the quantized region < 1%
  * leaves named in ``NO_COMPRESS`` (and all non-float leaves) bit-exact
  * positions in [length - residual, length) bit-exact (the fp tail)
  * ``quantize_tree`` is idempotent (an already-quantized tree is
    returned unchanged — never double-quantized)
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

_QKEY = "__q8__"

# Leaves that must never be quantized: position/validity metadata and the
# scale arrays of a natively-quantized (device-layout) cache — quantizing
# a scale would corrupt the int8 data it describes.
NO_COMPRESS = {"slot_pos", "block_tables", "k_scale", "v_scale"}

# Capacity axis (from the right) per leaf name: the token/slot axis along
# which "recent" is defined.  Shared with the recycler's resize surgery
# (grow_capacity / shrink_capacity).
CAP_AXIS = {"k": -3, "v": -3, "ckv": -2, "krope": -2, "slot_pos": -1,
            "k_scale": -2, "v_scale": -2}

# Default fp residual tail (positions).  One-to-two radix blocks of the
# most recent context: deep enough that the argmax-deciding attention mass
# reads exact values, shallow enough that compression still wins.
DEFAULT_RESIDUAL = 16


# ---------------------------------------------------------------------------
# numpy (host tier)
# ---------------------------------------------------------------------------
def quantize_vectors(a: np.ndarray):
    """a (..., d) float -> (int8 (..., d), f32 scale (..., 1)); symmetric
    per last-dim vector."""
    a32 = a.astype(np.float32)
    amax = np.max(np.abs(a32), axis=-1, keepdims=True) if a.size else \
        np.zeros(a.shape[:-1] + (1,), np.float32)
    scale = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(a32 / scale), -127, 127).astype(np.int8)
    return q, scale


def quantize_tree(tree, *, length: Optional[int] = None,
                  residual: int = 0):
    """Float leaves -> ``{_QKEY: int8, "scale": f32 per last-dim vector}``.

    ``length``: number of valid positions along each leaf's capacity axis;
    the invalid region [length, capacity) is dropped (reconstructed as
    zeros — always masked by ``slot_pos`` downstream).  ``residual``: the
    last ``residual`` valid positions stay full precision (the greedy-
    fidelity fix).  Leaves without a known capacity axis (recurrent state,
    cross-attention K/V) are quantized whole; ``NO_COMPRESS`` and non-
    float leaves pass through bit-exact.  Idempotent: an already-quantized
    tree is returned unchanged."""
    if is_quantized(tree):
        return tree

    def walk(t, name=None):
        if isinstance(t, dict):
            return {k: walk(v, k) for k, v in t.items()}
        a = np.asarray(t)
        if name in NO_COMPRESS or not np.issubdtype(a.dtype, np.floating):
            return a
        ax = CAP_AXIS.get(name)
        if ax is None or a.ndim < abs(ax):
            q, scale = quantize_vectors(a)
            return {_QKEY: q, "scale": scale, "dtype": np.dtype(a.dtype).str}
        axp = ax % a.ndim
        cap = a.shape[axp]
        n = cap if length is None else max(0, min(int(length), cap))
        split = max(0, n - max(0, int(residual)))
        sl_q = [slice(None)] * a.ndim
        sl_q[axp] = slice(0, split)
        sl_t = [slice(None)] * a.ndim
        sl_t[axp] = slice(split, n)
        q, scale = quantize_vectors(a[tuple(sl_q)])
        return {_QKEY: q, "scale": scale, "dtype": np.dtype(a.dtype).str,
                "tail": a[tuple(sl_t)], "cap": np.int64(cap),
                "ax": np.int64(axp)}
    return walk(tree)


def dequantize_tree(tree):
    """Inverse of ``quantize_tree``: reconstruct original-dtype leaves at
    full capacity (truncated invalid regions come back as zeros)."""
    def walk(t):
        if isinstance(t, dict):
            if _QKEY in t:
                dt = t["dtype"]
                dt = dt.item() if hasattr(dt, "item") else dt
                dt = np.dtype(str(dt))
                a = (np.asarray(t[_QKEY]).astype(np.float32)
                     * np.asarray(t["scale"])).astype(dt)
                if "cap" not in t:
                    return a
                axp = int(np.asarray(t["ax"]))
                cap = int(np.asarray(t["cap"]))
                a = np.concatenate([a, np.asarray(t["tail"]).astype(dt)],
                                   axis=axp)
                if a.shape[axp] < cap:
                    pad = [(0, 0)] * a.ndim
                    pad[axp] = (0, cap - a.shape[axp])
                    a = np.pad(a, pad)
                return a
            return {k: walk(v) for k, v in t.items()}
        return t
    return walk(tree)


def is_quantized(tree) -> bool:
    def walk(t):
        if isinstance(t, dict):
            return _QKEY in t or any(walk(v) for v in t.values())
        return False
    return walk(tree)


# ---------------------------------------------------------------------------
# jnp (device tier) — the same scheme for on-device caches
# ---------------------------------------------------------------------------
def quantize_vectors_jnp(x):
    """x (..., d) -> (int8 (..., d), f32 scale (...,)); symmetric per
    last-dim vector.  Identical math to ``quantize_vectors`` (scale is
    returned without the keepdim — device caches store it that way)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_vectors_jnp(q, scale, dtype):
    """Inverse of ``quantize_vectors_jnp`` (fused into the attention
    matmul on TPU; HBM traffic is the int8 bytes)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
