# Continuous batching vs serial FIFO: tokens/s on a mixed workload.
"""Throughput benchmark for the slot-pool and paged-pool decode engines.

  PYTHONPATH=src python benchmarks/continuous_batching.py
  PYTHONPATH=src python benchmarks/continuous_batching.py --full --max-new 32
  PYTHONPATH=src python benchmarks/continuous_batching.py --smoke   # CI

Workload: a fixed mix of recycled exact-prefix hits, partial-block hits and
cold misses (the three admission modes a production pool sees), served by

  * the serial FIFO scheduler (one generate per request — the seed's path),
  * the continuous-batching dense slot pool at batch sizes {1, 4, 8},
  * the paged block-table pool at the same batch sizes (PR 2) in BOTH
    admission modes: ``paged_staged_b*`` (the original staging-cache
    round-trip) and ``paged_chunked_b*`` (PR 5's paged-native chunked
    prefill — the default admission route),
  * with ``--int8``, the int8 paged pool (PR 4) in both modes
    (``paged_int8_staged_b*`` / ``paged_int8_chunked_b*``) plus
    ``int8_vs_fp_b*`` summaries (bytes-in-use reduction, tokens/s, max
    resident blocks).

All paths see identical precached recycler contents.  Each configuration
runs the workload once untimed (jit warmup — per-suffix-length prefill
executables on the staged paths, ONE chunk executable on the chunked
paths, plus the one pool decode executable) and twice timed (best wins;
the box is shared).  Reported tokens/s counts generated tokens only.

Paged rows also record the admission-latency story this PR is about:
``ttft_mean_s`` / ``ttft_max_s`` (submit -> first sampled token, the
serving TTFT) and ``prefill_compiles`` (compiled prefill executables —
per distinct suffix length on the staged path, exactly 1 on the chunked
path), summarized per batch size in ``chunked_vs_staged_b*`` rows.

With ``--semantic``, a prefix-free workload (every prompt shares 8
interior blocks with one donor but no prefix) runs with semantic
block-donor grafting off and on: ``semantic_off_b*`` / ``semantic_on_b*``
rows record hit rate, reuse depth, graft/refusal counts and gate
divergence, ``semantic_vs_exact_b*`` summarizes reuse-where-prefix-sees-
zero plus output fidelity (embedding cosine, on vs off), and
``semantic_preservation`` proves the standard workload's prefix-path
requests keep their mode and text under semantic mode.

With ``--speculative``, self-speculative decode (the same weights draft
``--gamma`` tokens against a pre-gathered sink+recent block view via
fixed-point sweeps — one multi-token dispatch per sweep — and ONE
batched dispatch verifies the bundle) runs against plain chunked decode
on a LONG-generation workload (``--long-new`` tokens per request — the
regime where decode dominates): ``{label}_spec_long_b*`` vs
``{label}_chunked_long_b*`` rows record decode tok/s, TPOT p50/p95,
acceptance rate, mean accepted length and tokens per round, summarized
in ``spec_vs_plain_{label}_b*`` with the decode speedup.  Every timed
row now carries ``tpot_p50_s`` / ``tpot_p95_s`` (per-token decode
latency; a speculative burst records equal per-token shares of its
round, so accepted drafts show up as lower TPOT).

Besides the table, the run writes ``BENCH_continuous_batching.json`` (or
``--json-out PATH``) so CI can track the perf trajectory machine-readably.
``--check-chunked`` (CI smoke) fails the run if any chunked config
compiled more than one prefill executable per chunk shape or if the
TTFT rows are missing from the artifact; ``--packed`` adds a
burst-arrival workload (8 requests at once) served by the chunked route
vs the ragged packed route (ALL pending admissions' chunk steps in ONE
dispatch per engine step), with ``packed_vs_chunked_b*`` rows recording
admission tokens/s, TTFT p50/p95 and dispatch/executable counts, and
``--check-packed`` gates token identity, one-dispatch-per-step, the
bucket-ladder compile bound and TTFT p95 no worse than chunked; ``--check-semantic`` fails it
unless the semantic rows show grafted reuse depth > 0 where the prefix
paths report 0, with the prefix paths byte-preserved; ``--check-spec``
fails it unless speculative rounds actually ran AND speculative greedy
decode is token-identical to non-speculative greedy decode (the
equivalence oracle — perf is reported, correctness is gated).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import get_config
from repro.core import HashEmbedder
from repro.core.metrics import tpot_summary
from repro.models import init_params, paged_block_bytes
from repro.models.cache import cache_bytes
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           Engine, FIFOScheduler, PagedEngine)

CACHED = [
    "the quick brown fox jumps over the lazy dog today",
    "what is the capital of france and why",
    "explain machine learning in simple terms please",
]

# 64 shared characters = 8 aligned byte-token blocks at block_size 8; a
# 7-char head (+BOS) fills exactly one differing block, so every query
# shares 8 interior blocks with the donor while sharing NO prefix — the
# workload where both prefix paths report zero reuse
SEM_MID = "the quick brown fox jumps over the lazy dog again and again!!!!"
SEM_DONOR = "aaaaaaa" + SEM_MID


def workload(n_requests: int):
    """Round-robin mix: exact hit / partial hit / cold miss."""
    reqs = []
    for i in range(n_requests):
        kind = i % 3
        if kind == 0:
            reqs.append(CACHED[i % len(CACHED)] + f" extended {i}")
        elif kind == 1:
            base = CACHED[i % len(CACHED)].rsplit(" ", 2)[0]
            reqs.append(base + f" divergent tail {i}")
        else:
            reqs.append(f"cold unseen prompt number {i} with no overlap")
    return reqs


def semantic_workload(n_requests: int):
    """Prefix-free queries sharing the donor's middle blocks."""
    return [f"q{i:06d}" + SEM_MID for i in range(n_requests)]


def _run(sched, prompts, max_new):
    """(seconds, generated_tokens, ttfts, served_results) for one
    workload pass.  Run twice on the SAME scheduler: the first pass
    compiles every prefill executable (one per suffix length staged, one
    total chunked) plus the pool decode step; only the second pass is a
    fair timing (the paper's T4 runs have no compile step either)."""
    sched.completed = []
    for p in prompts:
        sched.submit(p, max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    rejected = [r for r in done if r.result is None]
    if rejected:
        print(f"# {len(rejected)} request(s) rejected: {rejected[0].error}")
    served = [r.result for r in done if r.result is not None]
    toks = sum(r.gen_tokens for r in served)
    ttfts = [r.ttft_s for r in served]
    return dt, toks, ttfts, served


def timed_best(sched, prompts, max_new):
    """Warmup pass, then best of two timed passes (this box is shared;
    a single pass can eat a CPU-contention spike).  The warmup pass's
    TTFTs are returned too (as the 5th element): they INCLUDE compile
    time, which is the cold-start story — the staged admission path
    compiles one prefill executable per distinct suffix length right
    there, the chunked path compiles once ever.  The winning pass's
    GenResults ride along (4th element) so callers can summarize TPOT
    over single-pass per-token timings."""
    _, _, cold, _ = _run(sched, prompts, max_new)      # warmup compile
    a = _run(sched, prompts, max_new)
    b = _run(sched, prompts, max_new)
    return min(a, b, key=lambda r: r[0]) + (cold,)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI preset: fewer requests/batches, reduced "
                         "config, still emits the JSON record")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--int8", action="store_true",
                    help="also run the int8 paged pool (kv_quant) and "
                         "record fp-vs-int8 device_kv_bytes_in_use, "
                         "tokens/s and max resident blocks")
    ap.add_argument("--semantic", action="store_true",
                    help="also run the semantic block-donor workload "
                         "(prefix-free prompts sharing interior blocks "
                         "with one donor) with grafting off and on, and "
                         "record hit rate / reuse depth / graft counts / "
                         "gate divergence / output fidelity rows")
    ap.add_argument("--check-semantic", action="store_true",
                    help="fail (exit 1) unless the semantic-on rows show "
                         "grafts with reuse depth > 0 where the prefix "
                         "paths report 0, output fidelity clears "
                         "--fidelity-min, and semantic mode preserved "
                         "every prefix-path request's mode and text "
                         "(CI gate; implies --semantic)")
    ap.add_argument("--fidelity-min", type=float, default=-1.0,
                    help="minimum mean embedding cosine between "
                         "semantic-on and semantic-off outputs for "
                         "--check-semantic (default -1.0 = record only; "
                         "raise it when running trained weights, where "
                         "boundary recompute should keep outputs close)")
    ap.add_argument("--speculative", action="store_true",
                    help="also run self-speculative decode (sparse-view "
                         "drafter + single-dispatch verify) against plain "
                         "chunked decode on a LONG-generation workload "
                         "(--long-new tokens per request) and record "
                         "decode tok/s, TPOT p50/p95, acceptance rate "
                         "and mean accepted length per config")
    ap.add_argument("--gamma", type=int, default=12,
                    help="draft depth per speculative round (int8 pools "
                         "cap it at (fp_tail_blocks-1)*block_size)")
    ap.add_argument("--long-new", type=int, default=128,
                    help="generated tokens per request on the "
                         "long-generation speculative workload "
                         "(--smoke caps it at 16)")
    ap.add_argument("--check-spec", action="store_true",
                    help="fail (exit 1) unless speculative rows exist "
                         "with spec_rounds > 0 AND speculative greedy "
                         "decode is token-identical to non-speculative "
                         "greedy decode on the standard workload "
                         "(CI gate; implies --speculative)")
    ap.add_argument("--check-chunked", action="store_true",
                    help="fail (exit 1) unless every chunked config "
                         "compiled at most one prefill executable per "
                         "fixed chunk shape and every paged row carries "
                         "TTFT data (CI gate)")
    ap.add_argument("--packed", action="store_true",
                    help="also run the burst-arrival workload (8 requests "
                         "submitted at once) through the packed admission "
                         "route vs the chunked one and record admission "
                         "tokens/s, TTFT p50/p95 and prefill dispatch/"
                         "executable counts (packed_vs_chunked_b* rows)")
    ap.add_argument("--check-packed", action="store_true",
                    help="fail (exit 1) unless packed greedy decode is "
                         "token-identical to chunked on the burst "
                         "workload, the packed route issued ONE prefill "
                         "dispatch per engine step, compiled at most one "
                         "executable per packed bucket, and its TTFT p95 "
                         "is no worse than chunked (CI gate; implies "
                         "--packed)")
    ap.add_argument("--mesh", default=None, metavar="DxT",
                    help="also run the mesh-sharded ShardedServer (D "
                         "data-parallel PagedEngine replicas x T-way TP "
                         "block pools over a shared host L2) at replicas "
                         "in {1, D} and record tokens/s, per-replica "
                         "device KV bytes in use and cross-replica "
                         "warm-admission promotions; needs D*T devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count set BEFORE jax imports)")
    ap.add_argument("--check-mesh", action="store_true",
                    help="fail (exit 1) unless the sharded rows are "
                         "token-identical to the single-device paged "
                         "engine, DP scaling at D replicas beats 1 "
                         "replica, and the warm cross-replica pass "
                         "promoted blocks instead of recomputing (CI "
                         "gate; implies --mesh 2x2)")
    ap.add_argument("--overload", action="store_true",
                    help="also run the overload workload: the standard "
                         "request mix against an UNDERSIZED overcommitted "
                         "block pool (forced preemptions with exact "
                         "resume) plus a bounded-queue pass (typed "
                         "sheds), recording preemption/requeue/shed "
                         "counts, recomputed tokens and the pressure "
                         "slo_summary rows")
    ap.add_argument("--check-preempt", action="store_true",
                    help="fail (exit 1) unless every non-shed overload "
                         "request completes token-identical to the "
                         "uncapped run, at least one preemption actually "
                         "fired, pool invariants hold after the run, and "
                         "shed requests carry typed outcomes (CI gate; "
                         "implies --overload)")
    ap.add_argument("--json-out", default="BENCH_continuous_batching.json")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.max_new, args.batches = 6, 4, [4]

    cfg = get_config("dialogpt-medium")
    if not args.full:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = workload(args.requests)

    eng = Engine(cfg, params, max_new_tokens=args.max_new, block_size=8,
                 enable_partial=True)
    eng.precache(CACHED)
    serial_sched = FIFOScheduler(eng)

    rows = []
    dt, toks, _, served, _ = timed_best(serial_sched, prompts, args.max_new)
    serial_tps = toks / dt
    tp = tpot_summary(served)
    rows.append({"config": "serial_fifo", "wall_s": dt, "gen_tokens": toks,
                 "tokens_per_s": serial_tps, "speedup": 1.0,
                 "tpot_p50_s": tp["tpot_p50_s"],
                 "tpot_p95_s": tp["tpot_p95_s"]})

    for b in args.batches:
        beng = BatchedEngine(cfg, params, max_batch=b,
                             capacity=args.capacity,
                             max_new_tokens=args.max_new, block_size=8,
                             enable_partial=True)
        beng.precache(CACHED)
        dt, toks, _, served, _ = timed_best(ContinuousBatchingScheduler(beng),
                                            prompts, args.max_new)
        tp = tpot_summary(served)
        rows.append({"config": f"dense_pool_b{b}", "wall_s": dt,
                     "gen_tokens": toks, "tokens_per_s": toks / dt,
                     "speedup": (toks / dt) / serial_tps,
                     "tpot_p50_s": tp["tpot_p50_s"],
                     "tpot_p95_s": tp["tpot_p95_s"],
                     "device_kv_bytes": cache_bytes(beng.pool)})

    paged_variants = [(False, "paged")]
    if args.int8:
        paged_variants.append((True, "paged_int8"))
    for quant, label in paged_variants:
        for mode in ("staged", "chunked"):
            for b in args.batches:
                peng = PagedEngine(cfg, params, max_batch=b,
                                   capacity=args.capacity,
                                   max_new_tokens=args.max_new,
                                   block_size=8, enable_partial=True,
                                   kv_quant=quant, prefill_mode=mode)
                peng.precache(CACHED)
                dt, toks, ttfts, served, cold = timed_best(
                    ContinuousBatchingScheduler(peng), prompts,
                    args.max_new)
                blk_bytes = paged_block_bytes(cfg, peng.block, quant=quant)
                tp = tpot_summary(served)
                rows.append({
                    "config": f"{label}_{mode}_b{b}", "wall_s": dt,
                    "gen_tokens": toks, "tokens_per_s": toks / dt,
                    "speedup": (toks / dt) / serial_tps,
                    "tpot_p50_s": tp["tpot_p50_s"],
                    "tpot_p95_s": tp["tpot_p95_s"],
                    # admission latency: submit -> first sampled token
                    "ttft_mean_s": sum(ttfts) / max(len(ttfts), 1),
                    "ttft_max_s": max(ttfts, default=0.0),
                    # cold = warmup pass, compiles included: the
                    # per-suffix-length recompile cost the chunked
                    # route eliminates
                    "ttft_cold_mean_s": sum(cold) / max(len(cold), 1),
                    "ttft_cold_max_s": max(cold, default=0.0),
                    # compiled prefill executables: per suffix length on
                    # the staged path, exactly one on the chunked path
                    "prefill_compiles": peng.prefill_compiles(),
                    "prefill_chunk_shapes": len(peng.chunk_shapes),
                    "prefill_chunks": peng.stats["prefill_chunks"],
                    "staging_prefills": peng.stats["staging_prefills"],
                    "spec_preallocs": peng.stats["spec_preallocs"],
                    "layout_conversions":
                        peng.stats["layout_conversions"],
                    # device_kv_bytes is the STATIC allocation in both
                    # pool rows (apples to apples with dense_pool_b*);
                    # the peak/in-use numbers show what sharing and
                    # on-demand allocation actually touched
                    "device_kv_bytes": cache_bytes(peng.pool),
                    "device_kv_bytes_peak":
                        peng.allocator.stats["peak_live"] * blk_bytes,
                    "device_kv_bytes_in_use":
                        peng.device_kv_bytes_in_use(),
                    "max_resident_blocks":
                        peng.allocator.stats["peak_live"],
                    "resident_hits": peng.stats["resident_hits"],
                    "host_promotions": peng.stats["host_promotions"],
                    "h2d_bytes": peng.stats["h2d_bytes"],
                    "cow_copies": peng.stats["cow_copies"]})

    by = {r["config"]: r for r in rows}
    for quant, label in paged_variants:
        # staged-vs-chunked admission summary per batch size: TTFT and
        # compile counts are what the chunked route exists to improve
        for b in args.batches:
            s = by[f"{label}_staged_b{b}"]
            c = by[f"{label}_chunked_b{b}"]
            rows.append({
                "config": f"chunked_vs_staged_{label}_b{b}",
                "ttft_mean_staged_s": s["ttft_mean_s"],
                "ttft_mean_chunked_s": c["ttft_mean_s"],
                "ttft_speedup": s["ttft_mean_s"] / max(c["ttft_mean_s"],
                                                       1e-9),
                "ttft_cold_mean_staged_s": s["ttft_cold_mean_s"],
                "ttft_cold_mean_chunked_s": c["ttft_cold_mean_s"],
                "ttft_cold_speedup":
                    s["ttft_cold_mean_s"] / max(c["ttft_cold_mean_s"],
                                                1e-9),
                "prefill_compiles_staged": s["prefill_compiles"],
                "prefill_compiles_chunked": c["prefill_compiles"],
                "tokens_per_s_staged": s["tokens_per_s"],
                "tokens_per_s_chunked": c["tokens_per_s"],
            })

    if args.int8:
        # machine-readable fp-vs-int8 summary per batch size (over the
        # default chunked admission route): the whole point of the int8
        # tier is more resident context per HBM byte
        for b in args.batches:
            fp, q8 = by[f"paged_chunked_b{b}"], by[f"paged_int8_chunked_b{b}"]
            rows.append({
                "config": f"int8_vs_fp_b{b}",
                "bytes_in_use_fp": fp["device_kv_bytes_in_use"],
                "bytes_in_use_int8": q8["device_kv_bytes_in_use"],
                "bytes_reduction":
                    fp["device_kv_bytes_in_use"]
                    / max(q8["device_kv_bytes_in_use"], 1),
                "tokens_per_s_fp": fp["tokens_per_s"],
                "tokens_per_s_int8": q8["tokens_per_s"],
                "max_resident_blocks_fp": fp["max_resident_blocks"],
                "max_resident_blocks_int8": q8["max_resident_blocks"],
            })

    if args.check_packed:
        args.packed = True
    if args.packed:
        # Burst arrival — the regime the packed route exists for: 8
        # requests land at once, so every engine step has up to 8
        # admissions mid-flight.  The chunked route advances them with
        # one prefill dispatch EACH per step; the packed route lands all
        # their chunk steps in ONE ragged packed dispatch.  Same
        # workload, same precache, greedy — tokens must be identical,
        # the win is admission latency (TTFT) and dispatch count.
        burst_b = 8
        burst_prompts = workload(burst_b)
        pair = {}
        for mode in ("chunked", "packed"):
            peng = PagedEngine(cfg, params, max_batch=burst_b,
                               capacity=args.capacity,
                               max_new_tokens=args.max_new, block_size=8,
                               enable_partial=True, prefill_mode=mode)
            peng.precache(CACHED)
            sched = ContinuousBatchingScheduler(peng)
            dt, toks, ttfts, served, cold = timed_best(
                sched, burst_prompts, args.max_new)
            peng.check_invariants()
            from repro.core.metrics import slo_summary
            slo = slo_summary(served)
            prompt_toks = sum(r.prompt_tokens for r in served)
            row = {
                "config": f"{mode}_burst_b{burst_b}", "wall_s": dt,
                "gen_tokens": toks, "tokens_per_s": toks / dt,
                "speedup": (toks / dt) / serial_tps,
                # admission throughput: prompt tokens prefilled per
                # wall-second of the whole burst pass
                "admission_tokens_per_s": prompt_toks / dt,
                "ttft_mean_s": sum(ttfts) / max(len(ttfts), 1),
                "ttft_p50_s": slo["ttft_p50_s"],
                "ttft_p95_s": slo["ttft_p95_s"],
                "tpot_p50_s": slo["tpot_p50_s"],
                "tpot_p95_s": slo["tpot_p95_s"],
                "prefill_compiles": peng.prefill_compiles(),
                "prefill_chunks": peng.stats["prefill_chunks"],
                "prefill_dispatches": peng.stats["prefill_dispatches"],
                "prefill_packed_steps":
                    peng.stats.get("prefill_packed_steps", 0),
                "packed_buckets": len(peng.packed_buckets),
                # greedy outputs are deterministic across the timed
                # passes, so the scheduler's final pass stands in for
                # the best one in the identity gate
                "tokens_by_prompt": {r.prompt: [int(t) for t in
                                                r.result.token_ids]
                                     for r in sched.completed
                                     if r.result is not None},
            }
            pair[mode] = row
            rows.append(row)
        c, p = pair["chunked"], pair["packed"]
        rows.append({
            "config": f"packed_vs_chunked_b{burst_b}",
            "admission_tokens_per_s_chunked": c["admission_tokens_per_s"],
            "admission_tokens_per_s_packed": p["admission_tokens_per_s"],
            "ttft_p50_chunked_s": c["ttft_p50_s"],
            "ttft_p50_packed_s": p["ttft_p50_s"],
            "ttft_p95_chunked_s": c["ttft_p95_s"],
            "ttft_p95_packed_s": p["ttft_p95_s"],
            "ttft_p95_speedup": (c["ttft_p95_s"]
                                 / max(p["ttft_p95_s"], 1e-9)),
            "prefill_dispatches_chunked": c["prefill_dispatches"],
            "prefill_dispatches_packed": p["prefill_dispatches"],
            "prefill_compiles_chunked": c["prefill_compiles"],
            "prefill_compiles_packed": p["prefill_compiles"],
            "tokens_identical": (c["tokens_by_prompt"]
                                 == p["tokens_by_prompt"]),
        })
        for row in (c, p):              # the per-prompt tokens served
            del row["tokens_by_prompt"]  # their gate; keep the json lean

    if args.check_spec:
        args.speculative = True
    if args.speculative:
        # Self-speculative decode vs plain chunked decode on a LONG
        # generation workload — the regime speculation targets: decode
        # steps dominate and every accepted draft saves one full-table
        # dispatch.  The short workload above stays untouched as the
        # regression baseline.  int8 pools cap gamma at the ring-restore
        # bound (fp_tail_blocks - 1) * block_size.
        long_new = min(args.long_new, 16) if args.smoke else args.long_new
        cap_long = max(args.capacity,
                       8 * ((96 + long_new) // 8 + 2))
        for quant, label in paged_variants:
            gamma = min(args.gamma, 8) if quant else args.gamma
            for b in args.batches:
                pair = {}
                for spec in (False, True):
                    # full-coverage draft view: with random-init weights
                    # attention is diffuse, so a truly sparse view's
                    # greedy argmax rarely matches the full-context
                    # target (acceptance ~15%).  The view mechanism is
                    # identical either way — gathered once per round,
                    # stale within it — and recent_blocks is the honest
                    # knob a trained checkpoint would shrink.
                    peng = PagedEngine(cfg, params, max_batch=b,
                                       capacity=cap_long,
                                       max_new_tokens=long_new,
                                       block_size=8, enable_partial=True,
                                       kv_quant=quant,
                                       prefill_mode="chunked",
                                       speculative=spec, gamma=gamma,
                                       recent_blocks=cap_long // 8)
                    peng.precache(CACHED)
                    dt, toks, ttfts, served, _ = timed_best(
                        ContinuousBatchingScheduler(peng), prompts,
                        long_new)
                    peng.check_invariants()
                    tp = tpot_summary(served)
                    tag = "spec" if spec else "chunked"
                    row = {
                        "config": f"{label}_{tag}_long_b{b}", "wall_s": dt,
                        "gen_tokens": toks, "tokens_per_s": toks / dt,
                        "speedup": (toks / dt) / serial_tps,
                        "tpot_p50_s": tp["tpot_p50_s"],
                        "tpot_p95_s": tp["tpot_p95_s"],
                        "ttft_mean_s": sum(ttfts) / max(len(ttfts), 1),
                    }
                    if spec:
                        st = peng.stats
                        row.update({
                            "gamma": gamma,
                            "spec_iters": peng.spec_iters,
                            "recent_blocks": peng.recent_blocks,
                            "spec_rounds": st["spec_rounds"],
                            "acceptance_rate":
                                st["spec_accepted_tokens"]
                                / max(st["spec_draft_tokens"], 1),
                            "mean_accepted_len":
                                st["spec_accepted_tokens"]
                                / max(st["spec_rounds"], 1),
                            "tokens_per_round":
                                st["spec_emitted_tokens"]
                                / max(st["spec_rounds"], 1),
                            "spec_fallback_steps":
                                st["spec_fallback_steps"],
                        })
                    pair[spec] = row
                    rows.append(row)
                rows.append({
                    "config": f"spec_vs_plain_{label}_b{b}",
                    "tokens_per_s_plain": pair[False]["tokens_per_s"],
                    "tokens_per_s_spec": pair[True]["tokens_per_s"],
                    "decode_speedup": (pair[True]["tokens_per_s"]
                                       / max(pair[False]["tokens_per_s"],
                                             1e-9)),
                    "tpot_p50_plain_s": pair[False]["tpot_p50_s"],
                    "tpot_p50_spec_s": pair[True]["tpot_p50_s"],
                    "acceptance_rate": pair[True]["acceptance_rate"],
                    "mean_accepted_len": pair[True]["mean_accepted_len"],
                    "tokens_per_round": pair[True]["tokens_per_round"],
                })

    if args.check_semantic:
        args.semantic = True
    if args.semantic:
        # Semantic block-donor recycling (grafting rides the chunked
        # admission only).  graft_max_div is wide open here: with random
        # init (this benchmark never loads trained weights) the boundary
        # recompute always diverges numerically from the donor, and the
        # point of these rows is the reuse/fidelity ACCOUNTING — the
        # gate's recorded divergences, not its policy.
        sem_prompts = semantic_workload(args.requests)
        emb = HashEmbedder()
        texts = {}
        for sem in (False, True):
            label = "on" if sem else "off"
            for b in args.batches:
                peng = PagedEngine(cfg, params, max_batch=b,
                                   capacity=args.capacity,
                                   max_new_tokens=args.max_new,
                                   block_size=8, enable_partial=True,
                                   prefill_mode="chunked", semantic=sem,
                                   graft_max_div=1e9)
                sched = ContinuousBatchingScheduler(peng)
                sched.submit(SEM_DONOR, admit=True,
                             max_new_tokens=args.max_new)
                sched.run()
                sched.completed = []
                for p in sem_prompts:
                    sched.submit(p, max_new_tokens=args.max_new)
                t0 = time.perf_counter()
                done = sched.run()
                dt = time.perf_counter() - t0
                peng.check_invariants()
                served = {r.prompt: r.result for r in done
                          if r.result is not None}
                texts[(sem, b)] = {p: served[p].text for p in sem_prompts
                                   if p in served}
                depths = [served[p].reuse_depth for p in sem_prompts
                          if p in served]
                toks = sum(r.gen_tokens for r in served.values())
                divs = peng.semantic_gate_divs
                rows.append({
                    "config": f"semantic_{label}_b{b}", "wall_s": dt,
                    "gen_tokens": toks, "tokens_per_s": toks / dt,
                    "speedup": (toks / dt) / serial_tps,
                    "hit_rate": (sum(served[p].cache_hit
                                     for p in served) / max(len(served),
                                                            1)),
                    "reuse_depth_mean": (sum(depths)
                                         / max(len(depths), 1)),
                    "reuse_depth_max": max(depths, default=0),
                    "semantic_grafts": peng.stats["semantic_grafts"],
                    "semantic_refusals":
                        peng.stats["semantic_refusals"],
                    "semantic_resident_grafts":
                        peng.stats["semantic_resident_grafts"],
                    "semantic_host_grafts":
                        peng.stats["semantic_host_grafts"],
                    "tokens_grafted": peng.stats["tokens_grafted"],
                    "gate_div_mean": (sum(divs) / max(len(divs), 1)),
                    "gate_div_max": max(divs, default=0.0)})
        by = {r["config"]: r for r in rows}
        for b in args.batches:
            off, on = by[f"semantic_off_b{b}"], by[f"semantic_on_b{b}"]
            # fidelity: mean embedding cosine of per-request outputs, on
            # vs off — 1.0 means grafting changed nothing the embedder
            # can see (only meaningful with trained weights)
            cos = [float(emb.encode(texts[(True, b)][p])
                         @ emb.encode(texts[(False, b)][p]))
                   for p in sem_prompts
                   if p in texts[(True, b)] and p in texts[(False, b)]]
            rows.append({
                "config": f"semantic_vs_exact_b{b}",
                "reuse_depth_mean_off": off["reuse_depth_mean"],
                "reuse_depth_mean_on": on["reuse_depth_mean"],
                "hit_rate_off": off["hit_rate"],
                "hit_rate_on": on["hit_rate"],
                "semantic_grafts": on["semantic_grafts"],
                "tokens_grafted": on["tokens_grafted"],
                "gate_div_mean": on["gate_div_mean"],
                "fidelity": sum(cos) / max(len(cos), 1)})
        # prefix-path preservation: on the STANDARD workload semantic
        # mode must not change any request's mode or text (grafting only
        # ever fires on a prefix miss)
        pres_results = []
        for sem in (False, True):
            peng = PagedEngine(cfg, params, max_batch=args.batches[-1],
                               capacity=args.capacity,
                               max_new_tokens=args.max_new, block_size=8,
                               enable_partial=True,
                               prefill_mode="chunked", semantic=sem)
            peng.precache(CACHED)
            sched = ContinuousBatchingScheduler(peng)
            for p in prompts:
                sched.submit(p, max_new_tokens=args.max_new)
            done = sched.run()
            pres_results.append({r.prompt: r.result for r in done
                                 if r.result is not None})
        off_r, on_r = pres_results
        mismatches = [p for p in prompts
                      if p in off_r and off_r[p].cache_hit
                      and (p not in on_r
                           or on_r[p].mode != off_r[p].mode
                           or on_r[p].text != off_r[p].text)]
        rows.append({"config": "semantic_preservation",
                     "prefix_hits_checked":
                         sum(1 for p in prompts
                             if p in off_r and off_r[p].cache_hit),
                     "mismatches": len(mismatches),
                     "preserved": not mismatches})

    if args.check_mesh and args.mesh is None:
        args.mesh = "2x2"
    if args.mesh is not None:
        # Mesh-sharded serving (PR 8): D data-parallel PagedEngine
        # replicas, each TP-sharding its block pool over a (1, T)
        # sub-mesh, sharing ONE host L2.  Three claims measured here:
        # (1) greedy tokens stay identical to the single-device paged
        # engine, (2) a prefix admitted on replica 0 serves on the last
        # replica as block-granular host promotions (cross-replica warm
        # admission, zero recompute), (3) the DP layout beats the
        # device-count-equivalent pure-TP layout in tokens/s.
        #
        # The scaling comparison holds the HARDWARE constant: the same
        # D*T devices and the same aggregate block budget laid out as
        # ONE replica TP-sharded D*T ways (mesh_1x{D*T}) or as D
        # replicas TP-sharded T ways (mesh_{D}x{T}).  That is the
        # deployment question data parallelism answers — wider TP buys
        # narrower per-shard work plus a wider partial-softmax
        # reduction on EVERY dispatch, while DP replicas keep the
        # collective narrow and split the request stream.  On the
        # forced-host-device smoke topology all shards share the same
        # cores, so the margin is pure dispatch/collective overhead; on
        # a real mesh the replicas additionally overlap on disjoint
        # devices.
        import gc
        import statistics
        from repro.launch.serve import ShardedServer
        dp, tp = (int(x) for x in args.mesh.lower().split("x"))
        b = args.batches[-1]
        mesh_prompts = workload(2 * dp * b)
        # decode-dominated regime: the replication story is about decode
        # throughput, and a 4-token smoke generation would be all
        # admission overhead
        mesh_new = max(args.max_new, 16)
        nbt = args.capacity // 8
        replica_default = b * nbt + nbt + 1      # the engine default

        ref = PagedEngine(cfg, params, max_batch=b, capacity=args.capacity,
                          max_new_tokens=mesh_new, block_size=8,
                          enable_partial=True, prefill_mode="chunked")
        ref.precache(CACHED)
        sched = ContinuousBatchingScheduler(ref)
        for p in mesh_prompts:
            sched.submit(p, max_new_tokens=mesh_new)
        done = sched.run()
        ref_texts = {r.prompt: r.result.text for r in done
                     if r.result is not None}

        pair = {}
        for nrep, tp_c in ((1, dp * tp), (dp, tp)):
            # equal aggregate blocks: the wide-TP baseline holds D
            # replicas' worth of pool over its wider shard
            srv = ShardedServer(cfg, params, replicas=nrep, tp=tp_c,
                                max_batch=b, capacity=args.capacity,
                                num_blocks=(dp // nrep) * replica_default,
                                max_new_tokens=mesh_new, block_size=8,
                                enable_partial=True,
                                prefill_mode="chunked")
            srv.run(CACHED, replica=0, admit=True)
            # warm cross-replica pass: entries admitted on replica 0 must
            # serve on the LAST replica via host promotions (with one
            # replica this is a plain resident re-serve, promotions 0)
            srv.run(CACHED, replica=nrep - 1,
                    max_new_tokens=mesh_new)
            warm_promos = srv.shared_stats["cross_replica_promotions"]

            def once():
                gc.collect()
                gc.disable()          # a mid-pass GC pause swamps the
                try:                  # margin on a single-core box
                    t0 = time.perf_counter()
                    res = srv.run(mesh_prompts, max_new_tokens=mesh_new)
                    dt = time.perf_counter() - t0
                finally:
                    gc.enable()
                return dt, sum(r.gen_tokens for r in res), res
            once()                                  # warmup compile
            passes = [once() for _ in range(5)]
            dt, toks, res = sorted(passes,
                                   key=lambda r: r[0])[len(passes) // 2]
            srv.check_invariants()
            identical = all(r.text == ref_texts[p]
                            for p, r in zip(mesh_prompts, res))
            st = srv.stats()
            row = {
                "config": f"mesh_{nrep}x{tp_c}_b{b}", "wall_s": dt,
                "gen_tokens": toks, "tokens_per_s": toks / dt,
                "speedup": (toks / dt) / serial_tps,
                "replicas": nrep, "tp": tp_c,
                "tokens_identical": identical,
                "cross_replica_promotions":
                    st["cross_replica_promotions"],
                "warm_cross_replica_promotions": warm_promos,
                "host_promotions_last_replica":
                    st["per_replica"][-1]["stats"]["host_promotions"],
                "device_kv_bytes_in_use_per_replica":
                    [p["device_kv_bytes_in_use"]
                     for p in st["per_replica"]],
                "device_kv_bytes_per_device":
                    [p["device_kv_bytes_per_device"]
                     for p in st["per_replica"]],
                "kv_tp_degree": st["per_replica"][0]["kv_tp_degree"],
            }
            pair[nrep] = row
            rows.append(row)
        if dp > 1:
            r1, rn = pair[1], pair[dp]
            rows.append({
                "config": f"mesh_dp_scaling_{dp}x{tp}_b{b}",
                "tokens_per_s_r1": r1["tokens_per_s"],
                "tokens_per_s_rN": rn["tokens_per_s"],
                "dp_scaling": rn["tokens_per_s"]
                    / max(r1["tokens_per_s"], 1e-9),
                "warm_cross_replica_promotions":
                    rn["warm_cross_replica_promotions"],
                "tokens_identical": r1["tokens_identical"]
                    and rn["tokens_identical"],
            })

    if args.overload or args.check_preempt:
        # Overload workload: same request mix, but the paged pool is
        # deliberately undersized so decode writes and admission chunks
        # run out of blocks mid-flight.  The pressure-safe claim under
        # test: the engine preempts victims (demote to host L2, requeue,
        # exact resume) instead of failing the step, so every request
        # still completes with tokens identical to the uncapped run —
        # the only cost is recomputed tokens and extra wall time.  A
        # second pass bounds the queue to prove sheds are typed data,
        # not exceptions.
        from repro.core.metrics import slo_summary
        from repro.serving.scheduler import RequestOutcome
        b = args.batches[-1]
        over_prompts = workload(min(args.requests, 6))
        # blocks for roughly ONE full row (capacity/8 at block_size 8):
        # b concurrent rows cannot all be resident, and completed-row
        # frees plus trie eviction cannot hide the pressure
        overload_blocks = max(10, args.capacity // 8)

        def _overload_run(num_blocks):
            eng_kw = {}
            if num_blocks is not None:
                eng_kw = {"num_blocks": num_blocks, "overcommit": True}
            peng = PagedEngine(cfg, params, max_batch=b,
                               capacity=args.capacity,
                               max_new_tokens=args.max_new, block_size=8,
                               enable_partial=True, prefill_mode="chunked",
                               **eng_kw)
            peng.precache(CACHED)
            sched = ContinuousBatchingScheduler(peng)
            reqs = [sched.submit(p, max_new_tokens=args.max_new)
                    for p in over_prompts]
            t0 = time.perf_counter()
            sched.run()
            dt = time.perf_counter() - t0
            return peng, sched, reqs, dt

        ref_eng, _, ref_reqs, _ = _overload_run(None)
        ref_text = {p: r.result.text for p, r in zip(over_prompts,
                                                     ref_reqs)}
        peng, sched, reqs, dt = _overload_run(overload_blocks)
        invariants_ok = True
        try:
            peng.check_invariants()
        except AssertionError:
            invariants_ok = False
        mismatched = [p for p, r in zip(over_prompts, reqs)
                      if r.outcome != RequestOutcome.OK
                      or r.result.text != ref_text[p]]
        served = [r.result for r in reqs if r.result is not None]
        slo = slo_summary(served, reqs)
        rows.append({
            "config": f"overload_b{b}",
            "wall_s": dt,
            "gen_tokens": sum(r.gen_tokens for r in served),
            "tokens_per_s": sum(r.gen_tokens for r in served) / dt,
            "speedup": (sum(r.gen_tokens for r in served) / dt)
                / serial_tps,
            "num_blocks": overload_blocks,
            "preemptions": peng.stats["preemptions"],
            "preempt_errors": peng.stats["preempt_errors"],
            "step_rollbacks": peng.stats["step_rollbacks"],
            "tokens_recomputed":
                peng.stats["preempted_tokens_recomputed"],
            "requeues": sched.stats["preemptions"],
            "admissions_deferred": sched.stats["admissions_deferred"],
            "preemption_rate": slo["preemption_rate"],
            "tokens_identical": not mismatched,
            "invariants_ok": invariants_ok,
        })

        # bounded-queue pass: overflow sheds at submit with a typed
        # outcome; accepted requests are untouched by their neighbours'
        # rejection
        qeng = PagedEngine(cfg, params, max_batch=b,
                           capacity=args.capacity,
                           max_new_tokens=args.max_new, block_size=8,
                           enable_partial=True, prefill_mode="chunked")
        qsched = ContinuousBatchingScheduler(qeng, queue_limit=2)
        qreqs = [qsched.submit(p, max_new_tokens=args.max_new)
                 for p in over_prompts]
        qsched.run()
        qserved = [r.result for r in qreqs if r.result is not None]
        qslo = slo_summary(qserved, qreqs)
        untyped = [r for r in qreqs if r.outcome is None]
        rows.append({
            "config": f"overload_shed_b{b}",
            "queue_limit": 2,
            "shed_queue_full": qsched.stats["shed_queue_full"],
            "shed_deadline": qsched.stats["shed_deadline"],
            "shed_rate": qslo["shed_rate"],
            "outcome_counts": qslo["outcome_counts"],
            "all_outcomes_typed": not untyped,
            "accepted_identical": all(
                r.result.text == ref_text[p]
                for p, r in zip(over_prompts, qreqs)
                if r.outcome == RequestOutcome.OK),
        })

    timed = [r for r in rows if "wall_s" in r]
    print(f"{'config':<24} {'wall_s':>8} {'gen_tok':>8} "
          f"{'tok/s':>10} {'speedup':>8} {'tpot_ms':>8} {'ttft_ms':>8} "
          f"{'compiles':>8}")
    for r in timed:
        tpot = (f"{1e3 * r['tpot_p50_s']:>8.2f}"
                if isinstance(r.get("tpot_p50_s"), float)
                else f"{'-':>8}")
        ttft = (f"{1e3 * r['ttft_mean_s']:>8.1f}"
                if "ttft_mean_s" in r else f"{'-':>8}")
        comp = (f"{r['prefill_compiles']:>8d}"
                if "prefill_compiles" in r else f"{'-':>8}")
        print(f"{r['config']:<24} {r['wall_s']:>8.3f} "
              f"{r['gen_tokens']:>8d} {r['tokens_per_s']:>10.1f} "
              f"{r['speedup']:>7.2f}x {tpot} {ttft} {comp}")
    best = max(r["speedup"] for r in timed[1:])
    print(f"\nbest batched speedup over serial: {best:.2f}x")
    for r in rows:
        if r["config"].startswith("chunked_vs_staged"):
            print(f"{r['config']}: warm ttft "
                  f"{1e3 * r['ttft_mean_staged_s']:.1f}ms -> "
                  f"{1e3 * r['ttft_mean_chunked_s']:.1f}ms "
                  f"({r['ttft_speedup']:.2f}x), cold ttft "
                  f"{1e3 * r['ttft_cold_mean_staged_s']:.1f}ms -> "
                  f"{1e3 * r['ttft_cold_mean_chunked_s']:.1f}ms "
                  f"({r['ttft_cold_speedup']:.2f}x), prefill compiles "
                  f"{r['prefill_compiles_staged']} -> "
                  f"{r['prefill_compiles_chunked']}")
        if r["config"].startswith("packed_vs_chunked"):
            print(f"{r['config']}: ttft p95 "
                  f"{1e3 * r['ttft_p95_chunked_s']:.1f}ms -> "
                  f"{1e3 * r['ttft_p95_packed_s']:.1f}ms "
                  f"({r['ttft_p95_speedup']:.2f}x), prefill dispatches "
                  f"{r['prefill_dispatches_chunked']} -> "
                  f"{r['prefill_dispatches_packed']}, compiles "
                  f"{r['prefill_compiles_chunked']} -> "
                  f"{r['prefill_compiles_packed']}, tokens identical: "
                  f"{r['tokens_identical']}")
        if r["config"].startswith("int8_vs_fp"):
            print(f"{r['config']}: {r['bytes_reduction']:.2f}x fewer device "
                  f"KV bytes in use ({r['bytes_in_use_fp']} -> "
                  f"{r['bytes_in_use_int8']})")
        if r["config"].startswith("spec_vs_plain"):
            print(f"{r['config']}: decode "
                  f"{r['tokens_per_s_plain']:.1f} -> "
                  f"{r['tokens_per_s_spec']:.1f} tok/s "
                  f"({r['decode_speedup']:.2f}x), acceptance "
                  f"{100 * r['acceptance_rate']:.0f}%, "
                  f"{r['mean_accepted_len']:.2f} accepted + bonus = "
                  f"{r['tokens_per_round']:.2f} tok/round, tpot p50 "
                  f"{1e3 * r['tpot_p50_plain_s']:.1f}ms -> "
                  f"{1e3 * r['tpot_p50_spec_s']:.1f}ms")
        if r["config"].startswith("semantic_vs_exact"):
            print(f"{r['config']}: reuse depth "
                  f"{r['reuse_depth_mean_off']:.1f} -> "
                  f"{r['reuse_depth_mean_on']:.1f} "
                  f"({r['semantic_grafts']} grafts, "
                  f"{r['tokens_grafted']} tokens), gate div "
                  f"{r['gate_div_mean']:.3f}, fidelity "
                  f"{r['fidelity']:.3f}")
        if r["config"] == "semantic_preservation":
            print(f"semantic_preservation: "
                  f"{r['prefix_hits_checked']} prefix-path hits, "
                  f"{r['mismatches']} mismatches under semantic mode")
        if r["config"].startswith("overload_b"):
            print(f"{r['config']}: num_blocks={r['num_blocks']}, "
                  f"{r['preemptions']} preemptions, "
                  f"{r['requeues']} requeues, "
                  f"{r['tokens_recomputed']} tokens recomputed, "
                  f"tokens identical: {r['tokens_identical']}, "
                  f"invariants ok: {r['invariants_ok']}")
        if r["config"].startswith("overload_shed"):
            print(f"{r['config']}: queue_limit={r['queue_limit']}, "
                  f"{r['shed_queue_full']} shed (typed: "
                  f"{r['all_outcomes_typed']}), accepted identical: "
                  f"{r['accepted_identical']}")
        if r["config"].startswith("mesh_dp_scaling"):
            print(f"{r['config']}: {r['tokens_per_s_r1']:.1f} -> "
                  f"{r['tokens_per_s_rN']:.1f} tok/s "
                  f"({r['dp_scaling']:.2f}x DP scaling), "
                  f"{r['warm_cross_replica_promotions']} warm "
                  f"cross-replica promotions, tokens identical: "
                  f"{r['tokens_identical']}")

    record = {
        "benchmark": "continuous_batching",
        "config": cfg.name,
        "requests": args.requests,
        "max_new": args.max_new,
        "capacity": args.capacity,
        "results": rows,
    }
    with open(args.json_out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.json_out}")

    if args.check_chunked:
        # CI gate: the chunked route must have compiled exactly ONE
        # prefill executable per (chunk shape, quant mode) config, and
        # every paged row must carry TTFT data
        bad = []
        chunked_rows = [r for r in timed if "_chunked_b" in r["config"]]
        if not chunked_rows:
            bad.append("no chunked config rows in the artifact")
        for r in chunked_rows:
            budget = r.get("prefill_chunk_shapes", 1)
            compiles = r.get("prefill_compiles", 0)
            if compiles < 0:
                # _cache_size unavailable: an unknown count must not let
                # a per-suffix-length recompile regression slip through
                bad.append(f"{r['config']}: prefill compile count "
                           f"unavailable (jit._cache_size missing)")
            elif compiles > budget:
                bad.append(f"{r['config']}: {compiles} prefill "
                           f"executables (expected <= {budget}, one per "
                           f"chunk shape)")
        for r in timed:
            if ("_staged_b" in r["config"] or "_chunked_b" in r["config"]) \
                    and "ttft_mean_s" not in r:
                bad.append(f"{r['config']}: missing ttft_mean_s")
        if not any(r["config"].startswith("chunked_vs_staged")
                   for r in rows):
            bad.append("missing chunked_vs_staged summary rows")
        if bad:
            raise SystemExit("--check-chunked FAILED:\n  " +
                             "\n  ".join(bad))
        print("--check-chunked OK: at most one compiled prefill per "
              "chunk shape, TTFT rows present")

    if args.check_packed:
        # CI gate for the packed admission route: token identity vs
        # chunked on the burst workload, ONE prefill dispatch per packed
        # engine step (vs one per admission chunk on the chunked route),
        # at most one compiled executable per packed bucket, and TTFT
        # p95 no worse than chunked.  Perf margins beyond that are
        # reported, not gated — a shared CI box cannot promise ratios.
        bad = []
        summary = [r for r in rows
                   if r["config"].startswith("packed_vs_chunked")]
        if not summary:
            bad.append("no packed_vs_chunked summary row in the artifact")
        for r in summary:
            if not r["tokens_identical"]:
                bad.append(f"{r['config']}: packed tokens diverge from "
                           f"chunked on the burst workload")
            if r["ttft_p95_packed_s"] > r["ttft_p95_chunked_s"]:
                bad.append(f"{r['config']}: packed ttft p95 "
                           f"{1e3 * r['ttft_p95_packed_s']:.1f}ms worse "
                           f"than chunked "
                           f"{1e3 * r['ttft_p95_chunked_s']:.1f}ms")
        for r in timed:
            if not r["config"].startswith("packed_burst_b"):
                continue
            if r["prefill_dispatches"] != r["prefill_packed_steps"]:
                bad.append(f"{r['config']}: {r['prefill_dispatches']} "
                           f"dispatches over {r['prefill_packed_steps']} "
                           f"packed steps (expected one per step)")
            if r["prefill_chunks"] <= r["prefill_dispatches"]:
                bad.append(f"{r['config']}: burst never packed more than "
                           f"one admission per dispatch "
                           f"({r['prefill_chunks']} chunks over "
                           f"{r['prefill_dispatches']} dispatches)")
            if r["prefill_compiles"] > r["packed_buckets"]:
                bad.append(f"{r['config']}: {r['prefill_compiles']} "
                           f"prefill executables (expected <= "
                           f"{r['packed_buckets']}, one per packed "
                           f"bucket)")
        if bad:
            raise SystemExit("--check-packed FAILED:\n  " + "\n  ".join(bad))
        print("--check-packed OK: packed tokens identical to chunked, "
              "one dispatch per packed step, compiles bounded by the "
              "bucket ladder, ttft p95 no worse")

    if args.check_spec:
        # CI gate: speculative rows must exist with real rounds, and
        # speculative greedy decode must be TOKEN-IDENTICAL to plain
        # greedy decode on the standard workload (fresh engines, one
        # pass, fp — and int8 when it ran).  The 1.5x perf target is
        # deliberately NOT gated here: a shared CI box cannot promise
        # wall-clock ratios, only correctness.
        bad = []
        spec_rows = [r for r in timed if "_spec_long_b" in r["config"]]
        if not spec_rows:
            bad.append("no speculative config rows in the artifact")
        for r in spec_rows:
            if r.get("spec_rounds", 0) <= 0:
                bad.append(f"{r['config']}: no speculative rounds ran")
        quants = [q for q, _ in paged_variants]
        for quant in quants:
            outs = {}
            for spec in (False, True):
                peng = PagedEngine(cfg, params,
                                   max_batch=args.batches[-1],
                                   capacity=args.capacity,
                                   max_new_tokens=args.max_new,
                                   block_size=8, enable_partial=True,
                                   kv_quant=quant, prefill_mode="chunked",
                                   speculative=spec,
                                   gamma=min(args.gamma, 8))
                peng.precache(CACHED)
                sched = ContinuousBatchingScheduler(peng)
                for p in prompts:
                    sched.submit(p, max_new_tokens=args.max_new)
                done = sched.run()
                peng.check_invariants()
                outs[spec] = {r.prompt: list(r.result.token_ids)
                              for r in done if r.result is not None}
            for p in prompts:
                if outs[False].get(p) != outs[True].get(p):
                    bad.append(f"spec tokens diverge from plain greedy "
                               f"(quant={quant}): {p!r}")
        if bad:
            raise SystemExit("--check-spec FAILED:\n  " + "\n  ".join(bad))
        print("--check-spec OK: speculative greedy token-identical to "
              "plain greedy, rounds > 0")

    if args.check_semantic:
        # CI gate for the tentpole claim: the semantic workload shows
        # reuse where the prefix paths see none, fidelity is recorded
        # (and clears --fidelity-min), and the prefix paths are
        # byte-preserved under semantic mode
        bad = []
        on_rows = [r for r in rows
                   if r["config"].startswith("semantic_on_b")]
        off_rows = [r for r in rows
                    if r["config"].startswith("semantic_off_b")]
        if not on_rows:
            bad.append("no semantic_on rows in the artifact")
        for r in off_rows:
            if r["reuse_depth_max"] != 0:
                bad.append(f"{r['config']}: prefix paths reported reuse "
                           f"{r['reuse_depth_max']} on the prefix-free "
                           f"workload")
        for r in on_rows:
            if r["semantic_grafts"] <= 0:
                bad.append(f"{r['config']}: no grafts on the semantic "
                           f"workload")
            if r["reuse_depth_mean"] <= 0:
                bad.append(f"{r['config']}: zero reuse depth despite "
                           f"semantic mode")
        for r in rows:
            if r["config"].startswith("semantic_vs_exact") \
                    and r["fidelity"] < args.fidelity_min:
                bad.append(f"{r['config']}: fidelity {r['fidelity']:.3f}"
                           f" < {args.fidelity_min}")
        pres = [r for r in rows if r["config"] == "semantic_preservation"]
        if not pres:
            bad.append("missing semantic_preservation row")
        elif not pres[0]["preserved"]:
            bad.append(f"semantic mode changed {pres[0]['mismatches']} "
                       f"prefix-path request(s)")
        if bad:
            raise SystemExit("--check-semantic FAILED:\n  " +
                             "\n  ".join(bad))
        print("--check-semantic OK: grafted reuse where prefix paths "
              "report zero, prefix paths preserved")

    if args.check_mesh:
        # CI gate for the mesh-sharded server: correctness (token
        # identity vs the single-device paged engine), warm cross-replica
        # admission (block promotion, not recompute), and DP scaling
        # above 1.0x at D replicas.  The scaling bar is deliberately
        # just ">1": a shared CI box cannot promise linear scaling.
        bad = []
        mesh_rows = [r for r in timed if r["config"].startswith("mesh_")]
        if not mesh_rows:
            bad.append("no mesh config rows in the artifact")
        for r in mesh_rows:
            if not r.get("tokens_identical"):
                bad.append(f"{r['config']}: sharded tokens diverge from "
                           f"the single-device paged engine")
        scal = [r for r in rows
                if r["config"].startswith("mesh_dp_scaling")]
        if not scal:
            bad.append("missing mesh_dp_scaling summary row")
        for r in scal:
            if r["dp_scaling"] <= 1.0:
                bad.append(f"{r['config']}: DP scaling "
                           f"{r['dp_scaling']:.2f}x <= 1.0x")
            if r["warm_cross_replica_promotions"] <= 0:
                bad.append(f"{r['config']}: warm pass promoted nothing "
                           f"across replicas (recompute instead?)")
        if bad:
            raise SystemExit("--check-mesh FAILED:\n  " + "\n  ".join(bad))
        print("--check-mesh OK: sharded tokens identical, warm "
              "cross-replica promotions > 0, DP scaling > 1.0x")

    if args.check_preempt:
        # CI gate for pressure-safe serving: the undersized pool must
        # have actually preempted (otherwise the workload proved
        # nothing), every non-shed request must match the uncapped run
        # token-for-token, the pool invariants must hold afterwards,
        # and every terminal request must carry a typed outcome.
        bad = []
        over = [r for r in rows if r["config"].startswith("overload_b")]
        if not over:
            bad.append("no overload rows in the artifact")
        for r in over:
            if r["preemptions"] < 1:
                bad.append(f"{r['config']}: pool never preempted "
                           f"(num_blocks={r['num_blocks']} too big for "
                           f"the workload?)")
            if not r["tokens_identical"]:
                bad.append(f"{r['config']}: preempted-then-resumed "
                           f"tokens diverge from the uncapped run")
            if not r["invariants_ok"]:
                bad.append(f"{r['config']}: pool invariants violated "
                           f"after the overload run")
            if r["preempt_errors"]:
                bad.append(f"{r['config']}: {r['preempt_errors']} "
                           f"requests errored instead of resuming")
        shed_rows = [r for r in rows
                     if r["config"].startswith("overload_shed")]
        if not shed_rows:
            bad.append("missing overload_shed row")
        for r in shed_rows:
            if r["shed_queue_full"] < 1:
                bad.append(f"{r['config']}: bounded queue never shed")
            if not r["all_outcomes_typed"]:
                bad.append(f"{r['config']}: some terminal request has "
                           f"no typed outcome")
            if not r["accepted_identical"]:
                bad.append(f"{r['config']}: accepted requests' tokens "
                           f"changed when neighbours were shed")
        if bad:
            raise SystemExit("--check-preempt FAILED:\n  " +
                             "\n  ".join(bad))
        print("--check-preempt OK: preemptions fired, non-shed tokens "
              "identical to the uncapped run, invariants held, sheds "
              "typed")

    return rows


if __name__ == "__main__":
    main()
