"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Temporal mixing:  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t),  r/i gates sigmoid-linear in the
(causal-conv'd) input branch.  Prefill uses ``lax.associative_scan`` in f32;
decode is a single fused step.  The recycled "cache" for this family is the
(h, conv tail) state snapshot (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, split_tree

_C = 8.0  # Griffin's fixed scalar on softplus(Lambda)


def init_rglru(cfg: ModelConfig, key, dtype):
    hc = cfg.hybrid
    d, w = cfg.d_model, (hc.lru_width or cfg.d_model)
    ks = split_tree(key, 7)
    return {
        "w_x": dense_init(ks[0], (d, w), dtype),
        "w_gate": dense_init(ks[1], (d, w), dtype),
        "conv_k": dense_init(ks[2], (hc.conv1d_width, w), dtype, scale=0.1),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(ks[3], (w, w), dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[4], (w, w), dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Lambda init so a^c in (0.9, 0.999) — Griffin appendix
        "lam": jax.random.uniform(ks[5], (w,), jnp.float32, 2.0, 5.0),
        "w_out": dense_init(ks[6], (w, d), dtype),
    }


def init_rglru_state(cfg: ModelConfig, batch: int, dtype):
    hc = cfg.hybrid
    w = hc.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, hc.conv1d_width - 1, w), dtype),
    }


def _causal_conv(p, x, conv_state):
    """x: (B,S,w); conv_state: (B, cw-1, w) tail of previous tokens."""
    cw = p["conv_k"].shape[0]
    xp = jnp.concatenate([conv_state, x], axis=1)            # (B, S+cw-1, w)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_k"][i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else conv_state
    return out + p["conv_b"], new_state


def _gates(p, xb):
    """a_t (log-space f32) and gated input from conv'd branch xb (B,S,w)."""
    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) in log space for stability
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * (i * xf)


def rglru_prefill(cfg: ModelConfig, p, x, state, rt=None):
    """x: (B,S,d) -> (y, new_state).  Associative scan over time."""
    B, S, _ = x.shape
    xb = x @ p["w_x"]
    xb, conv_state = _causal_conv(p, xb, state["conv"])
    a, bx = _gates(p, xb)                                    # (B,S,w) f32

    if rt is not None and rt.use_pallas and x.shape[1] > 1:
        from repro.kernels import ops
        h, _ = ops.rglru_scan(a, bx, state["h"],
                              interpret=rt.pallas_interpret)
    else:
        # fold in carried state: h_t = (prod a_1..t) h_0 + scan(b)
        def binop(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        a_sc, b_sc = jax.lax.associative_scan(binop, (a, bx), axis=1)
        h = b_sc + a_sc * state["h"][:, None, :]
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32))
    y = (h * gate).astype(x.dtype) @ p["w_out"]
    new_state = {"h": h[:, -1], "conv": conv_state}
    return y, new_state


def rglru_decode(cfg: ModelConfig, p, x, state, rt=None):
    """x: (B,1,d) single step."""
    xb = x @ p["w_x"]
    xb, conv_state = _causal_conv(p, xb, state["conv"])
    a, bx = _gates(p, xb)
    h = a[:, 0] * state["h"] + bx[:, 0]                      # (B,w)
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32))
    y = (h[:, None] * gate).astype(x.dtype) @ p["w_out"]
    return y, {"h": h, "conv": conv_state}
