"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

  compute    = HLO_FLOPs / (chips * 197e12)
  memory     = HLO_bytes / (chips * 819e9)
  collective = collective_bytes / (chips * 50e9)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
parsed from the post-SPMD HLO text: we sum result-shape bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute.
Collectives inside a while body (the layer scan) execute once per trip, so
ops found in while-loop computations are multiplied by the scan trip count
— we recover per-computation trip counts from the loop bound constant when
printable, falling back to the arch's layer count (heuristic, documented).

MODEL_FLOPS uses the classic 6*N*D (training) / 2*N*D (inference) with
N = active params; the ratio MODEL_FLOPS/HLO_FLOPs flags remat or dispatch
waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

import numpy as np

from repro.config import ModelConfig, InputShape
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_COMP_RE = re.compile(r"^(?:%?)([\w\.\-]+)\s.*\{", re.M)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str, scan_trip: int = 1) -> Dict[str, float]:
    """Sum collective result bytes; while-body ops x scan_trip."""
    # Split into computations: lines like "%name (param: ...) -> ... {" or
    # "ENTRY %main ... {".  We approximate: track current computation name.
    totals: Dict[str, float] = {}
    cur_mult = 1
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.endswith("{") and ("(" in ls or ls.startswith("ENTRY")):
            name = ls.split()[0].lstrip("%")
            in_while = ("while" in name or "body" in name
                        or "scan" in name or "cond" in name)
            cur_mult = scan_trip if in_while else 1
        m = _COLL_RE.search(line)
        if m:
            dtype, dims, op = m.groups()
            b = _shape_bytes(dtype, dims) * cur_mult
            totals[op] = totals.get(op, 0.0) + b
            totals["total"] = totals.get("total", 0.0) + b
    return totals


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per-device FLOPs from cost_analysis
    hlo_bytes: float             # per-device bytes accessed
    collective_bytes: float      # per-device collective bytes (parsed)
    model_flops: float           # 6*N_act*D or 2*N_act*D, global
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    bytes_per_device: float = 0.0
    flops_consistent: bool = True

    def finalize(self):
        # cost_analysis on a partitioned module reports per-device numbers,
        # but XLA:CPU does not always fold while-loop trip counts into the
        # totals.  The analytic MODEL_FLOPS/chips is a hard lower bound on
        # per-device compute, so the compute term takes the max of the two;
        # ``flops_consistent`` records whether the HLO count was trusted.
        analytic = self.model_flops / max(self.chips, 1)
        self.flops_consistent = bool(self.hlo_flops >= 0.8 * analytic)
        self.compute_s = max(self.hlo_flops, analytic) / PEAK_FLOPS_BF16
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo = max(self.hlo_flops, analytic) * self.chips
        self.useful_ratio = (self.model_flops / total_hlo) if total_hlo else 0.0
        return self

    def as_dict(self):
        return asdict(self)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Global useful FLOPs for one step of this shape."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence (+ attention over the cache, which is
    # memory- not FLOP-dominated; 2*N*1 is the conventional figure)
    return 2.0 * n_act * shape.global_batch


def max_scan_trip(cfg: ModelConfig) -> int:
    from repro.models.transformer import segments
    return max(n for _, n in segments(cfg))
