"""Self-speculative decoding on the paged pool: the sparse-view drafter +
single-dispatch verifier is token-for-token identical to plain greedy
decode — the equivalence oracle — across exact/partial/miss admissions,
fp and int8 pools, chunked and staged prefill, and early EOS; rollback is
exact for ARBITRARY draft tokens (a hypothesis property substitutes
random drafts and the output still cannot drift, with allocator/table/
ring invariants holding after every step); the batched verify kernel
matches the jnp reference; per-token TPOT samples land on GenResult; and
``sample_batched`` short-circuits concrete all-greedy batches.

Plain greedy decode (``speculative=False``) is the reference baseline
throughout — the same diff-the-outputs discipline the chunked-prefill
suite uses against the staged path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import EOS
from repro.models import init_params
from repro.serving import ContinuousBatchingScheduler, PagedEngine
from repro.serving.sampling import greedy, sample_batched

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CACHED = [
    "the quick brown fox jumps over the lazy dog today",
    "what is the capital of france and why",
]
REQUESTS = [
    (CACHED[0] + " and tomorrow", "exact_prefix"),
    ("the quick brown fox jumps over a red fence", "partial_block"),
    ("zzz qqq completely unrelated 12345", "miss"),
    (CACHED[1] + " is it paris", "exact_prefix"),
]


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _paged(stack, *, spec, prefill_mode="chunked", quant=False, max_new=8,
           max_batch=3, capacity=128, precache=CACHED, **kw):
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=max_batch, capacity=capacity,
                      max_new_tokens=max_new, block_size=8,
                      enable_partial=True, kv_quant=quant,
                      prefill_mode=prefill_mode, speculative=spec, **kw)
    if precache:
        eng.precache(precache)
    return eng


def _run(eng, prompts, **submit_kw):
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, **submit_kw) for p in prompts]
    while sched.pending() or sched.in_flight:
        sched.step()
        eng.check_invariants()           # holds mid-flight, every step
    return reqs


# ---------------------------------------------------------------------------
# equivalence oracle: speculative greedy == plain greedy, everywhere
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quant", [False, True], ids=["fp", "int8"])
@pytest.mark.parametrize("mode", ["chunked", "staged"])
def test_spec_matches_plain(stack, quant, mode):
    """Acceptance: speculative decode emits the exact token sequence of
    non-speculative greedy decode for every admission mode, both pool
    dtypes, both prefill routes — drafts only buy steps, never change
    them."""
    plain = _paged(stack, spec=False, prefill_mode=mode, quant=quant)
    spec = _paged(stack, spec=True, prefill_mode=mode, quant=quant)
    preqs = _run(plain, [p for p, _ in REQUESTS])
    sreqs = _run(spec, [p for p, _ in REQUESTS])
    for (p, _), rp, rs in zip(REQUESTS, preqs, sreqs):
        assert rs.result.text == rp.result.text, p
        np.testing.assert_array_equal(rs.result.token_ids,
                                      rp.result.token_ids)
    assert spec.stats["spec_rounds"] > 0
    assert (spec.stats["spec_emitted_tokens"]
            > spec.stats["spec_rounds"]), "no draft was ever accepted"


@pytest.mark.parametrize("gamma", [1, 3, 6])
def test_spec_matches_plain_across_gamma(stack, gamma):
    """The identity holds for any draft depth, including gamma = 1
    (degenerate: one draft + bonus) and gamma spanning > 1 block."""
    plain = _paged(stack, spec=False)
    spec = _paged(stack, spec=True, gamma=gamma)
    preqs = _run(plain, [p for p, _ in REQUESTS[:2]])
    sreqs = _run(spec, [p for p, _ in REQUESTS[:2]])
    for rp, rs in zip(preqs, sreqs):
        np.testing.assert_array_equal(rs.result.token_ids,
                                      rp.result.token_ids)
    assert spec.stats["spec_rounds"] > 0


def test_spec_early_eos_equivalence(stack, monkeypatch):
    """A verifier target remapped to EOS mid-bundle truncates the burst
    exactly where plain decode would stop: finished rows release their
    blocks (reserved ones included) while survivors keep speculating."""
    import repro.serving.engine as engine_mod

    def eos_greedy(logits):
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(g % 5 == 1, jnp.int32(EOS), g)

    monkeypatch.setattr(engine_mod, "greedy", eos_greedy)
    plain = _paged(stack, spec=False, max_new=10)
    spec = _paged(stack, spec=True, max_new=10)
    preqs = _run(plain, [p for p, _ in REQUESTS])
    sreqs = _run(spec, [p for p, _ in REQUESTS])
    assert any(r.result.gen_tokens < 10 and r.result.token_ids[-1] == EOS
               for r in preqs), "remap produced no early EOS"
    for rp, rs in zip(preqs, sreqs):
        assert rs.result.gen_tokens == rp.result.gen_tokens
        np.testing.assert_array_equal(rs.result.token_ids,
                                      rp.result.token_ids)


def test_spec_sampled_rows_fall_back(stack):
    """Speculation is greedy-only: any sampled row in the pool parks the
    whole batch on the plain path (counted as fallback steps), and the
    engine still completes correctly."""
    eng = _paged(stack, spec=True)
    reqs = _run(eng, [p for p, _ in REQUESTS[:2]],
                temperature=0.8)
    assert all(r.result is not None for r in reqs)
    assert eng.stats["spec_rounds"] == 0
    assert eng.stats["spec_fallback_steps"] > 0


def test_spec_pallas_engine_equivalence(stack):
    """The Pallas verify-kernel path emits the same greedy tokens as the
    jnp reference path on a real speculative workload (fp and int8)."""
    from repro.runtime import Runtime
    for quant in (False, True):
        outs = []
        for rt in (Runtime(), Runtime(use_pallas=True)):
            eng = _paged(stack, spec=True, quant=quant, max_batch=2,
                         max_new=6, precache=CACHED[:1], rt=rt)
            reqs = _run(eng, [p for p, _ in REQUESTS[:2]])
            outs.append([r.result.text for r in reqs])
        assert outs[0] == outs[1], ("pallas vs jnp", quant)


# ---------------------------------------------------------------------------
# TPOT satellites: per-step decode timing + the all-greedy fast path
# ---------------------------------------------------------------------------
def test_step_times_recorded_plain_and_spec(stack):
    """Every decode-produced token carries a TPOT sample (the admission
    token is TTFT, not TPOT); a speculative burst records equal shares
    of its round, so totals stay per-token comparable."""
    from repro.core.metrics import tpot_summary
    for spec in (False, True):
        eng = _paged(stack, spec=spec)
        reqs = _run(eng, [p for p, _ in REQUESTS])
        results = [r.result for r in reqs]
        for r in results:
            assert len(r.step_times_s) == r.gen_tokens - 1
            assert all(t > 0.0 for t in r.step_times_s)
        s = tpot_summary(results)
        assert s["tpot_samples"] == sum(r.gen_tokens - 1 for r in results)
        assert 0.0 < s["tpot_p50_s"] <= s["tpot_p95_s"]
        assert s["ttft_mean_s"] > 0.0


def test_sample_batched_all_greedy_fast_path():
    """A concrete all-zero temperature vector short-circuits to argmax —
    rng-independent — while any hot row still samples; the Tracer guard
    keeps the check out of traced code paths."""
    rng = np.random.default_rng(5)
    logits = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    t0 = np.zeros((4,), np.float32)
    a = sample_batched(logits, jax.random.PRNGKey(0), temperature=t0)
    b = sample_batched(logits, jax.random.PRNGKey(9), temperature=t0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(greedy(logits)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # mixed batch: greedy rows stay pinned, hot rows draw
    tm = np.asarray([0.0, 1.0, 0.0, 1.0], np.float32)
    c = sample_batched(logits, jax.random.PRNGKey(0), temperature=tm)
    np.testing.assert_array_equal(np.asarray(c)[[0, 2]],
                                  np.asarray(greedy(logits))[[0, 2]])
    # the guard must not force a value under jit
    jitted = jax.jit(lambda lg, k, t: sample_batched(lg, k, temperature=t))
    d = jitted(logits, jax.random.PRNGKey(0), jnp.asarray(t0))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(greedy(logits)))


# ---------------------------------------------------------------------------
# kernel == reference
# ---------------------------------------------------------------------------
def test_verify_kernel_matches_reference_fp():
    from repro.kernels import ops
    from repro.models.attention import attend_paged_verify
    rng = np.random.default_rng(21)
    NB, bs, H, hkv, dh, NBt, B, Cv = 12, 8, 4, 2, 16, 6, 2, 8
    kp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    tbl = jnp.asarray([[3, 5, 7, 9, 0, 0], [1, 2, 4, 6, 8, 10]], jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, Cv, H, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, Cv, hkv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, Cv, hkv, dh)), jnp.float32)
    c0s = jnp.asarray([29, 40], jnp.int32)   # mid-block + block-aligned
    cache = {"k": kp, "v": vp, "block_tables": tbl}
    ref = attend_paged_verify(q, kc, vc, cache, c0s)
    out = ops.paged_verify_attention(q, kc, vc, kp, vp, tbl, c0s,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_verify_kernel_matches_reference_quant():
    from repro.kernels import ops
    from repro.models.attention import attend_paged_verify
    rng = np.random.default_rng(22)
    NB, bs, H, hkv, dh, NBt, B, Cv, R = 12, 8, 4, 2, 16, 6, 2, 8, 2
    kp = jnp.asarray(rng.integers(-127, 128, size=(NB, bs, hkv, dh)),
                     jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, size=(NB, bs, hkv, dh)),
                     jnp.int8)
    ks = jnp.asarray(rng.uniform(0.001, 0.02, size=(NB, bs, hkv)),
                     jnp.float32)
    vs = jnp.asarray(rng.uniform(0.001, 0.02, size=(NB, bs, hkv)),
                     jnp.float32)
    # live ring (draft-polluted; must NOT be read) vs pre-round snapshot
    kt_live = jnp.asarray(rng.normal(size=(B, R * bs, hkv, dh)), jnp.float32)
    vt_live = jnp.asarray(rng.normal(size=(B, R * bs, hkv, dh)), jnp.float32)
    kt_snap = jnp.asarray(rng.normal(size=(B, R * bs, hkv, dh)), jnp.float32)
    vt_snap = jnp.asarray(rng.normal(size=(B, R * bs, hkv, dh)), jnp.float32)
    tbl = jnp.asarray([[3, 5, 7, 9, 0, 0], [1, 2, 4, 6, 8, 10]], jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, Cv, H, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, Cv, hkv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, Cv, hkv, dh)), jnp.float32)
    cache = {"k": kp, "v": vp, "k_scale": ks, "v_scale": vs,
             "k_tail": kt_live, "v_tail": vt_live,
             "k_tail_snap": kt_snap, "v_tail_snap": vt_snap,
             "block_tables": tbl}
    for c0s in (jnp.asarray([29, 40], jnp.int32),
                jnp.asarray([13, 21], jnp.int32)):
        ref = attend_paged_verify(q, kc, vc, cache, c0s)
        out = ops.paged_verify_attention_quant(
            q, kc, vc, kp, vp, ks, vs, kt_snap, vt_snap, tbl, c0s,
            interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# rollback is exact for ARBITRARY drafts (hypothesis property, with a
# fixed-seed fallback where hypothesis is unavailable)
# ---------------------------------------------------------------------------
def _check_arbitrary_drafts_never_change_tokens(seed, gamma, quant):
    """Substitute RANDOM tokens for the drafter's proposals: the
    accept/reject machinery must still reproduce the plain greedy output
    exactly (random drafts mostly reject, exercising full and partial
    rollback), and allocator refcounts, free-list integrity, table-prefix
    contiguity, and int8 ring consistency hold after every single decode
    step (``_run`` calls ``check_invariants`` per scheduler step)."""
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    stack = (cfg, params)
    plain = _paged(stack, spec=False, quant=quant)
    preqs = _run(plain, [p for p, _ in REQUESTS])

    spec = _paged(stack, spec=True, quant=quant, gamma=gamma)
    draw = np.random.default_rng(seed)

    def noisy(draft):
        # half the rounds: pure noise (full rejection path); half:
        # corrupt a random suffix (partial acceptance + partial rollback)
        noise = draw.integers(0, cfg.vocab_size,
                              size=draft.shape).astype(draft.dtype)
        if draw.integers(0, 2):
            return noise
        cut = int(draw.integers(0, draft.shape[1]))
        out = draft.copy()
        out[:, cut:] = noise[:, cut:]
        return out

    spec._draft_tokens = noisy
    sreqs = _run(spec, [p for p, _ in REQUESTS])
    for rp, rs in zip(preqs, sreqs):
        np.testing.assert_array_equal(rs.result.token_ids,
                                      rp.result.token_ids)
    assert spec.stats["spec_rounds"] > 0


if HAVE_HYPOTHESIS:
    class TestSpecRollbackProperty:
        @given(seed=st.integers(0, 2**31 - 1), gamma=st.sampled_from([2, 4]),
               quant=st.booleans())
        @settings(max_examples=5, deadline=None)
        def test_arbitrary_drafts_never_change_tokens(self, seed, gamma,
                                                      quant):
            _check_arbitrary_drafts_never_change_tokens(seed, gamma, quant)
else:
    @pytest.mark.parametrize("seed,gamma,quant",
                             [(11, 4, False), (12, 2, False), (13, 4, True)])
    def test_spec_rollback_fixed_seeds(seed, gamma, quant):
        _check_arbitrary_drafts_never_change_tokens(seed, gamma, quant)
