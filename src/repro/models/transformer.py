"""Decoder stack: segment layout, layer bodies, and the layer scan.

The stack is a sequence of *segments*, each a ``lax.scan`` over ``n``
identically-shaped layers (stacked params, stacked caches) — the compiled
HLO is O(1) in depth, which keeps 80-layer x 512-device dry-run compiles
tractable (DESIGN.md §5).

Segment kinds:
  dense         attention (GQA or MLA) + dense MLP
  moe           attention + expert-parallel MoE FFN
  dense_first   attention + dense MLP with ``moe.dense_d_ff`` (DeepSeek/Kimi
                bottom layer)
  griffin_block rglru -> rglru -> local attention (Griffin 1:2), each + MLP
  griffin_tail  single rglru layer (+MLP) for depth remainders
  rwkv          RWKV6 time mix + channel mix
  encdec        self attention + cross attention + MLP (whisper decoder)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import (apply_mlp, apply_norm, init_mlp, init_norm,
                                 split_tree)
from repro.models.moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# segment layout
# ---------------------------------------------------------------------------
def segments(cfg: ModelConfig):
    """[(kind, n_layers_in_scan), ...] covering cfg.num_layers exactly."""
    L = cfg.num_layers
    if cfg.arch_type == "ssm":
        return [("rwkv", L)]
    if cfg.arch_type == "hybrid":
        blk = len(cfg.hybrid.pattern)
        full, tail = divmod(L, blk)
        out = [("griffin_block", full)]
        if tail:
            out.append(("griffin_tail", tail))
        return out
    if cfg.is_encdec:
        return [("encdec", L)]
    if cfg.moe is not None:
        nd = cfg.moe.first_dense_layers
        out = []
        if nd:
            out.append(("dense_first", nd))
        out.append(("moe", L - nd))
        return out
    return [("dense", L)]


# ---------------------------------------------------------------------------
# per-kind init
# ---------------------------------------------------------------------------
def _init_layer(cfg: ModelConfig, kind: str, key, dtype):
    ks = split_tree(key, 12)
    if kind == "rwkv":
        return {
            "ln1": init_norm(cfg),
            "tmix": rwkv_mod.init_rwkv_tmix(cfg, ks[0], dtype),
            "ln2": init_norm(cfg),
            "cmix": rwkv_mod.init_rwkv_cmix(cfg, ks[1], dtype),
        }
    if kind in ("griffin_block", "griffin_tail"):
        sub = cfg.hybrid.pattern if kind == "griffin_block" else ("rglru",)
        p = {}
        for j, s in enumerate(sub):
            mixer = (rglru_mod.init_rglru(cfg, ks[2 * j], dtype)
                     if s == "rglru"
                     else attn.init_attention(cfg, ks[2 * j], dtype))
            p[f"sub{j}"] = {
                "ln1": init_norm(cfg),
                "mixer": mixer,
                "ln2": init_norm(cfg),
                "mlp": init_mlp(cfg, ks[2 * j + 1], cfg.d_model, cfg.d_ff, dtype),
            }
        return p
    # attention trunk kinds
    a = (mla_mod.init_mla(cfg, ks[0], dtype) if cfg.mla is not None
         else attn.init_attention(cfg, ks[0], dtype))
    p = {"ln1": init_norm(cfg), "attn": a, "ln2": init_norm(cfg)}
    if kind == "moe":
        p["ffn"] = init_moe(cfg, ks[1], dtype)
    elif kind == "dense_first":
        p["ffn"] = init_mlp(cfg, ks[1], cfg.d_model, cfg.moe.dense_d_ff, dtype)
    else:
        p["ffn"] = init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff, dtype)
    if kind == "encdec":
        p["ln_x"] = init_norm(cfg)
        p["xattn"] = attn.init_cross_attention(cfg, ks[2], dtype)
    return p


def init_stack(cfg: ModelConfig, key, dtype):
    params = {}
    for i, (kind, n) in enumerate(segments(cfg)):
        keys = jax.random.split(jax.random.fold_in(key, i), n)
        params[f"seg{i}"] = jax.vmap(
            lambda k, _kind=kind: _init_layer(cfg, _kind, k, dtype))(keys)
    params["final_norm"] = init_norm(cfg)
    return params


# ---------------------------------------------------------------------------
# layer bodies — each returns (x, new_cache, aux)
# ---------------------------------------------------------------------------
def _mix_attn(cfg, p, x, cache, *, mode, pos, window, rt):
    """Attention sublayer dispatch (GQA vs MLA, prefill vs decode)."""
    if cfg.mla is not None:
        if mode == "decode":
            return mla_mod.mla_decode(cfg, p, x, cache, pos, window=window, rt=rt)
        return mla_mod.mla_prefill(cfg, p, x, start_pos=pos, cache=cache,
                                   window=window, rt=rt)
    if mode == "decode":
        return attn.attn_decode(cfg, p, x, cache, pos, window=window, rt=rt)
    if mode == "draft":
        qpos, vpos = pos
        return attn.attn_draft_view(cfg, p, x, cache, qpos, vpos, rt=rt)
    if mode == "verify":
        c0s, n_valid, act = pos
        return attn.attn_verify(cfg, p, x, cache, c0s, n_valid, act, rt=rt)
    if mode == "prefill_packed":
        rows, tables, c0s, w_floors, valids, q_offs, seg_ids = pos
        return attn.attn_prefill_packed(cfg, p, x, cache, rows, tables,
                                        c0s, w_floors, valids, q_offs,
                                        seg_ids, rt=rt)
    return attn.attn_prefill(cfg, p, x, start_pos=pos, cache=cache,
                             window=window, rt=rt)


def _layer_trunk(cfg, p, x, cache, *, mode, pos, window, rt, kind, enc_out):
    zero = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["ln1"], x)
    a, c_attn = _mix_attn(cfg, p["attn"], h, None if cache is None else
                          (cache["self"] if kind == "encdec" else cache),
                          mode=mode, pos=pos, window=window, rt=rt)
    x = x + a
    new_cache = None
    if kind == "encdec":
        hx = apply_norm(cfg, p["ln_x"], x)
        if mode == "decode":
            ck, cv = cache["cross_k"], cache["cross_v"]
        else:
            ck, cv = attn.cross_kv(cfg, p["xattn"], enc_out)
        B, S, _ = hx.shape
        hh = (hx @ p["xattn"]["wq"]
              + (p["xattn"]["bq"] if cfg.qkv_bias else 0)).reshape(
                  B, S, cfg.num_heads, cfg.head_dim)
        qpos = jnp.arange(S, dtype=jnp.int32)
        kpos = jnp.arange(ck.shape[1], dtype=jnp.int32)
        xo = attn.attend_direct(hh, ck, cv, qpos, kpos, causal=False)
        x = x + xo.reshape(B, S, -1) @ p["xattn"]["wo"]
        if cache is not None:
            new_cache = {"self": c_attn, "cross_k": ck, "cross_v": cv}
    elif cache is not None:
        new_cache = c_attn
    h2 = apply_norm(cfg, p["ln2"], x)
    if kind == "moe":
        f, aux = moe_ffn(cfg, p["ffn"], h2, rt)
    else:
        f, aux = apply_mlp(cfg, p["ffn"], h2, rt), zero
    return x + f, new_cache, aux


def _layer_rwkv(cfg, p, x, state, *, mode, pos, window, rt, kind, enc_out):
    zero = jnp.zeros((), jnp.float32)
    state = state if state is not None else rwkv_mod.init_rwkv_state(
        cfg, x.shape[0], x.dtype)
    h = apply_norm(cfg, p["ln1"], x)
    y, state = rwkv_mod.rwkv_tmix(cfg, p["tmix"], h, state, rt)
    x = x + y
    h2 = apply_norm(cfg, p["ln2"], x)
    y2, state = rwkv_mod.rwkv_cmix(cfg, p["cmix"], h2, state, rt)
    return x + y2, state, zero


def _layer_griffin(cfg, p, x, cache, *, mode, pos, window, rt, kind, enc_out):
    zero = jnp.zeros((), jnp.float32)
    sub = cfg.hybrid.pattern if kind == "griffin_block" else ("rglru",)
    new_cache = {} if cache is not None else None
    ri = 0
    for j, s in enumerate(sub):
        sp = p[f"sub{j}"]
        h = apply_norm(cfg, sp["ln1"], x)
        if s == "rglru":
            ri += 1
            key = f"r{ri}"
            st = (cache[key] if cache is not None
                  else rglru_mod.init_rglru_state(cfg, x.shape[0], x.dtype))
            if mode == "decode":
                y, st = rglru_mod.rglru_decode(cfg, sp["mixer"], h, st, rt)
            else:
                y, st = rglru_mod.rglru_prefill(cfg, sp["mixer"], h, st, rt)
            if new_cache is not None:
                new_cache[key] = st
        else:  # local attention
            w = cfg.hybrid.local_window
            y, c = _mix_attn(cfg, sp["mixer"], h,
                             None if cache is None else cache["attn"],
                             mode=mode, pos=pos, window=w, rt=rt)
            if new_cache is not None:
                new_cache["attn"] = c
        x = x + y
        h2 = apply_norm(cfg, sp["ln2"], x)
        x = x + apply_mlp(cfg, sp["mlp"], h2, rt)
    return x, new_cache, zero


_LAYER_FNS = {
    "dense": _layer_trunk,
    "moe": _layer_trunk,
    "dense_first": _layer_trunk,
    "encdec": _layer_trunk,
    "rwkv": _layer_rwkv,
    "griffin_block": _layer_griffin,
    "griffin_tail": _layer_griffin,
}


# ---------------------------------------------------------------------------
# the stack
# ---------------------------------------------------------------------------
def apply_stack(cfg: ModelConfig, params, x, *, mode: str, cache=None,
                pos=0, window: int = 0, rt=None, enc_out=None):
    """Run all segments.  Returns (x, new_cache_or_None, aux_loss)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    for i, (kind, n) in enumerate(segments(cfg)):
        p_seg = params[f"seg{i}"]
        layer_fn = functools.partial(
            _LAYER_FNS[kind], cfg, mode=mode, pos=pos, window=window,
            rt=rt, kind=kind, enc_out=enc_out)

        if cache is None:
            sp = (rt is not None and rt.seq_parallel and mode == "train"
                  and rt.mesh is not None and rt.model_axes
                  and x.shape[1] % rt.axis_size(rt.model_axes) == 0)

            def body(carry, p_l):
                h, aux = carry
                h, _, a = layer_fn(p_l, h, None)
                if sp:
                    # carry leaves each layer sequence-sharded over 'model':
                    # the checkpointed boundary activation (the only thing
                    # the backward scan stores per layer) is 1/tp the size.
                    h = rt.hint(h, rt.batch_axes or None, rt.model_axes, None)
                return (h, aux + a), None

            if rt is not None and rt.remat and mode == "train":
                body = jax.checkpoint(body)
            if sp:
                x = rt.hint(x, rt.batch_axes or None, rt.model_axes, None)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), p_seg)
            if sp:
                x = rt.hint(x, rt.batch_axes or None, None, None)
        else:
            def body(carry, xs):
                h, aux = carry
                p_l, c_l = xs
                h, c_new, a = layer_fn(p_l, h, c_l)
                return (h, aux + a), c_new

            (x, aux_total), c_seg = jax.lax.scan(
                body, (x, aux_total), (p_seg, cache[f"seg{i}"]))
            new_cache[f"seg{i}"] = c_seg
    x = apply_norm(cfg, params["final_norm"], x)
    return x, new_cache, aux_total
