"""qwen2.5-3b — dense decoder with GQA (kv=2) and QKV bias.

[hf:Qwen/Qwen2.5-0.5B model-card family] assigned dims: 36L, d_model=2048,
16 heads (GQA kv=2), d_ff=11008, vocab=151936.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    source="hf:Qwen/Qwen2.5-0.5B (family card, assigned 3B dims)",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    sliding_window=8192,
)
