"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

``input_specs`` builds weak-type-correct, shardable specs with zero device
allocation — the dry-run lowers against these.  The same shape logic is the
single source of truth for what each step function consumes:

  train_4k     train_step(params, opt_state, batch)
  prefill_32k  prefill_step(params, tokens, cache[, frontend])
  decode_*     serve_step(params, token, cache, pos)   # ONE new token

VLM note: ``seq_len`` budgets the whole sequence; 256 positions are patch
embeddings, the rest text.  Whisper note: decoder consumes seq_len text
tokens; the (stub) audio frontend contributes 1500 encoder frames.
Long-context note: archs with ``sliding_window`` switch their decode cache
to a ring of that size; SSM/hybrid archs carry O(1)/windowed state natively.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, InputShape
from repro.models.cache import cache_struct, init_paged_pool


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def decode_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Ring-buffer window for attention layers at this shape (0 = full)."""
    if shape.long_context and cfg.sliding_window:
        return cfg.sliding_window
    return 0


def text_len(cfg: ModelConfig, shape: InputShape) -> int:
    """Text-token count: VLM reserves patch positions out of seq_len."""
    if cfg.frontend is not None and not cfg.frontend.cross_attention:
        return shape.seq_len - cfg.frontend.num_tokens
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                kv_quant: bool = False) -> Dict[str, Any]:
    B = shape.global_batch
    dtype = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch = {"tokens": sds((B, text_len(cfg, shape)), jnp.int32)}
        if cfg.frontend is not None:
            f = cfg.frontend
            batch["frontend"] = sds((B, f.num_tokens, f.embed_dim), dtype)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((B, text_len(cfg, shape)), jnp.int32),
               "cache": cache_struct(cfg, B, shape.seq_len,
                                     window=decode_window(cfg, shape),
                                     dtype=dtype, kv_quant=kv_quant)}
        if cfg.frontend is not None:
            f = cfg.frontend
            out["frontend"] = sds((B, f.num_tokens, f.embed_dim), dtype)
        return out
    # decode: one token against a seq_len-deep cache
    return {
        "token": sds((B, 1), jnp.int32),
        "cache": cache_struct(cfg, B, shape.seq_len,
                              window=decode_window(cfg, shape), dtype=dtype,
                              kv_quant=kv_quant),
        "pos": sds((), jnp.int32),
    }


def paged_pool_struct(cfg: ModelConfig, num_blocks: int, block_size: int,
                      max_batch: int, max_blocks_per_seq: int, *,
                      dtype=None, kv_quant: bool = False,
                      fp_tail_blocks: int = 2):
    """ShapeDtypeStruct pytree of a paged block pool — ``cache_struct``'s
    serving analogue, zero device allocation.  Feed it to
    ``sharding.paged_pool_shardings`` to audit mesh placement (which
    leaves land head-sharded on 'model', which fall back to replication
    — the fallbacks that would silently multiply the KV memory budget)
    before committing pool memory."""
    fn = functools.partial(init_paged_pool, cfg, num_blocks, block_size,
                           max_batch, max_blocks_per_seq, dtype=dtype,
                           quant=kv_quant, fp_tail_blocks=fp_tail_blocks)
    return jax.eval_shape(fn)
