"""End-to-end behaviour of the paper's system (replaces the scaffold
placeholder): the full §4.4 evaluation loop — baseline pass, cache
construction, token-recycling pass — on a reduced DialoGPT, asserting the
paper's qualitative claims hold in this implementation:

  C1 (hit rate): every designed test prompt hits its cache prompt
  C2 (fidelity): greedy recycled output == greedy baseline output
  C3 (reuse):    reuse depth == full cached prompt length (r == k)
  C4 (fallback): non-overlapping prompts behave exactly like baseline
  C5 (speedup):  recycled latency <= baseline latency on average
                 (CPU magnitudes differ from the paper's T4; direction and
                 mechanism — skipped prefix FLOPs — are the claim)
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.metrics import RunMetrics, summarize_runs
from repro.core import HashEmbedder
from repro.data.pipeline import CACHE_PROMPTS, TEST_PROMPTS
from repro.models import init_params
from repro.serving import Engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new_tokens=8, block_size=16)
    eng.precache(CACHE_PROMPTS)
    prompts = TEST_PROMPTS[:4]
    for p in prompts:                      # compile both shapes
        eng.warmup(p, use_recycling=False)
        eng.warmup(p)
    base, rec = [], []
    for p in prompts:
        b = eng.generate(p, use_recycling=False)
        r = eng.generate(p)
        base.append((p, b))
        rec.append((p, r))
    return eng, base, rec


def test_c1_hit_rate(setup):
    _, _, rec = setup
    assert all(r.cache_hit for _, r in rec)


def test_c2_output_fidelity(setup):
    _, base, rec = setup
    for (_, b), (_, r) in zip(base, rec):
        assert b.text == r.text


def test_c3_full_prefix_reuse(setup):
    eng, _, rec = setup
    for p, r in rec:
        # the matching cache prompt is a strict prefix of the test prompt
        cached = [c for c in CACHE_PROMPTS if p.startswith(c)]
        assert cached and r.reuse_depth == len(eng.tok.encode(cached[0]))


def test_c4_miss_fallback(setup):
    eng, _, _ = setup
    r = eng.generate("totally unrelated gibberish xq zw 42")
    assert not r.cache_hit and r.reuse_depth == 0 and r.mode == "miss"


def test_c5_summary_table(setup):
    """The paper's Table-1 summary computes; recycled mean latency does not
    exceed baseline (timing noise tolerated via the aggregate)."""
    _, base, rec = setup
    brows = [RunMetrics(p, "baseline", b.latency_s, b.prompt_tokens,
                        b.gen_tokens, output_text=b.text)
             for p, b in base]
    rrows = [RunMetrics(p, "recycled", r.latency_s, r.prompt_tokens,
                        r.gen_tokens, r.reuse_depth, r.cache_hit,
                        r.prompt_similarity, r.mode, r.text)
             for p, r in rec]
    table = summarize_runs(brows, rrows, embedder=HashEmbedder())
    assert table["total_prompts"] == 4
    assert table["cache_hits"] == 4
    assert table["total_tokens_reused"] > 0
    assert table["avg_output_similarity"] > 0.99    # identical greedy text
    assert table["latency_recycled_avg_s"] <= table["latency_baseline_avg_s"] * 1.2
