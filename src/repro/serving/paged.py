"""Paged continuous-batching engine: block-table KV pool with ref-counted
prefix sharing (the device-resident recycling tier).

The dense slot pool (``BatchedEngine``) gives every in-flight request a
private ``[capacity]`` KV row and materializes every recycled hit from the
host store — two requests sharing a 500-token prefix hold two device
copies and both pay a host→device transfer.  This engine replaces the row
with a *block table*: all K/V lives in ONE shared pool of ``block_size``-
token blocks (``models.cache.init_paged_pool``), request r's cache is the
ordered list of pool block ids in its table, and a block may appear in any
number of tables at once.

Two cache tiers serve admissions:

  L1 — ``core.radix.BlockTrie``: token-block keys → live device blocks.
       A warm-prefix admission composes its table from the resident chain
       with **zero host round-trip**: shared full blocks are referenced in
       place (refcount++), and only the divergent boundary block is
       materialized fresh (copy-on-write through the prefill staging
       buffer — a shared block is never written in place).
  L2 — the existing ``Recycler``/``HostKVStore`` path: on an L1 miss the
       host entry is promoted back to device in block-granular chunks and
       indexed in L1 for the next admission.

Static shapes still rule: the pool is one fixed ``[num_blocks, bs, ...]``
allocation per layer, tables are fixed-width (sentinel-0 padded), and ONE
compiled decode executable (`decode_step` over the paged cache) advances
every in-flight request per step regardless of occupancy or sharing.

Correctness contract (tests/test_paged_pool.py): paged decode is
token-for-token identical to the dense slot pool — and therefore to serial
``generate`` — for every admission mode; blocks shared between requests
have refcount > 1 and are never written by either sharer.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import BlockAllocator, BlockPoolExhausted, BlockTrie
from repro.core.blockpool import SENTINEL
from repro.core.kvstore import to_host, tree_bytes
from repro.core.recycler import grow_capacity
from repro.data.tokenizer import EOS
from repro.models import decode_step, init_paged_pool, paged_block_bytes
from repro.serving import engine as engine_mod
from repro.serving.engine import Engine, GenResult, _Slot
from repro.serving.sampling import sample_batched, sample_logits


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# jitted pool <-> staging composition (device-to-device; no host traffic)
# ---------------------------------------------------------------------------
def _stage_from_pool(pool, chain_ids, depth: int, cap: int):
    """Compose a dense single-request staging cache holding positions
    [0, depth) gathered from pool blocks ``chain_ids`` — the layout the
    existing (compiled) prefill consumes.  Pure device gather."""
    stage = {}
    for seg, c in pool.items():
        sub = {}
        for name in ("k", "v"):
            a = c[name][:, chain_ids]                  # (L, ncb, bs, H, D)
            L = a.shape[0]
            a = a.reshape(L, -1, *a.shape[3:])[:, :depth]
            a = jnp.pad(a, ((0, 0), (0, cap - depth), (0, 0), (0, 0)))
            sub[name] = a[:, None]                     # (L, 1, cap, H, D)
        pos = jnp.arange(cap, dtype=jnp.int32)
        sp = jnp.where(pos < depth, pos, -1)
        sub["slot_pos"] = jnp.broadcast_to(sp, (c["k"].shape[0], cap))
        stage[seg] = sub
    return stage


def _scatter_to_pool(pool, stage, dst_ids, start: int, n: int, bs: int):
    """Write staging positions [start, start + n) into pool blocks
    ``dst_ids`` (dst_ids[i] holds positions [start + i*bs, ...)).  The
    copy-on-write boundary block is materialized here: staging already
    holds the donor prefix for [start, depth), so the divergent block's
    private copy costs no extra pass."""
    ps = start + jnp.arange(n, dtype=jnp.int32)
    blk = dst_ids[(ps - start) // bs]
    off = ps % bs
    out = {}
    for seg, c in pool.items():
        out[seg] = {
            "k": c["k"].at[:, blk, off].set(stage[seg]["k"][:, 0, start:start + n]),
            "v": c["v"].at[:, blk, off].set(stage[seg]["v"][:, 0, start:start + n]),
            "block_tables": c["block_tables"],
        }
    return out


def _set_row(pool, tokens, pos, row, table_row, tok0, m):
    out = {}
    for seg, c in pool.items():
        out[seg] = {**c,
                    "block_tables": c["block_tables"].at[:, row].set(table_row)}
    return out, tokens.at[row].set(tok0), pos.at[row].set(m)


def _set_table_entry(pool, row, idx, blk):
    out = {}
    for seg, c in pool.items():
        out[seg] = {**c,
                    "block_tables": c["block_tables"].at[:, row, idx].set(blk)}
    return out


def _clear_row(pool, row):
    out = {}
    for seg, c in pool.items():
        out[seg] = {**c, "block_tables":
                    c["block_tables"].at[:, row].set(SENTINEL)}
    return out


class PagedEngine(Engine):
    """Continuous batching over a paged, prefix-shared device KV pool.

    Drop-in replacement for ``BatchedEngine`` behind the scheduler surface
    (``free_slots`` / ``admit_slot`` / ``decode_batch``); the dense slot
    pool stays as the equivalence reference.  Trunk attention only, no
    sliding window (paged blocks have no ring semantics), no kv_quant yet.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 capacity: int = 256, num_blocks: Optional[int] = None,
                 **kw):
        super().__init__(cfg, params, **kw)
        if self.window:
            raise NotImplementedError("paged pool does not support "
                                      "sliding-window rings")
        if self.kv_quant:
            raise NotImplementedError("paged pool stores dense-dtype K/V")
        bs = self.block                      # page size == radix block size
        if capacity % bs:
            capacity = _ceil_div(capacity, bs) * bs
        self.max_batch = max_batch
        self.capacity = capacity
        self.nbt = capacity // bs            # fixed table width
        if num_blocks is None:
            # worst case every row full + one row's worth of retained
            # prefixes in the L1 trie + the sentinel
            num_blocks = max_batch * self.nbt + self.nbt + 1
        self.allocator = BlockAllocator(num_blocks, bs)
        self.trie = BlockTrie(bs)
        self.pool = init_paged_pool(cfg, num_blocks, bs, max_batch,
                                    self.nbt, dtype=jnp.dtype(cfg.dtype))
        self._tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self._pos = jnp.zeros((max_batch,), jnp.int32)
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._tables = np.zeros((max_batch, self.nbt), np.int32)  # host mirror
        self._row_blocks: List[List[int]] = [[] for _ in range(max_batch)]
        self._committed: List[int] = [0] * max_batch  # future allocs owed
        self._temp = np.zeros((max_batch,), np.float32)
        self._topk = np.zeros((max_batch,), np.int32)
        self._step_rng = self._sample_key

        self._stage_fn = jax.jit(_stage_from_pool, static_argnums=(2, 3))
        self._scatter_fn = jax.jit(_scatter_to_pool,
                                   static_argnums=(3, 4, 5),
                                   donate_argnums=(0,))
        self._setrow_fn = jax.jit(_set_row, donate_argnums=(0, 1, 2))
        self._setent_fn = jax.jit(_set_table_entry, donate_argnums=(0,))
        self._clear_fn = jax.jit(_clear_row, donate_argnums=(0,))
        self._pstep_fn = jax.jit(self._paged_step, donate_argnums=(1, 2, 3))
        self._pstep_sampled_fn = jax.jit(self._paged_step_sampled,
                                         donate_argnums=(1, 2, 3),
                                         static_argnums=(7,))
        self.stats.update({
            "batched_decode_steps": 0, "admissions": 0, "sampled_steps": 0,
            "resident_hits": 0, "host_promotions": 0, "cow_copies": 0,
            "h2d_copies": 0, "h2d_bytes": 0, "trie_evictions": 0,
        })

    # ------------------------------------------------------------------
    def _paged_step(self, params, tokens, pool, pos):
        # greedy via the engine module so tests can substitute it (early
        # EOS) in the serial, dense-pool and paged paths at once
        logits, pool = decode_step(self.cfg, params, tokens, pool, pos,
                                   window=0, rt=self.rt)
        nxt = engine_mod.greedy(logits)
        return nxt, nxt[:, None], pool, pos + 1

    def _paged_step_sampled(self, params, tokens, pool, pos, temp, topk,
                            rng, topk_cap):
        logits, pool = decode_step(self.cfg, params, tokens, pool, pos,
                                   window=0, rt=self.rt)
        nxt = sample_batched(logits, rng, temperature=temp, top_k=topk,
                             top_k_cap=topk_cap)
        return nxt, nxt[:, None], pool, pos + 1

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    # ------------------------------------------------------------------
    # block bookkeeping
    # ------------------------------------------------------------------
    def _evictable(self, exclude=()) -> int:
        ex = set(exclude)
        return self.trie.evictable(
            lambda b: b not in ex and self.allocator.refcount(b) == 1)

    def _alloc_block(self, protect=()) -> int:
        """A fresh private block, evicting cold L1 prefixes if needed.
        ``protect`` names blocks that must survive eviction (e.g. the
        boundary block an in-progress admission is about to gather)."""
        try:
            return self.allocator.alloc()
        except BlockPoolExhausted:
            ex = set(protect)
            dropped = self.trie.evict(
                1, lambda b: b not in ex and self.allocator.refcount(b) == 1)
            for b in dropped:
                self.allocator.unref(b)
                self.stats["trie_evictions"] += 1
            if not dropped:
                raise
            return self.allocator.alloc()

    def device_kv_bytes_in_use(self) -> int:
        """Bytes of pool K/V actually referenced (live blocks, counted
        once however many tables share them)."""
        return self.allocator.num_live() * paged_block_bytes(
            self.cfg, self.block, dtype=jnp.dtype(self.cfg.dtype))

    # ------------------------------------------------------------------
    def admit_slot(self, slot: int, prompt: str, *,
                   max_new_tokens: Optional[int] = None,
                   use_recycling: bool = True, admit: bool = False,
                   stop_at_eos: bool = True, temperature: float = 0.0,
                   top_k: int = 0) -> Optional[GenResult]:
        """Admit ``prompt`` into pool row ``slot``: L1 block-table reuse
        when the prefix is device-resident, else L2 host promotion, else a
        cold prefill — all through one staged dense prefill whose result
        is scattered into (copy-on-write) private blocks."""
        if self._slots[slot] is not None:
            raise ValueError(f"slot {slot} is occupied")
        max_new = max_new_tokens or self.max_new
        t0 = time.perf_counter()
        ids = self.tok.encode(prompt)
        m = len(ids)
        if m + max_new > self.capacity:
            raise ValueError(f"request needs {m + max_new} positions; pool "
                             f"capacity is {self.capacity}")
        bs = self.block
        nb_prompt = _ceil_div(m, bs)
        nb_total = _ceil_div(m + max_new, bs)

        depth, hit, mode, sim = 0, False, "baseline", 0.0
        chain: List[Tuple[int, int]] = []
        res = None
        if use_recycling:
            d1, chain = self.trie.lookup(ids)
            d1 = min(d1, m - 1)
            d2 = 0
            if d1 < m - 1:
                # L1 can still be beaten — consult the host (L2) tier.
                # At maximal resident depth the lookup is skipped: no host
                # hit (d2 <= m-1) could win, and Recycler.lookup would
                # materialize the whole host cache just to be discarded.
                res = self.recycler.lookup(prompt, ids)
                d2 = res.reuse_depth if res.hit else 0
                sim = res.similarity
            # prefer the resident tier unless the host hit is deeper by
            # MORE than one block: re-prefilling a partial-block tail is
            # far cheaper than a host→device copy of the whole prefix
            if d1 > 0 and d1 >= d2 - bs:
                depth, hit, mode = d1, True, "resident_block"
                # a resident hit is served by the trie, not retrieval —
                # there is no honest similarity to report
                sim = float("nan")
                self.stats["resident_hits"] += 1
            elif d2 > 0:
                depth, hit, mode = d2, True, res.mode
                self.stats["host_promotions"] += 1
            else:
                mode = "miss"
        if mode != "resident_block":
            chain = []

        nb_shared = depth // bs if chain else 0
        start = nb_shared * bs               # first position written fresh
        shared = [b for b, _ in chain[:nb_shared]]
        gather = [b for b, _ in chain[:_ceil_div(depth, bs)]] if chain else []

        # admission guarantee: every block this request will ever need —
        # now or at a later decode boundary — must be obtainable without
        # starving the futures other in-flight rows were promised
        need_now = nb_prompt - nb_shared
        need_later = nb_total - nb_prompt
        owed = sum(self._committed)
        avail = self.allocator.num_free() + self._evictable(exclude=gather)
        if avail < need_now + need_later + owed:
            raise ValueError(
                f"paged pool exhausted: request needs {need_now + need_later}"
                f" blocks, {avail - owed} obtainable "
                f"(free={self.allocator.num_free()}, "
                f"in-flight reservations={owed})")

        for b in shared:                      # share the resident prefix
            self.allocator.ref(b)
        fresh = [self._alloc_block(protect=gather) for _ in range(need_now)]
        if chain and depth % bs:
            # divergent boundary block: its private copy is written from
            # staging below instead of mutating the shared original
            self.stats["cow_copies"] += 1

        # ---- staged dense prefill (the compiled serial path) ----------
        cap = self._capacity(m)
        if mode == "resident_block":
            stage = self._stage_fn(self.pool, jnp.asarray(gather, jnp.int32),
                                   depth, cap)
        elif hit:
            self.stats["h2d_copies"] += 1
            self.stats["h2d_bytes"] += tree_bytes(res.cache)
            stage = jax.tree.map(jnp.asarray, grow_capacity(res.cache, cap))
        else:
            stage = self._make_cache(cap)
        suffix = jnp.asarray(ids[depth:])[None]
        logits, stage = self._prefill_fn(self.params, suffix, stage, depth)

        # ---- scatter the fresh region [start, m) into private blocks --
        if fresh:
            self.pool = self._scatter_fn(
                self.pool, stage, jnp.asarray(fresh, jnp.int32),
                start, m - start, bs)

        # ---- index the now-resident prompt prefix in L1 ---------------
        table_blocks = shared + fresh        # covers [0, m)
        for b in self.trie.register(ids, m, table_blocks):
            self.allocator.ref(b)            # the trie's own reference

        if temperature > 0.0:
            self._step_rng, sub = jax.random.split(self._step_rng)
            tok0 = sample_logits(logits, sub, temperature=temperature,
                                 top_k=top_k)
        else:
            tok0 = engine_mod.greedy(logits)

        self.stats["requests"] += 1
        self.stats["hits"] += int(hit)
        self.stats["tokens_reused"] += depth
        self.stats["tokens_prefilled"] += m - depth
        self.stats["admissions"] += 1

        st = _Slot(prompt, ids, m, max_new, use_recycling, admit,
                   stop_at_eos, depth, hit, mode, sim,
                   emitted=[int(tok0[0])], t0=t0,
                   temperature=temperature, top_k=top_k)
        if (st.stop_at_eos and st.emitted[0] == EOS) or max_new == 1:
            # finished at its first token: the prompt prefix stays warm in
            # L1, but the row is never occupied
            result = self._result(st, stage=stage, cap=cap)
            for b in table_blocks:
                self.allocator.unref(b)
            return result

        row = np.full((self.nbt,), SENTINEL, np.int32)
        row[:len(table_blocks)] = table_blocks
        self._tables[slot] = row
        self._row_blocks[slot] = list(table_blocks)
        self._committed[slot] = need_later
        self._temp[slot] = temperature
        self._topk[slot] = top_k
        self.pool, self._tokens, self._pos = self._setrow_fn(
            self.pool, self._tokens, self._pos, slot, jnp.asarray(row),
            tok0, jnp.int32(m))
        self._slots[slot] = st
        return None

    # ------------------------------------------------------------------
    def decode_batch(self) -> List[Tuple[int, GenResult]]:
        """One masked decode step over the paged pool (single dispatch).
        Before stepping, rows whose next write position crosses into an
        unallocated table entry get a fresh private block (allocation is
        on demand — device bytes track actual lengths, not capacity)."""
        active = self.active_slots()
        if not active:
            return []
        for i in active:
            st = self._slots[i]
            p = st.m + len(st.emitted) - 1   # position this step writes
            idx = p // self.block
            if self._tables[i, idx] == SENTINEL:
                b = self._alloc_block()
                self._tables[i, idx] = b
                self._row_blocks[i].append(b)
                self._committed[i] -= 1
                self.pool = self._setent_fn(self.pool, i, idx, jnp.int32(b))

        if np.any(self._temp > 0.0):
            self._step_rng, sub = jax.random.split(self._step_rng)
            self.stats["sampled_steps"] += 1
            nxt, self._tokens, self.pool, self._pos = self._pstep_sampled_fn(
                self.params, self._tokens, self.pool, self._pos,
                jnp.asarray(self._temp), jnp.asarray(self._topk), sub,
                max(int(self._topk.max()), 1))
        else:
            nxt, self._tokens, self.pool, self._pos = self._pstep_fn(
                self.params, self._tokens, self.pool, self._pos)
        toks = np.asarray(nxt)
        self.stats["batched_decode_steps"] += 1
        done: List[Tuple[int, GenResult]] = []
        for i in active:
            st = self._slots[i]
            st.emitted.append(int(toks[i]))
            if ((st.stop_at_eos and st.emitted[-1] == EOS)
                    or len(st.emitted) >= st.max_new):
                done.append((i, self._result(st, row=i)))
                self._release_row(i)
        return done

    # ------------------------------------------------------------------
    def _release_row(self, row: int) -> None:
        """Free the row: drop its table references (prefix blocks indexed
        in L1 survive as the device cache tier; generation-only blocks
        fall to refcount 0 and return to the free list)."""
        for b in self._row_blocks[row]:
            self.allocator.unref(b)
        self._row_blocks[row] = []
        self._committed[row] = 0
        self._tables[row] = SENTINEL
        self._temp[row] = 0.0
        self._topk[row] = 0
        self.pool = self._clear_fn(self.pool, row)
        self._slots[row] = None

    # ------------------------------------------------------------------
    def _result(self, st: _Slot, *, row: Optional[int] = None, stage=None,
                cap: Optional[int] = None) -> GenResult:
        if st.admit:
            cap = cap or self._capacity(st.m + st.max_new)
            if stage is None:
                # harvest from the pool: gather the row's prompt blocks
                # back into the dense host-store layout, valid [0, m)
                ids = [b for b in self._tables[row]
                       if b != SENTINEL][:_ceil_div(st.m, self.block)]
                stage = self._stage_fn(self.pool,
                                       jnp.asarray(ids, jnp.int32),
                                       st.m, cap)
            # else instant finish: the staging cache already holds exactly
            # [0, m) — generated positions were never written into it
            self.recycler.admit(st.prompt, st.ids, to_host(stage), st.m, cap)
        all_ids = np.concatenate([st.ids, np.asarray(st.emitted, np.int32)])
        return GenResult(
            text=self.tok.decode(st.emitted),
            token_ids=all_ids,
            latency_s=time.perf_counter() - st.t0,
            prompt_tokens=st.m,
            gen_tokens=len(st.emitted),
            reuse_depth=st.depth,
            cache_hit=st.hit,
            mode=st.mode if st.use_recycling else "baseline",
            prompt_similarity=st.sim,
        )

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Paged-pool global invariants (fuzzed in tests):
          * allocator free/live accounting is consistent
          * every block's refcount equals (#tables naming it) + (1 if the
            L1 trie indexes it) — so a block in two tables is provably
            shared, and no freed block is reachable
          * table entries beyond a row's blocks are sentinel"""
        self.allocator.check()
        expected: Dict[int, int] = {}
        for i in range(self.max_batch):
            for b in self._row_blocks[i]:
                expected[b] = expected.get(b, 0) + 1
            named = [b for b in self._tables[i] if b != SENTINEL]
            assert named == self._row_blocks[i], \
                (i, named, self._row_blocks[i])
        for b in self.trie.blocks():
            expected[b] = expected.get(b, 0) + 1
        for b in range(1, self.allocator.num_blocks):
            assert self.allocator.refcount(b) == expected.get(b, 0), \
                (b, self.allocator.refcount(b), expected.get(b, 0))
