# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  python -m benchmarks.run               # all benches, reduced model
  python -m benchmarks.run --full        # paper's real 345M DialoGPT
  python -m benchmarks.run --only table1

Sections (one per paper table/figure + framework extras):
  table1            paper §5.1 summary table
  latency           paper §5.2 latency comparison
  speedup_vs_depth  paper §5.5 S ~ alpha*k/m figure (+ fitted alpha)
  recycle_modes     beyond-paper: exact-only vs block-radix partial reuse
  kernels           Pallas kernel micro-bench (interpret mode)
  roofline          §Roofline table from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="use the real 345M DialoGPT config (slow on CPU)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_repro, recycling_modes
    from benchmarks import roofline_report

    sections = {}

    def sec(name, fn):
        if args.only and args.only != name:
            return
        sections[name] = fn

    table_rows_cache = {}

    def run_table1():
        rows, raw = paper_repro.table1(args.full)
        table_rows_cache["raw"] = raw
        return rows

    sec("table1", run_table1)
    sec("latency", lambda: paper_repro.latency_fig(
        table_rows_cache.get("raw"), args.full))
    sec("speedup_vs_depth", lambda: paper_repro.speedup_vs_depth(args.full))
    sec("recycle_modes", recycling_modes.exact_vs_partial)
    sec("kernels", kernel_bench.kernels)
    sec("roofline", roofline_report.roofline_rows)

    print("name,us_per_call,derived")
    failed = 0
    for name, fn in sections.items():
        try:
            for row in fn():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
        except Exception:
            failed += 1
            print(f"{name}.ERROR,0,{traceback.format_exc(limit=1).splitlines()[-1]}")
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
