"""Logical -> mesh sharding rules for params, optimizer state, caches, and
step inputs (DESIGN.md §5).

Conventions:
  * ``dp``   — data-parallel axes: ('data',) or ('pod','data')
  * ``tp``   — tensor/expert axis: 'model'
  * params follow Megatron column/row splits keyed by leaf NAME (names are
    globally unique per role — e.g. MoE expert weights are ``we_*`` so the
    expert dim rule can't collide with the dense MLP rule).
  * stacked-layer leading dims are absorbed by RIGHT-ALIGNING every rule.
  * any rule whose dim isn't divisible by the axis size falls back to
    replication for that leaf (logged by the dry-run).

Cache rules (decode):
  * kv_heads % tp == 0      -> heads on 'model'
  * big caches (>= 16k slots) -> capacity (sequence) on 'model'
    (sequence-parallel decode attention: q is all-gathered — KBs — and the
    partial softmax reduces across 'model'; the GBs-scale cache never moves)
  * small ring/window caches & recurrent states -> replicated over 'model'
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, InputShape
from repro.runtime import Runtime

TP = "model"
BIG_CACHE = 16384

# ---------------------------------------------------------------------------
# replication-fallback log: every rule that WANTED to shard a leaf but fell
# back to replication records (leaf, shape, reason, chosen spec) exactly once
# so the dry-run (and operators) can see which leaves silently replicate.
# ---------------------------------------------------------------------------
_FALLBACKS: dict = {}


def _log_fallback(name, shape, spec, reason: str) -> None:
    key = (name, tuple(shape), reason)
    if key in _FALLBACKS:
        return
    rec = {"leaf": name, "shape": tuple(int(s) for s in shape),
           "spec": str(spec), "reason": reason}
    _FALLBACKS[key] = rec
    import logging
    logging.getLogger(__name__).warning(
        "sharding fallback: leaf %r shape %s -> %s (%s)",
        name, rec["shape"], rec["spec"], reason)


def fallback_log():
    """Records of every rule that fell back to replication (logged once per
    (leaf, shape, reason)); surfaced by the dry-run artifact."""
    return list(_FALLBACKS.values())


def clear_fallback_log() -> None:
    _FALLBACKS.clear()

# leaf name -> axis position (from the right) that gets the 'model' axis
_PARAM_RULES = {
    # embeddings
    "wte": 2, "lm_head": 1,
    # attention (column: out dim; row: in dim)
    "wq": 1, "wk": 1, "wv": 1, "bq": 1, "bk": 1, "bv": 1, "wo": 2,
    # MLA up/down projections
    "w_uq": 1, "w_uk": 1, "w_uv": 1,
    # dense MLP
    "w_gate": 1, "w_up": 1, "b_up": 1, "w_down": 2,
    # MoE experts: expert dim
    "we_gate": 3, "we_up": 3, "we_down": 3,
    # RG-LRU
    "w_x": 1, "w_a": 1, "w_i": 1, "b_a": 1, "b_i": 1, "conv_k": 1,
    "conv_b": 1, "lam": 1, "w_out": 2,
    # RWKV-6
    "w_r": 1, "w_k": 1, "w_v": 1, "w_g": 1, "w_o": 2, "u": 2,
}


def _right_aligned(ndim: int, axis_from_right: int, name: str) -> P:
    spec = [None] * ndim
    idx = ndim - axis_from_right
    if idx < 0:
        return P()
    spec[idx] = TP
    return P(*spec)


def param_spec(path, leaf) -> P:
    """PartitionSpec for one parameter leaf (path = tree_util key path)."""
    name = None
    for k in reversed(path):
        if hasattr(k, "key"):
            name = k.key
            break
    if name in _PARAM_RULES:
        ndim = len(leaf.shape)
        rule = _PARAM_RULES[name]
        spec = _right_aligned(ndim, rule, name)
        # divisibility guard
        idx = ndim - rule
        if 0 <= idx < ndim:
            return spec, idx
    return P(), None


def param_shardings(params_struct, mesh: Mesh, *,
                    zero1_axes: Tuple[str, ...] = (),
                    expert_fsdp_axes: Tuple[str, ...] = ()):
    """NamedSharding pytree for params.

    ``zero1_axes``: large leaves additionally shard one replicated dim over
    the data axes (ZeRO-1 for optimizer moments).
    ``expert_fsdp_axes``: MoE expert weights (``we_*``) shard their hidden
    dim over the data axes too (expert-FSDP — a 1T-param MoE does not fit
    sharded over 'model' alone); ``moe_ffn`` re-gathers per layer inside the
    scan (DESIGN.md §5).
    """
    tp_size = mesh.shape[TP]
    dp_size = 1
    for a in zero1_axes:
        dp_size *= mesh.shape[a]
    fsdp_size = 1
    for a in expert_fsdp_axes:
        fsdp_size *= mesh.shape[a]

    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        spec, idx = param_spec(path, leaf)
        spec_list = list(spec) + [None] * (len(leaf.shape) - len(spec))
        is_expert = bool(name and name.startswith("we_"))
        if idx is not None and leaf.shape[idx] % tp_size != 0:
            spec_list = [None] * len(leaf.shape)          # fallback: replicate
            _log_fallback(name, leaf.shape, P(),
                          f"dim {idx} = {leaf.shape[idx]} not divisible by "
                          f"tp={tp_size}")
        elif (idx is not None and not is_expert
              and leaf.shape[idx] < 128 * tp_size):
            # tiny dims (e.g. whisper's 512-wide attention) — sharding buys
            # nothing and forces reshape remats; replicate.
            spec_list = [None] * len(leaf.shape)
        nd = len(leaf.shape)
        if expert_fsdp_axes and is_expert:
            # shard the per-expert FFN dim f over the data axes: we_gate/
            # we_up (E,d,f) on -1, we_down (E,f,d) on -2.  Consistent f
            # sharding lets the decode path compute on resident f-chunks
            # (partial-sum psum) with NO weight gather (§Perf kimi-decode).
            fdim = nd - 1 if name in ("we_gate", "we_up") else nd - 2
            if leaf.shape[fdim] % fsdp_size == 0:
                spec_list[fdim] = expert_fsdp_axes
        if zero1_axes and leaf.size >= 1 << 20:
            used = {a for s in spec_list if s
                    for a in (s if isinstance(s, tuple) else (s,))}
            if not (set(zero1_axes) & used):
                for d, n in enumerate(leaf.shape):
                    if spec_list[d] is None and n % dp_size == 0 and n > 1:
                        spec_list[d] = zero1_axes
                        break
        return NamedSharding(mesh, P(*spec_list))

    return jax.tree_util.tree_map_with_path(one, params_struct)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def cache_shardings(cache_struct, cfg: ModelConfig, mesh: Mesh,
                    dp: Tuple[str, ...], batch: int):
    """NamedSharding pytree for an inference cache."""
    tp_size = mesh.shape[TP]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bspec = dp if (dp and batch % dp_size == 0 and batch >= dp_size) else None

    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        if name in ("k", "v", "ckv", "krope", "cross_k", "cross_v",
                    "k_scale", "v_scale",
                    "wkv", "h", "conv", "shift_t", "shift_c"):
            # batch axis position: stacked layer dim(s) first, batch next.
            # k/v: (..., B, C, Hkv, Dh); ckv/krope: (..., B, C, r);
            # states: (..., B, ...)
            boff = {"k": 4, "v": 4, "ckv": 3, "krope": 3, "cross_k": 4,
                    "cross_v": 4, "k_scale": 3, "v_scale": 3,
                    "wkv": 4, "h": 2, "conv": 3,
                    "shift_t": 2, "shift_c": 2}[name]
            bidx = nd - boff
            if bspec and bidx >= 0 and shape[bidx] == batch:
                spec[bidx] = dp
        if name in ("k", "v"):
            C, hkv = shape[-3], shape[-2]
            if hkv % tp_size == 0:
                spec[nd - 2] = TP
            elif C >= BIG_CACHE and C % tp_size == 0:
                spec[nd - 3] = TP
            elif tp_size > 1:
                _log_fallback(name, shape, P(),
                              f"kv_heads = {hkv} not divisible by "
                              f"tp={tp_size} (cache rule)")
        elif name in ("ckv", "krope"):
            C = shape[-2]
            if C >= BIG_CACHE and C % tp_size == 0:
                spec[nd - 2] = TP
        elif name in ("k_scale", "v_scale"):
            C, hkv = shape[-2], shape[-1]
            if hkv % tp_size == 0:
                spec[nd - 1] = TP
            elif C >= BIG_CACHE and C % tp_size == 0:
                spec[nd - 2] = TP
        elif name == "slot_pos":
            C = shape[-1]
            if C >= BIG_CACHE and C % tp_size == 0:
                # only sharded when sibling k/v shard capacity; kv-head-
                # sharded caches keep slot_pos replicated.  We can't see the
                # sibling here, so shard iff no arch kv-head rule applies.
                if cfg.num_kv_heads % tp_size != 0 or cfg.mla is not None:
                    spec[nd - 1] = TP
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_struct)


# ---------------------------------------------------------------------------
# paged serving pool (PR 8): TP-sharded block pool placement
# ---------------------------------------------------------------------------
def paged_pool_shardings(pool_struct, cfg: ModelConfig, mesh: Mesh):
    """NamedSharding pytree for a paged KV block pool (``init_paged_pool``).

    Derived from the decode cache rules above: pool K/V blocks
    (L, NB, bs, Hkv, Dh) and the per-row fp ring tails put the KV-head
    axis on 'model' when ``kv_heads % tp == 0`` (same right-aligned
    position nd-2 as the dense k/v rule); int8 scale leaves
    (L, NB, bs, Hkv) shard heads at nd-1 like k_scale/v_scale.  Block
    tables are host-mirrored allocator state and stay replicated — the
    scalar-prefetch gather needs every table entry on every shard.
    Falls back to replication (logged once) when heads don't divide."""
    tp_size = mesh.shape[TP]

    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        nd = len(leaf.shape)
        spec = [None] * nd
        if name in ("k", "v", "k_tail", "v_tail"):
            hkv = leaf.shape[nd - 2]
            if hkv % tp_size == 0:
                spec[nd - 2] = TP
            elif tp_size > 1:
                _log_fallback(name, leaf.shape, P(),
                              f"kv_heads = {hkv} not divisible by "
                              f"tp={tp_size} (paged pool rule)")
        elif name in ("k_scale", "v_scale"):
            hkv = leaf.shape[nd - 1]
            if hkv % tp_size == 0:
                spec[nd - 1] = TP
            elif tp_size > 1:
                _log_fallback(name, leaf.shape, P(),
                              f"kv_heads = {hkv} not divisible by "
                              f"tp={tp_size} (paged pool rule)")
        # block_tables (and anything unrecognized) replicate
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, pool_struct)


def serving_runtime(mesh: Mesh, *, use_pallas: bool = False) -> Runtime:
    """Runtime for one paged-serving engine replica over a (data=1, model=T)
    sub-mesh.  Batch stays replicated inside the replica (data parallelism
    is N whole engine replicas, not a sharded batch); the 'model' axis
    splits KV heads in the pool and the attention dispatches."""
    return Runtime(mesh=mesh, model_axes=(TP,), use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# runtimes per (shape, mesh)
# ---------------------------------------------------------------------------
def runtime_for(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                *, use_pallas: bool = False, remat: bool = True,
                seq_parallel: bool = False,
                moe_fsharded: bool = False) -> Runtime:
    axes = list(mesh.axis_names)
    dp = tuple(a for a in axes if a != TP)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_ok = shape.global_batch % dp_size == 0 and shape.global_batch >= dp_size
    batch_axes = dp if batch_ok else ()
    # MoE tokens stay sharded exactly like the residual stream (batch axes
    # only).  Each 'model' rank dispatches the 1/tp slice of tokens it owns
    # (dedup in moe_ffn) — no resharding of the activation stream, which
    # GSPMD could only express as a full rematerialization.
    token_axes = batch_axes
    return Runtime(
        mesh=mesh,
        batch_axes=batch_axes,
        model_axes=(TP,),
        token_axes=token_axes,
        use_pallas=use_pallas,
        remat=remat and shape.kind == "train",
        seq_parallel=seq_parallel and shape.kind == "train",
        moe_fsharded=moe_fsharded and shape.kind == "decode",
    )


def batch_shardings(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                    rt: Runtime):
    """Shardings for the step's data inputs (tokens / frontend feats)."""
    b = rt.batch_axes if rt.batch_axes else None
    out = {"tokens": NamedSharding(mesh, P(b, None))}
    if cfg.frontend is not None:
        out["frontend"] = NamedSharding(mesh, P(b, None, None))
    return out
