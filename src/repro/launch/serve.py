"""Serving launcher: build a recycling engine and run the paper's two-phase
evaluation (cache construction + baseline vs recycled), or an interactive
request loop.

  PYTHONPATH=src python -m repro.launch.serve --arch dialogpt-medium \
      --reduced --max-new 16 [--partial] [--compare]
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.core import HashEmbedder
from repro.core.metrics import RunMetrics, summarize_runs
from repro.data.pipeline import paper_prompt_sets
from repro.models import init_params
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dialogpt-medium")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--partial", action="store_true",
                    help="enable beyond-paper block-radix partial reuse")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--compare", action="store_true",
                    help="run the paper's baseline-vs-recycled table")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, max_new_tokens=args.max_new,
                 enable_partial=args.partial, block_size=args.block_size)

    cache_prompts, test_prompts = paper_prompt_sets("data")
    print(f"building KV cache for {len(cache_prompts)} prompts ...")
    eng.precache(cache_prompts)
    print(f"store: {len(eng.recycler.store)} entries, "
          f"{eng.recycler.store.total_bytes / 1e6:.1f} MB host bytes")

    if not args.compare:
        for p in test_prompts:
            r = eng.generate(p)
            print(f"[{r.mode:13s}] reuse={r.reuse_depth:3d}/{r.prompt_tokens}"
                  f" {r.latency_s*1e3:7.1f} ms  sim={r.prompt_similarity:.2f}"
                  f"  '{p[:40]}...'")
        return

    # paper §4.4 two-phase comparison with warmup (jit compile excluded)
    for p in test_prompts:
        eng.warmup(p, use_recycling=False)
        eng.warmup(p)
    base, rec = [], []
    for p in test_prompts:
        b = eng.generate(p, use_recycling=False)
        r = eng.generate(p)
        base.append(RunMetrics(p, "baseline", b.latency_s, b.prompt_tokens,
                               b.gen_tokens, output_text=b.text))
        rec.append(RunMetrics(p, "recycled", r.latency_s, r.prompt_tokens,
                              r.gen_tokens, r.reuse_depth, r.cache_hit,
                              r.prompt_similarity, r.mode, r.text))
        sp = (b.latency_s - r.latency_s) / b.latency_s * 100
        print(f"reuse={r.reuse_depth:3d}/{r.prompt_tokens:3d} "
              f"base={b.latency_s*1e3:7.1f}ms rec={r.latency_s*1e3:7.1f}ms "
              f"speedup={sp:5.1f}%  same_output={b.text == r.text}")
    table = summarize_runs(base, rec, embedder=HashEmbedder())
    print(json.dumps(table, indent=1))


if __name__ == "__main__":
    main()
