"""Per-architecture inference cache pytrees.

Every cache is a nested dict of arrays with a leading layer/segment-stack
dimension so the layer scan can thread it.  ``init_cache`` builds concrete
zeros (engine / smoke tests); ``cache_struct`` builds ShapeDtypeStructs for
the dry-run (no allocation).  Capacity semantics:

  * full-attention decode: capacity == seq_len (slot == position)
  * sliding-window decode: capacity == window (ring buffer)
  * recurrent families: O(1) state, capacity ignored

The same pytrees are what ``repro.core`` serializes to the host for
cross-prompt recycling.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.transformer import segments


def _stack(tree, n: int):
    return jax.tree.map(lambda a: jnp.tile(a[None], (n,) + (1,) * a.ndim), tree)


def _attn_cache(cfg: ModelConfig, batch: int, capacity: int, dtype,
                quant: bool = False, per_slot: bool = False):
    if cfg.mla is not None:
        if per_slot:
            raise NotImplementedError(
                "per-slot pools require the GQA/MHA slot-buffer cache; "
                "MLA latent caches have no per-row slot_pos")
        return mla_mod.init_mla_cache(cfg, batch, capacity, dtype)
    return attn.init_kv_cache(batch, capacity, cfg.num_kv_heads,
                              cfg.head_dim, dtype, quant=quant,
                              per_slot=per_slot)


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               *, window: int = 0, dtype=None, kv_quant: bool = False,
               per_slot: bool = False):
    """capacity: max absolute positions the attention caches must hold.
    ``window`` > 0 switches full-attention layers to ring buffers of that
    size (long-context mode).  ``kv_quant`` stores trunk K/V in int8
    (EXPERIMENTS.md §Perf-4).  ``per_slot`` builds the continuous-batching
    slot pool layout: every batch row gets its own ``slot_pos`` vector so it
    can hold an independent request at its own position (trunk attention
    families only — recurrent/enc-dec/MLA rows can't be sliced per slot)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    eff_cap = min(window, capacity) if window else capacity
    cache = {}
    for i, (kind, n) in enumerate(segments(cfg)):
        if per_slot and kind not in ("dense", "moe", "dense_first"):
            raise NotImplementedError(
                f"per-slot pool unsupported for segment kind {kind!r}")
        if kind in ("dense", "moe", "dense_first"):
            c = _attn_cache(cfg, batch, eff_cap, dtype, quant=kv_quant,
                            per_slot=per_slot)
        elif kind == "griffin_block":
            hc = cfg.hybrid
            c = {
                "r1": rglru_mod.init_rglru_state(cfg, batch, dtype),
                "r2": rglru_mod.init_rglru_state(cfg, batch, dtype),
                "attn": attn.init_kv_cache(
                    batch, min(hc.local_window, capacity),
                    cfg.num_kv_heads, cfg.head_dim, dtype),
            }
        elif kind == "griffin_tail":
            c = {"r1": rglru_mod.init_rglru_state(cfg, batch, dtype)}
        elif kind == "rwkv":
            c = rwkv_mod.init_rwkv_state(cfg, batch, dtype)
        elif kind == "encdec":
            f = cfg.frontend
            c = {
                "self": _attn_cache(cfg, batch, eff_cap, dtype),
                "cross_k": jnp.zeros((batch, f.num_tokens, cfg.num_kv_heads,
                                      cfg.head_dim), dtype),
                "cross_v": jnp.zeros((batch, f.num_tokens, cfg.num_kv_heads,
                                      cfg.head_dim), dtype),
            }
        else:
            raise ValueError(kind)
        cache[f"seg{i}"] = _stack(c, n)
    return cache


def init_paged_pool(cfg: ModelConfig, num_blocks: int, block_size: int,
                    max_batch: int, max_blocks_per_seq: int, *, dtype=None,
                    quant: bool = False, fp_tail_blocks: int = 2,
                    mesh=None):
    """Paged continuous-batching pool: ONE shared block pool per layer
    plus per-request block tables (``attention.init_paged_kv_cache``),
    stacked over the layer scan like every other cache.  Blocks are
    addressed identically in every layer — block id b holds token block b
    of some request in ALL layers — so one host-side allocator covers the
    whole stack.  Trunk attention only (same restriction as ``per_slot``):
    recurrent/enc-dec/MLA state cannot be sliced into shared blocks.

    ``quant=True`` stores pool K/V int8 with per-vector f32 scales plus a
    per-row fp ring tail of ``fp_tail_blocks`` blocks — ~2-4x more
    resident blocks per HBM byte (see ``attention.init_paged_kv_cache``).

    ``mesh`` places the pool with ``sharding.paged_pool_shardings``:
    K/V blocks, ring tails, and int8 scales split the KV-head axis over
    'model' (replication fallback when heads don't divide); block tables
    replicate.  Without a mesh the pool is single-device as before."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        raise NotImplementedError(
            "paged pools require the GQA/MHA block layout; MLA latent "
            "caches have no per-block K/V to share")
    pool = {}
    for i, (kind, n) in enumerate(segments(cfg)):
        if kind not in ("dense", "moe", "dense_first"):
            raise NotImplementedError(
                f"paged pool unsupported for segment kind {kind!r}")
        c = attn.init_paged_kv_cache(
            num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim, dtype,
            max_batch=max_batch, max_blocks_per_seq=max_blocks_per_seq,
            quant=quant, fp_tail_blocks=fp_tail_blocks)
        pool[f"seg{i}"] = _stack(c, n)
    if mesh is not None:
        from repro.sharding import paged_pool_shardings
        shardings = paged_pool_shardings(pool, cfg, mesh)
        pool = jax.tree.map(jax.device_put, pool, shardings)
    return pool


def paged_block_bytes(cfg: ModelConfig, block_size: int, *,
                      dtype=None, quant: bool = False) -> int:
    """Device bytes ONE pool block occupies across the whole layer stack
    (K + V) — the unit of the paged engine's bytes-in-use accounting.
    ``quant=True``: int8 K/V plus the per-vector f32 scale (the per-row fp
    ring tails are per-ROW constants, not per-block, so they are not part
    of the per-block unit)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    vectors = 2 * block_size * cfg.num_kv_heads
    if quant:
        per_layer = vectors * (cfg.head_dim + 4)     # int8 elems + f32 scale
    else:
        per_layer = vectors * cfg.head_dim * jnp.dtype(dtype).itemsize
    return per_layer * cfg.num_layers


def cache_struct(cfg: ModelConfig, batch: int, capacity: int,
                 *, window: int = 0, dtype=None, kv_quant: bool = False,
                 per_slot: bool = False):
    """ShapeDtypeStruct pytree for dry-run lowering (no allocation)."""
    fn = functools.partial(init_cache, cfg, batch, capacity,
                           window=window, dtype=dtype, kv_quant=kv_quant,
                           per_slot=per_slot)
    return jax.eval_shape(fn)


def cache_bytes(cache) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(cache))
