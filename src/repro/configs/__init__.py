"""Registry of architecture configs.

Each module defines ``CONFIG: ModelConfig`` with the exact assigned numbers
(source cited in ``config.source``).  ``get_config(name)`` resolves by id;
``--arch <id>`` on every launcher goes through here.
"""
from __future__ import annotations

from repro.config import ModelConfig, InputShape, INPUT_SHAPES, get_shape

from repro.configs.whisper_base import CONFIG as _whisper_base
from repro.configs.qwen2_5_3b import CONFIG as _qwen2_5_3b
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma_9b
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek_v2_236b
from repro.configs.qwen1_5_32b import CONFIG as _qwen1_5_32b
from repro.configs.rwkv6_3b import CONFIG as _rwkv6_3b
from repro.configs.qwen3_1_7b import CONFIG as _qwen3_1_7b
from repro.configs.command_r_35b import CONFIG as _command_r_35b
from repro.configs.internvl2_76b import CONFIG as _internvl2_76b
from repro.configs.kimi_k2_1t import CONFIG as _kimi_k2_1t
from repro.configs.dialogpt_medium import CONFIG as _dialogpt_medium

_REGISTRY = {
    c.name: c
    for c in [
        _whisper_base,
        _qwen2_5_3b,
        _recurrentgemma_9b,
        _deepseek_v2_236b,
        _qwen1_5_32b,
        _rwkv6_3b,
        _qwen3_1_7b,
        _command_r_35b,
        _internvl2_76b,
        _kimi_k2_1t,
        _dialogpt_medium,
    ]
}

# The ten architectures assigned from the public pool (paper testbed excluded).
ASSIGNED_ARCHS = [
    "whisper-base",
    "qwen2.5-3b",
    "recurrentgemma-9b",
    "deepseek-v2-236b",
    "qwen1.5-32b",
    "rwkv6-3b",
    "qwen3-1.7b",
    "command-r-35b",
    "internvl2-76b",
    "kimi-k2-1t-a32b",
]


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    return sorted(_REGISTRY)


__all__ = [
    "ModelConfig",
    "InputShape",
    "INPUT_SHAPES",
    "ASSIGNED_ARCHS",
    "get_config",
    "get_shape",
    "list_configs",
]
