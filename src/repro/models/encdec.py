"""Whisper-style encoder + modality frontend stubs.

Per the assignment, the mel-spectrogram/conv codec (audio) and ViT/projector
(vision) are STUBS: ``input_specs`` delivers precomputed frame/patch
embeddings.  What we DO implement: the bidirectional encoder stack the
decoder cross-attends to (whisper), and the VLM projector + prefix concat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (apply_mlp, apply_norm, dense_init, init_mlp,
                                 init_norm, sinusoid_positions, split_tree)


def init_encoder(cfg: ModelConfig, key, dtype):
    f = cfg.frontend
    if not f.cross_attention:
        # VLM: projector only (embed_dim -> d_model)
        return {"proj": dense_init(key, (f.embed_dim, cfg.d_model), dtype)}
    ks = split_tree(key, f.encoder_layers + 2)
    layers = []
    for i in range(f.encoder_layers):
        lk = split_tree(ks[i], 2)
        layers.append({
            "ln1": init_norm(cfg),
            "attn": attn.init_attention(cfg, lk[0], dtype),
            "ln2": init_norm(cfg),
            "mlp": init_mlp(cfg, lk[1], cfg.d_model, f.encoder_d_ff, dtype),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {"in_proj": dense_init(ks[-2], (f.embed_dim, cfg.d_model), dtype),
            "layers": stacked, "final_norm": init_norm(cfg)}


def encode(cfg: ModelConfig, params, feats, rt=None):
    """feats: (B, F, embed_dim) stub embeddings -> (B, F, d_model)."""
    f = cfg.frontend
    if not f.cross_attention:
        return feats @ params["proj"]
    x = feats @ params["in_proj"]
    F = x.shape[1]
    pos = jnp.arange(F, dtype=jnp.int32)
    x = x + sinusoid_positions(pos, cfg.d_model, x.dtype)

    def body(h, p_l):
        a = apply_norm(cfg, p_l["ln1"], h)
        q, k, v = attn.project_qkv(cfg, p_l["attn"], a, pos, rope=False)
        o = attn.attend_direct(q, k, v, pos, pos, causal=False)
        B, Fr, H, D = o.shape
        h = h + o.reshape(B, Fr, H * D) @ p_l["attn"]["wo"]
        m = apply_norm(cfg, p_l["ln2"], h)
        h = h + apply_mlp(cfg, p_l["mlp"], m, rt)
        return h, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return apply_norm(cfg, params["final_norm"], x)
