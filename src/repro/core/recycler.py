"""The recycling decision path: retrieval -> prefix test -> cache surgery.

Paper-faithful mode (§2.5/§3.1): embed the new prompt, retrieve the most
similar cached prompt, require the cached token ids to be an *exact full
prefix* (reuse depth r == k), then hand the cached pytree + suffix tokens to
the engine.

Beyond-paper mode adds the block-radix partial path: the deepest
block-aligned common prefix of ANY cached entry can be reused by *trimming*
the cached attention buffers to that depth (slot_pos masking — valid because
causal prefix KVs are independent of what follows).  Recurrent-state caches
(RWKV/Griffin) are not trimmable — a state snapshot cannot be rewound — so
they only ever hit via the exact full-prefix rule, as DESIGN.md §4 requires.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from repro.core.embedder import HashEmbedder
from repro.core.faults import InjectedFault
from repro.core.index import EmbeddingIndex
from repro.core.lsh import BlockLSH, match_mask
from repro.core import quant as kvq
from repro.core.kvstore import CacheEntry, HostKVStore, cache_digest
from repro.core.quant import CAP_AXIS as _CAP_AXIS
from repro.core.radix import RadixPrefixCache

_STATEFUL_KEYS = {"wkv", "h", "conv", "shift_t", "shift_c"}
_NO_RESIZE = {"cross_k", "cross_v"}


def common_prefix_len(a, b) -> int:
    n = min(len(a), len(b))
    if n == 0:
        return 0
    a = np.asarray(a[:n])
    b = np.asarray(b[:n])
    neq = np.nonzero(a != b)[0]
    return int(neq[0]) if len(neq) else n


def is_trimmable(cache) -> bool:
    """True iff the cache contains no recurrent state (pure attention)."""
    def walk(t) -> bool:
        if isinstance(t, dict):
            if _STATEFUL_KEYS & set(t.keys()):
                return False
            return all(walk(v) for v in t.values())
        return True
    return walk(cache)


def trim_to_depth(cache, depth: int):
    """Mask every cached slot holding a position >= depth (prefix reuse)."""
    def walk(t):
        if isinstance(t, dict):
            return {k: (np.where(v < depth, v, -1).astype(v.dtype)
                        if k == "slot_pos" else walk(v))
                    for k, v in t.items()}
        return t
    return walk(cache)


def grow_capacity(cache, new_capacity: int):
    """Pad attention buffers' slot axis up to new_capacity (host numpy)."""
    def walk(t, name=None):
        if isinstance(t, dict):
            return {k: walk(v, k) for k, v in t.items()}
        if name in _NO_RESIZE or name not in _CAP_AXIS:
            return t
        if t.ndim < abs(_CAP_AXIS[name]):
            return t                       # leaf too small to hold this axis
        ax = _CAP_AXIS[name] % t.ndim
        cur = t.shape[ax]
        if cur >= new_capacity:
            return t
        pad = [(0, 0)] * t.ndim
        pad[ax] = (0, new_capacity - cur)
        fill = -1 if name == "slot_pos" else 0
        return np.pad(t, pad, constant_values=fill)
    return walk(cache)


def shrink_capacity(cache, new_capacity: int):
    """Slice attention buffers' slot axis down to new_capacity (host numpy).

    Inverse of grow_capacity, valid only when every surviving slot index is
    < new_capacity — true for an unwrapped (non-ring) cache whose positions
    were written at slot == position and whose valid positions are all
    < new_capacity (e.g. after trim_to_depth(m) with m <= new_capacity)."""
    def walk(t, name=None):
        if isinstance(t, dict):
            return {k: walk(v, k) for k, v in t.items()}
        if name in _NO_RESIZE or name not in _CAP_AXIS:
            return t
        if t.ndim < abs(_CAP_AXIS[name]):
            return t
        ax = _CAP_AXIS[name] % t.ndim
        if t.shape[ax] <= new_capacity:
            return t
        sl = [slice(None)] * t.ndim
        sl[ax] = slice(0, new_capacity)
        return t[tuple(sl)]
    return walk(cache)


@dataclass
class RecycleResult:
    hit: bool
    mode: str                    # "exact_prefix" | "partial_block" | "miss"
    entry: Optional[CacheEntry]
    reuse_depth: int             # k — tokens skipped
    similarity: float            # retrieval similarity (paper metric)
    cache: Any = None            # host cache pytree ready for the engine


@dataclass
class GraftPlan:
    """A semantic block-donor graft nominated by ``lookup_semantic``.

    The donor's blocks [b0, b1) match the query's SAME-POSITION blocks
    (verified token agreement >= min_agree per block).  The engine
    recomputes the first ``boundary`` block(s) of the run under the new
    prompt's real context (the KVLink link-region idea at block
    granularity) and grafts the interior [interior_lo, interior_hi)
    verbatim; the fidelity gate compares the recomputed boundary against
    the donor's same blocks and refuses the graft on divergence.
    """
    entry: CacheEntry
    b0: int                      # first matched block
    b1: int                      # one past the last matched block
    boundary: int                # leading run blocks recomputed, >= 1
    block_size: int
    similarity: float            # donor's sentence-embedding similarity
    agreement: float             # mean token agreement over [b0, b1)

    @property
    def interior_lo(self) -> int:
        return self.b0 + self.boundary

    @property
    def interior_hi(self) -> int:
        return self.b1

    @property
    def seg1_end(self) -> int:
        """First token position NOT recomputed before the graft."""
        return self.interior_lo * self.block_size

    @property
    def graft_end(self) -> int:
        """First token position after the grafted interior."""
        return self.b1 * self.block_size

    @property
    def interior_tokens(self) -> int:
        return (self.interior_hi - self.interior_lo) * self.block_size


class Recycler:
    """Cross-prompt KV recycling policy over a HostKVStore."""

    def __init__(self, store: Optional[HostKVStore] = None,
                 embedder: Optional[HashEmbedder] = None,
                 *, enable_partial: bool = False, block_size: int = 64,
                 retrieval_k: int = 4, compress: bool = False,
                 compress_residual: int = kvq.DEFAULT_RESIDUAL,
                 semantic: bool = False, graft_min_agree: float = 1.0,
                 graft_boundary_blocks: int = 1):
        # NB: not ``store or ...`` — an empty HostKVStore is falsy (__len__)
        self.store = store if store is not None else HostKVStore()
        self.embedder = embedder if embedder is not None else HashEmbedder()
        self.index = EmbeddingIndex(self.embedder.dim)
        self.block = block_size
        self.radix = RadixPrefixCache(block_size) if enable_partial else None
        self.retrieval_k = retrieval_k
        # int8 host-cache compression (beyond paper): halves bf16 KV bytes.
        # The last ``compress_residual`` valid positions stay full precision
        # (core.quant residual tail) so recycled greedy output matches the
        # uncompressed path; the invalid region beyond ``length`` is dropped.
        self.compress = compress
        self.compress_residual = compress_residual
        # beyond-paper semantic mode: block-level donor search over a
        # token-block LSH (SemShareKV-style), consumed by the paged
        # engine's graft path.  Off by default — the greedy token-identity
        # of the exact/partial/miss paths is untouched when off.
        self.semantic = semantic
        self.graft_min_agree = graft_min_agree
        self.graft_boundary_blocks = max(1, graft_boundary_blocks)
        self.lsh = BlockLSH(block_size) if semantic else None
        # A store handed in pre-populated (e.g. HostKVStore.load_dir) used
        # to be INVISIBLE to retrieval: neither the embedding index nor
        # the radix/LSH were rebuilt, so no persisted entry could ever
        # hit.  Rebuild every mirror from the store's entries here.
        # fault-containment accounting: host-store IO errors degrade to a
        # miss / skipped admit, corrupt entries (digest mismatch at serve
        # time) are dropped and degrade to a miss — in every case the
        # request continues on the baseline path with identical tokens
        self.stats = {"io_fault_misses": 0, "admit_io_faults": 0,
                      "corrupt_entry_drops": 0}
        for e in self.store.entries():
            self._index_entry(e)
        # budget evictions can now fire inside store.put(); the callback
        # keeps the mirrors consistent however the eviction was triggered
        self.store.on_evict = self._forget_entry

    # ------------------------------------------------------------------
    def _index_entry(self, entry: CacheEntry) -> None:
        """Register one store entry in every retrieval mirror."""
        self.index.add(entry.entry_id, self.embedder.encode(entry.text))
        if is_trimmable(entry.cache):
            if self.radix is not None:
                self.radix.insert(entry.token_ids, entry.entry_id,
                                  entry.length)
            if self.lsh is not None:
                self.lsh.add(entry.entry_id, entry.token_ids, entry.length)

    def _forget_entry(self, entry_id: int) -> None:
        """Drop one evicted entry from every retrieval mirror."""
        self.index.remove(entry_id)
        if self.radix is not None:
            self.radix.forget_entry(entry_id)
        if self.lsh is not None:
            self.lsh.remove(entry_id)

    def _verify_entry(self, e: CacheEntry) -> bool:
        """Serve-time corruption gate: recompute the entry's content
        digest and compare against the one stamped at put.  A mismatch
        drops the entry from the store and every mirror — serving a
        silently-corrupted KV payload would break the token-identity
        guarantee, whereas a miss never can."""
        if e.digest and cache_digest(e.cache) != e.digest:
            self.store.remove(e.entry_id)
            self._forget_entry(e.entry_id)
            self.stats["corrupt_entry_drops"] += 1
            return False
        return True

    # ------------------------------------------------------------------
    def admit(self, text: str, token_ids, cache_host, length: int,
              capacity: Optional[int] = None,
              compress: Optional[bool] = None,
              tenant: Optional[str] = None) -> Optional[CacheEntry]:
        """Store a finished run's cache for future recycling (paper §2.4).
        ``compress`` overrides the recycler-wide default for this entry
        (byte-budget eviction fires either way); ``tenant`` labels the
        entry for the store's per-tenant byte accounting.

        Returns None when a host-store IO error (real or injected)
        prevents the write — admission is best-effort: the run already
        produced its tokens, losing the cache entry only costs future
        reuse, never correctness."""
        if self.compress if compress is None else compress:
            cache_host = kvq.quantize_tree(cache_host, length=length,
                                           residual=self.compress_residual)
        try:
            entry = self.store.put(text, token_ids, cache_host, length,
                                   capacity, tenant=tenant)
        except (InjectedFault, OSError):
            self.stats["admit_io_faults"] += 1
            return None
        # put() enforces the byte budget itself now (evicted ids reach
        # _forget_entry through store.on_evict); only index the new entry
        # if it actually survived — an entry bigger than the whole budget
        # is evicted inside put and must not linger in the mirrors
        if entry.entry_id in self.store:
            self._index_entry(entry)
        return entry

    # ------------------------------------------------------------------
    def lookup(self, text: str, token_ids) -> RecycleResult:
        """IO-fault-contained lookup: a host-store read error (real or
        injected) anywhere in the retrieval path degrades to a MISS —
        the request prefills from scratch with identical tokens instead
        of crashing the engine step."""
        try:
            return self._lookup_impl(text, token_ids)
        except (InjectedFault, OSError):
            self.stats["io_fault_misses"] += 1
            return RecycleResult(False, "miss", None, 0, 0.0, None)

    def _lookup_impl(self, text: str, token_ids) -> RecycleResult:
        token_ids = np.asarray(token_ids, np.int32)
        m = len(token_ids)
        max_depth = m - 1          # generation needs >= 1 input token

        # --- paper-faithful: retrieve -> exact full-prefix test ---------
        best_exact: Optional[tuple] = None
        sim_best = 0.0
        qvec = self.embedder.encode(text)
        for eid, sim in self.index.search(qvec, self.retrieval_k):
            if eid not in self.store:
                continue
            e = self.store.get(eid, touch=False)
            sim_best = max(sim_best, sim)
            r = common_prefix_len(token_ids, e.token_ids[:e.length])
            if r != e.length or r == 0:
                continue                          # cached not a full prefix
            # Attention caches tolerate depth < e.length (overwritten by the
            # suffix); recurrent state cannot rewind, so it needs the full
            # cached length to fit under max_depth.
            depth = min(r, max_depth)
            if not is_trimmable(e.cache) and e.length > max_depth:
                continue
            if depth > 0 and (best_exact is None or depth > best_exact[0]):
                best_exact = (depth, e, sim)

        # --- beyond-paper: block-radix partial prefix --------------------
        best_partial: Optional[tuple] = None
        if self.radix is not None:
            depth, eid = self.radix.lookup(token_ids)
            depth = min(depth, max_depth)
            if depth > 0 and eid is not None and eid in self.store:
                best_partial = (depth, self.store.get(eid, touch=False))

        def _materialize(cache):
            # per-entry, not self.compress: admit() can toggle compression
            # per entry, and natively-quantized device-layout caches
            # (k_scale present, no __q8__) pass through untouched
            return (kvq.dequantize_tree(cache) if kvq.is_quantized(cache)
                    else cache)

        if best_exact and (not best_partial or best_exact[0] >= best_partial[0]):
            depth, e, sim = best_exact
            if not self._verify_entry(e):         # corrupt -> dropped
                return RecycleResult(False, "miss", None, 0, sim_best, None)
            self.store.get(e.entry_id)            # LRU touch
            if self.radix is not None:
                self.radix.touch(e.entry_id)      # keep trie recency in sync
            # exact path needs no trim: cached positions are all < e.length
            # <= m, and [depth, e.length) get overwritten by the suffix.
            return RecycleResult(True, "exact_prefix", e, depth, sim,
                                 _materialize(e.cache))
        if best_partial:
            depth, e = best_partial
            # recency stamps (store LRU + radix last-touch) only when the
            # entry actually SERVES: stamping before the trimmable gate
            # would let a never-servable entry keep winning the radix's
            # recency preference forever (self-reinforcing miss loop)
            if is_trimmable(e.cache):
                if not self._verify_entry(e):     # corrupt -> dropped
                    return RecycleResult(False, "miss", None, 0,
                                         sim_best, None)
                self.store.get(e.entry_id)
                self.radix.touch(e.entry_id)
                # report the HIT ENTRY's own retrieval similarity — not
                # sim_best from the exact-path loop, which may describe a
                # different (rejected) candidate and poison the metrics.
                sim = self.index.similarity(e.entry_id, qvec)
                return RecycleResult(True, "partial_block", e, depth,
                                     sim, _materialize(trim_to_depth(e.cache,
                                                                     depth)))
        return RecycleResult(False, "miss", None, 0, sim_best, None)

    # ------------------------------------------------------------------
    def lookup_semantic(self, text: str, token_ids) -> Optional[GraftPlan]:
        """Block-level donor search for a prompt that MISSED the prefix
        paths (beyond paper; SemShareKV + KVLink at block granularity).

        Hashes the query's token blocks through the LSH, verifies every
        candidate block against the donor's actual token ids at the SAME
        positions, and nominates the donor with the longest run of
        agreeing blocks.  The plan's leading ``boundary`` block(s) are
        recomputed by the engine; only the interior is grafted, and the
        run is clamped so the block holding the final prompt token is
        always recomputed (generation needs its true logits).  Returns
        None when semantic mode is off or no donor clears the bar.
        """
        if self.lsh is None:
            return None
        try:
            return self._lookup_semantic_impl(text, token_ids)
        except (InjectedFault, OSError):
            self.stats["io_fault_misses"] += 1
            return None

    def _lookup_semantic_impl(self, text: str,
                              token_ids) -> Optional[GraftPlan]:
        ids = np.asarray(token_ids, np.int32)
        m = len(ids)
        bs = self.block
        last_block = (m - 1) // bs       # must be recomputed -> ungraftable
        if last_block < self.graft_boundary_blocks + 1:
            return None                  # no room for any interior
        best: Optional[GraftPlan] = None
        qvec = self.embedder.encode(text)
        for eid, cand in self.lsh.candidates(ids, m).items():
            if eid not in self.store:
                continue
            e = self.store.get(eid, touch=False)
            agrees = match_mask(ids, e.token_ids[:e.length], bs, cand,
                                self.graft_min_agree)
            # only blocks strictly before last_block can be grafted
            agrees = agrees[:min(len(agrees), last_block)]
            # longest run of agreeing blocks
            b = 0
            while b < len(agrees):
                if agrees[b] <= 0.0:
                    b += 1
                    continue
                b_end = b
                while b_end < len(agrees) and agrees[b_end] > 0.0:
                    b_end += 1
                n_interior = (b_end - b) - self.graft_boundary_blocks
                if n_interior >= 1:
                    mean_agree = float(np.mean(agrees[b:b_end]))
                    plan = GraftPlan(e, b, b_end,
                                     self.graft_boundary_blocks, bs,
                                     0.0, mean_agree)
                    if (best is None
                            or plan.interior_tokens
                            > best.interior_tokens
                            or (plan.interior_tokens
                                == best.interior_tokens
                                and plan.agreement > best.agreement)):
                        best = plan
                b = b_end
        if best is not None:
            if not self._verify_entry(best.entry):   # corrupt -> dropped
                return None
            best.similarity = self.index.similarity(best.entry.entry_id,
                                                    qvec)
        return best
