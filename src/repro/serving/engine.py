"""Serving engine: tokenizer -> recycler -> prefill(suffix) -> decode loop.

This is the paper's evaluation loop (§4.4) as a production surface:

  baseline run    engine.generate(p, use_recycling=False)
  cache build     engine.precache(prompts)          # §4.4 "Cache Construction"
  recycled run    engine.generate(p)                # retrieval + prefix test
                                                    # + past_key_values reuse

plus what the paper doesn't have: capacity-bucketed cache allocation (stable
jit signatures), automatic admission of finished generations (multi-turn
prefix reuse), block-radix partial hits, and byte-budget LRU eviction.

Latency accounting mirrors §4.5: wall time around the whole generate call
with ``block_until_ready`` as the synchronize analogue, reuse depth k, and
prompt similarity from the retrieval stage.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import HashEmbedder, Recycler
from repro.core.kvstore import to_host
from repro.core.recycler import grow_capacity, is_trimmable, trim_to_depth
from repro.data.tokenizer import ByteTokenizer, EOS
from repro.models import decode_step, init_cache, prefill
from repro.runtime import Runtime, LOCAL
from repro.serving.sampling import greedy


@dataclass
class GenResult:
    text: str
    token_ids: np.ndarray
    latency_s: float
    prompt_tokens: int
    gen_tokens: int
    reuse_depth: int = 0
    cache_hit: bool = False
    mode: str = "baseline"
    prompt_similarity: float = 0.0


class Engine:
    def __init__(self, cfg: ModelConfig, params, *,
                 tokenizer: Optional[ByteTokenizer] = None,
                 recycler: Optional[Recycler] = None,
                 enable_partial: bool = False,
                 block_size: int = 64,
                 max_new_tokens: int = 32,
                 window: int = 0,
                 compress_host_cache: bool = False,
                 kv_quant: bool = False,
                 rt: Runtime = LOCAL):
        self.cfg = cfg
        self.params = params
        self.tok = tokenizer or ByteTokenizer(cfg.vocab_size)
        self.recycler = recycler or Recycler(
            embedder=HashEmbedder(), enable_partial=enable_partial,
            block_size=block_size, compress=compress_host_cache)
        self.block = block_size
        self.max_new = max_new_tokens
        self.window = window
        self.kv_quant = kv_quant
        self.rt = rt
        self._prefill_fn = jax.jit(
            lambda p, t, c, sp: prefill(cfg, p, t, c, start_pos=sp,
                                        window=window, rt=rt))
        self._decode_fn = jax.jit(
            lambda p, t, c, pos: decode_step(cfg, p, t, c, pos,
                                             window=window, rt=rt))
        self.stats = {"requests": 0, "hits": 0, "tokens_reused": 0,
                      "tokens_prefilled": 0}

    # ------------------------------------------------------------------
    def _capacity(self, n: int) -> int:
        return ((n + self.block - 1) // self.block) * self.block

    def _make_cache(self, capacity: int):
        return init_cache(self.cfg, 1, capacity, window=self.window,
                          dtype=jnp.dtype(self.cfg.dtype),
                          kv_quant=self.kv_quant)

    # ------------------------------------------------------------------
    def precache(self, prompts, lengths: Optional[Dict[str, int]] = None):
        """Paper §4.4 cache construction: one forward pass per cache prompt
        with caching enabled; serialize to host and index by embedding."""
        for p in prompts:
            ids = self.tok.encode(p)
            cap = self._capacity(len(ids) + self.max_new)
            cache = self._make_cache(cap)
            _, cache = self._prefill_fn(self.params, jnp.asarray(ids)[None],
                                        cache, 0)
            self.recycler.admit(p, ids, to_host(cache), len(ids), cap)

    # ------------------------------------------------------------------
    def generate(self, prompt: str, *, max_new_tokens: Optional[int] = None,
                 use_recycling: bool = True, admit: bool = False,
                 stop_at_eos: bool = True) -> GenResult:
        max_new = max_new_tokens or self.max_new
        t0 = time.perf_counter()
        ids = self.tok.encode(prompt)
        m = len(ids)
        cap = self._capacity(m + max_new)

        depth, hit, mode, sim = 0, False, "baseline", 0.0
        if use_recycling:
            res = self.recycler.lookup(prompt, ids)
            sim = res.similarity
            if res.hit:
                depth, hit, mode = res.reuse_depth, True, res.mode
                host_cache = grow_capacity(res.cache, cap)
                cache = jax.tree.map(jnp.asarray, host_cache)
            else:
                mode = "miss"
        if not hit:
            cache = self._make_cache(cap)

        suffix = jnp.asarray(ids[depth:])[None]
        logits, cache = self._prefill_fn(self.params, suffix,
                                         cache, depth)
        out_ids = []
        tok = greedy(logits)[:, None]
        pos = m
        for _ in range(max_new):
            out_ids.append(int(tok[0, 0]))
            if stop_at_eos and out_ids[-1] == EOS:
                break
            logits, cache = self._decode_fn(self.params, tok, cache,
                                            jnp.int32(pos))
            tok = greedy(logits)[:, None]
            pos += 1
        jax.block_until_ready(logits)
        latency = time.perf_counter() - t0

        all_ids = np.concatenate([ids, np.asarray(out_ids, np.int32)])
        if admit:
            host = to_host(cache)
            if is_trimmable(host):
                # admit at PROMPT depth: future prompts extending this one
                # (without the generated reply) still pass the exact-prefix
                # test; generated positions are masked out.
                self.recycler.admit(prompt, ids, trim_to_depth(host, m),
                                    m, cap)
            else:
                # recurrent state can't rewind: admit the full trajectory
                self.recycler.admit(prompt, all_ids, host, len(all_ids), cap)

        self.stats["requests"] += 1
        self.stats["hits"] += int(hit)
        self.stats["tokens_reused"] += depth
        self.stats["tokens_prefilled"] += m - depth
        return GenResult(
            text=self.tok.decode(out_ids),
            token_ids=all_ids,
            latency_s=latency,
            prompt_tokens=m,
            gen_tokens=len(out_ids),
            reuse_depth=depth,
            cache_hit=hit,
            mode=mode if use_recycling else "baseline",
            prompt_similarity=sim,
        )

    # ------------------------------------------------------------------
    def warmup(self, prompt: str, *, max_new_tokens: Optional[int] = None,
               use_recycling: bool = True) -> None:
        """Compile the shapes a subsequent timed call will use (the paper's
        T4 runs have no compile step; jit does — exclude it from latency)."""
        self.generate(prompt, max_new_tokens=max_new_tokens,
                      use_recycling=use_recycling, admit=False)
