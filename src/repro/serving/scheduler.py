"""Request scheduling: serial FIFO baseline + continuous batching.

Two schedulers share one Request surface:

``FIFOScheduler`` is the honest serial baseline — ``step()`` pops requests
off the queue and runs ``engine.generate`` one at a time.  It exists as the
reference the batched path is benchmarked (and tested token-for-token)
against.

``ContinuousBatchingScheduler`` drives a pool engine — the dense
``BatchedEngine`` slot pool or the paged ``PagedEngine`` block-table pool,
both behind the same ``free_slots``/``admit_slot``/``decode_batch``
surface: it owns the admission policy (FIFO order, admit-before-decode),
the slot allocator (free list over pool rows), and the in-flight set.  Every
``step()`` first fills free slots from the queue head, then advances ALL
in-flight requests with one ``decode_batch`` call.  What an admission costs
inside that call is the ENGINE's choice: the dense pool (and the paged pool
in ``prefill_mode="staged"``) runs the whole single-row prefill inside
``admit_slot``; the paged pool's chunked default instead queues the
admission and ``decode_batch`` advances it ONE fixed-size chunk per step,
interleaved with the batched decode dispatch — a long prompt admits over
several steps while the resident batch keeps emitting tokens, and its slot
counts as in-flight the whole time (``admit_slot`` returned None).  Rows
that hit EOS or their token budget are freed at the step boundary and the
next ``step()`` refills them mid-flight: the batch never drains to refill,
which is what "continuous" means and where the throughput over the serial
loop comes from (one dispatch per token-step instead of one per request).

Static shapes still rule everything: the pool is a fixed ``[max_batch,
capacity, ...]`` allocation, so the decode executable compiles exactly once
per pool shape regardless of arrival order, mix of hit/miss requests, or
occupancy.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.serving.engine import BatchedEngine, Engine, GenResult


@dataclass
class Request:
    request_id: int
    prompt: str
    max_new_tokens: Optional[int] = None
    use_recycling: bool = True
    admit: bool = False
    # sampling controls; 0 temperature = greedy (the paper's
    # do_sample=False default).  Rows at different temperatures mix
    # freely in one pool dispatch (engine `sample_batched`).
    temperature: float = 0.0
    top_k: int = 0
    submitted_at: float = field(default_factory=time.perf_counter)
    result: Optional[GenResult] = None
    error: Optional[str] = None          # set when admission rejects it

    @property
    def done(self) -> bool:
        return self.result is not None or self.error is not None


class FIFOScheduler:
    """Serial reference scheduler: one ``engine.generate`` per request."""

    def __init__(self, engine: Engine, *, max_batch: int = 8):
        self.engine = engine
        self.max_batch = max_batch
        self._queue: Deque[Request] = deque()
        self._next_id = 0
        self.completed: List[Request] = []

    def submit(self, prompt: str, **kw) -> Request:
        req = Request(self._next_id, prompt, **kw)
        self._next_id += 1
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    def step(self) -> List[Request]:
        """Serve up to max_batch requests from the queue head, sequentially
        (the engine's jit cache makes same-shape requests share one
        executable, but each still pays its own dispatch per token)."""
        served = []
        while self._queue and len(served) < self.max_batch:
            req = self._queue.popleft()
            req.result = self.engine.generate(
                req.prompt, max_new_tokens=req.max_new_tokens,
                use_recycling=req.use_recycling, admit=req.admit,
                temperature=req.temperature, top_k=req.top_k)
            served.append(req)
            self.completed.append(req)
        return served

    def run(self) -> List[Request]:
        while self._queue:
            self.step()
        return self.completed


class ContinuousBatchingScheduler:
    """Admission policy + slot allocator over a ``BatchedEngine`` pool."""

    def __init__(self, engine: BatchedEngine, *,
                 max_admissions_per_step: Optional[int] = None):
        self.engine = engine
        # at most this many single-row prefills per step before decoding;
        # None = fill every free slot (prefill-heavy but maximal occupancy)
        if max_admissions_per_step is not None and max_admissions_per_step < 1:
            raise ValueError("max_admissions_per_step must be >= 1 (0 would "
                             "make run() spin forever admitting nothing)")
        self.max_admissions = max_admissions_per_step
        self._queue: Deque[Request] = deque()
        self._next_id = 0
        self._free: List[int] = engine.free_slots()
        self.in_flight: Dict[int, Request] = {}       # slot -> request
        self.completed: List[Request] = []
        self.stats = {"decode_steps": 0, "admissions": 0,
                      "instant_finishes": 0, "slot_reuses": 0,
                      "rejected": 0, "occupancy_sum": 0}

    # ------------------------------------------------------------------
    def submit(self, prompt: str, **kw) -> Request:
        req = Request(self._next_id, prompt, **kw)
        self._next_id += 1
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    def _admit(self) -> List[Request]:
        """Fill free slots from the queue head; returns requests that
        completed during admission (rejections and instant finishes)."""
        done: List[Request] = []
        budget = (len(self._free) if self.max_admissions is None
                  else min(self.max_admissions, len(self._free)))
        while self._queue and budget > 0:
            slot = self._free.pop()
            req = self._queue.popleft()
            try:
                res = self.engine.admit_slot(
                    slot, req.prompt, max_new_tokens=req.max_new_tokens,
                    use_recycling=req.use_recycling, admit=req.admit,
                    temperature=req.temperature, top_k=req.top_k)
            except ValueError as e:
                # reject THIS request (e.g. longer than the pool capacity)
                # without dropping the rest of the queue or the slot
                self._free.append(slot)
                req.error = str(e)
                self.completed.append(req)
                self.stats["rejected"] += 1
                done.append(req)
                continue
            except Exception:
                self._free.append(slot)      # don't leak the slot
                raise
            self.stats["admissions"] += 1
            budget -= 1    # admission work happened either way (a staged
            #                prefill ran, or chunk steps were queued)
            if res is not None:                       # finished at token 0
                req.result = res
                self.completed.append(req)
                self.stats["instant_finishes"] += 1
                self._free.append(slot)
                done.append(req)
                continue
            self.in_flight[slot] = req
        return done

    def step(self) -> List[Request]:
        """Admit into free slots, then advance every in-flight request one
        token.  Returns the requests that completed this step (including
        admission-time completions: rejections and instant finishes)."""
        finished: List[Request] = list(self._admit())
        decoded = bool(self.in_flight)
        self.stats["occupancy_sum"] += len(self.in_flight)
        for slot, result in self.engine.decode_batch():
            req = self.in_flight.pop(slot)
            req.result = result
            self.completed.append(req)
            finished.append(req)
            if self._queue:
                self.stats["slot_reuses"] += 1
            self._free.append(slot)
        self.stats["decode_steps"] += int(decoded)
        return finished

    def run(self) -> List[Request]:
        while self._queue or self.in_flight:
            self.step()
        return self.completed

    # ------------------------------------------------------------------
    def mean_occupancy(self) -> float:
        steps = max(self.stats["decode_steps"], 1)
        return self.stats["occupancy_sum"] / steps
