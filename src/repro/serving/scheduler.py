"""Request scheduling: FIFO with shape-compatible micro-batching.

Full continuous batching is out of scope for a single-host CPU runtime; what
ships here is honest: requests whose *suffix* token count (after recycling)
and cache capacity land in the same bucket are decoded together by stacking
their per-request caches along the batch axis, others run serially.  The
bucketing exists for the same reason as the engine's capacity rounding:
static shapes = stable compiled executables on TPU.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

from repro.serving.engine import Engine, GenResult


@dataclass
class Request:
    request_id: int
    prompt: str
    max_new_tokens: Optional[int] = None
    use_recycling: bool = True
    admit: bool = False
    submitted_at: float = field(default_factory=time.perf_counter)
    result: Optional[GenResult] = None

    @property
    def done(self) -> bool:
        return self.result is not None


class FIFOScheduler:
    def __init__(self, engine: Engine, *, max_batch: int = 8):
        self.engine = engine
        self.max_batch = max_batch
        self._queue: Deque[Request] = deque()
        self._next_id = 0
        self.completed: List[Request] = []

    def submit(self, prompt: str, **kw) -> Request:
        req = Request(self._next_id, prompt, **kw)
        self._next_id += 1
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    def step(self) -> List[Request]:
        """Serve up to max_batch requests from the queue head (currently
        sequential generate calls; the engine's jit cache makes same-bucket
        requests reuse one executable)."""
        served = []
        while self._queue and len(served) < self.max_batch:
            req = self._queue.popleft()
            req.result = self.engine.generate(
                req.prompt, max_new_tokens=req.max_new_tokens,
                use_recycling=req.use_recycling, admit=req.admit)
            served.append(req)
            self.completed.append(req)
        return served

    def run(self) -> List[Request]:
        while self._queue:
            self.step()
        return self.completed
