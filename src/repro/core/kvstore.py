"""Host-resident KV cache store with disk serialization.

Paper §3.4: "We store KVs per layer on the CPU (torch.save) and reload them
together with the cached prompt's token IDs to enable exact prefix checks."
Here an entry is the model's whole cache pytree (any family: attention KV,
MLA latent, recurrent state) moved to host numpy, keyed by an integer id,
with byte accounting and LRU order for eviction.

Since the paged device pool (PR 2) this store is the **L2 tier** of a
two-level cache: the paged engine serves warm prefixes from device-resident
pool blocks (L1, ``core.radix.BlockTrie``) and only falls back here on an
L1 miss, promoting the prefix back to device in block-granular chunks.
Cold entries spill out of L1 under allocator pressure while their host copy
survives; per-entry ``hits``/``last_hit`` plus ``stats`` make the tier's
traffic observable (serving stats and benchmarks report them).

Entries may be stored quantized: ``repro.core.quant`` (the int8 scheme
shared with the device tier) turns float leaves into int8 + per-vector
scales with a full-precision residual tail, and this store only does the
byte accounting — ``CacheEntry.nbytes`` always reflects the
post-quantization size, including the metadata leaves.

Disk format: one ``<id>.npz`` per entry ('/'-joined tree paths as npz keys
— quantized ``__q8__``/scale/tail leaves round-trip bit-exactly) plus a
json sidecar with text/tokens/length and the LRU/tier state (hits,
last_hit, clock).  ``load_dir`` enforces the byte budget on load and
restores the tier state, so a reload neither exceeds ``max_bytes`` nor
resets every entry to stone cold.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np


def flatten_cache(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_cache(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def unflatten_cache(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for path, arr in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return root


def to_host(cache) -> Any:
    """Device pytree -> host numpy pytree (the paper's ``.to(cpu)``)."""
    return jax.tree.map(lambda a: np.asarray(jax.device_get(a)), cache)


def tree_bytes(tree) -> int:
    return sum(np.asarray(a).nbytes for a in jax.tree.leaves(tree))


def cache_digest(cache) -> str:
    """Content digest of a host cache pytree: blake2b over the sorted
    flattened leaves (path bytes + raw array bytes).  Pure function of
    leaf contents, so it survives a save_dir/load_dir round-trip and
    catches any in-memory or on-disk corruption of the KV payload."""
    h = hashlib.blake2b(digest_size=16)
    for path, arr in sorted(flatten_cache(cache).items()):
        h.update(path.encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# int8 host-cache compression now lives in ``repro.core.quant`` (one scheme
# shared with the device tier — dense ``kv_quant`` caches and the int8 paged
# pool).  Re-exported here for back-compat: this module was its home first.
# ---------------------------------------------------------------------------
from repro.core.quant import (_QKEY, NO_COMPRESS as _NO_COMPRESS,  # noqa: F401
                              dequantize_tree, is_quantized, quantize_tree)


@dataclass
class CacheEntry:
    entry_id: int
    text: str
    token_ids: np.ndarray        # (k,) int32 — enables the exact prefix test
    cache: Any                   # host numpy cache pytree
    length: int                  # tokens covered (reuse depth ceiling)
    capacity: int                # slot capacity of the attention buffers
    nbytes: int = 0
    hits: int = 0                # times this entry served a lookup (tiering)
    last_hit: int = -1           # store clock at the last touching get()
    tenant: Optional[str] = None  # owner for per-tenant byte quotas
    digest: str = ""             # blake2b of the cache leaves (corruption check)

    def __post_init__(self):
        if not self.nbytes:
            self.nbytes = tree_bytes(self.cache)
        if not self.digest:
            self.digest = cache_digest(self.cache)


class HostKVStore:
    """LRU-ordered entry store with a byte budget.

    The budget is enforced at EVERY ``put`` (it used to be enforced only
    by ``Recycler.admit`` calling ``evict_to_budget`` afterwards, so
    direct store users could exceed ``max_bytes`` indefinitely).  Callers
    that mirror the store's contents elsewhere (the recycler's embedding
    index / radix / LSH) register ``on_evict`` so entries evicted inside
    ``put`` never go stale in the mirrors.  Invariant (property-tested):
    ``total_bytes == sum(e.nbytes for e in entries)`` after any mix of
    put / get / remove / evict_to_budget.
    """

    def __init__(self, max_bytes: Optional[int] = None):
        self.max_bytes = max_bytes
        self._entries: "OrderedDict[int, CacheEntry]" = OrderedDict()
        self._next_id = 0
        self.total_bytes = 0
        self.evictions = 0
        self._clock = 0                        # touching-get counter
        self.stats = {"peeks": 0, "hits": 0}   # L2-tier traffic
        self.load_corrupt_skips = 0            # bad disk entries skipped
        # optional core.faults.FaultPlan; sites: kvstore_get, kvstore_put
        # (raise InjectedFault — IO errors), kvstore_corrupt (bit-flip a
        # byte of the served entry's cache, caught by the digest check)
        self.fault_plan = None
        # per-tenant byte usage (entries with tenant=None are untracked):
        # what the scheduler's admit-time quota check reads.  Maintained
        # by put/remove/evict so it always equals the sum of that
        # tenant's live entries' nbytes.
        self.tenant_bytes: Dict[str, int] = {}
        # called with each evicted entry_id (budget eviction only, not
        # explicit remove()); lets index mirrors stay consistent even when
        # eviction fires inside put()
        self.on_evict: Optional[Callable[[int], None]] = None
        # The store is the SHARED L2 of the mesh-sharded server: N engine
        # replicas admit/promote through one instance from their own
        # threads.  This reentrant lock makes each mutation (put + its
        # budget eviction, touching get, remove) atomic; cross-structure
        # consistency with the recycler's retrieval mirrors is the
        # caller's job (ShardedServer serializes whole recycler ops).
        self.lock = threading.RLock()

    def __len__(self):
        return len(self._entries)

    def __contains__(self, entry_id: int):
        return entry_id in self._entries

    def ids(self) -> List[int]:
        return list(self._entries.keys())

    def entries(self) -> List[CacheEntry]:
        """Current entries in LRU order (coldest first), without touching
        recency or peek stats — for rebuilding retrieval mirrors."""
        return list(self._entries.values())

    def tenant_usage(self, tenant: str) -> int:
        """Live bytes held by ``tenant``'s entries (0 for unknown)."""
        with self.lock:
            return self.tenant_bytes.get(tenant, 0)

    def _untrack_tenant(self, e: CacheEntry) -> None:
        if e.tenant is not None:
            left = self.tenant_bytes.get(e.tenant, 0) - e.nbytes
            if left > 0:
                self.tenant_bytes[e.tenant] = left
            else:
                self.tenant_bytes.pop(e.tenant, None)

    def put(self, text: str, token_ids, cache, length: int,
            capacity: Optional[int] = None,
            tenant: Optional[str] = None) -> CacheEntry:
        token_ids = np.asarray(token_ids, np.int32)
        if self.fault_plan is not None:
            self.fault_plan.maybe_fire("kvstore_put", "injected: host-store "
                                       "write IO error")
        with self.lock:
            entry = CacheEntry(self._next_id, text, token_ids, cache,
                               int(length), int(capacity or length),
                               tenant=tenant)
            self._next_id += 1
            self._entries[entry.entry_id] = entry
            self.total_bytes += entry.nbytes
            if tenant is not None:
                self.tenant_bytes[tenant] = (
                    self.tenant_bytes.get(tenant, 0) + entry.nbytes)
            # enforce the byte budget HERE, not just in Recycler.admit —
            # the new entry is MRU, so it is evicted only if it alone
            # exceeds the whole budget (in which case the store honestly
            # refuses to hold it rather than blowing the budget)
            self.evict_to_budget()
            return entry

    def get(self, entry_id: int, *, touch: bool = True) -> CacheEntry:
        """``touch=True`` marks a *served hit*: LRU order moves, and the
        entry's tier accounting (hits / last_hit) is stamped.  Peeking
        candidates during retrieval uses touch=False and only counts as a
        peek, so hits / (hits + peeks-that-missed) stays meaningful."""
        if self.fault_plan is not None:
            self.fault_plan.maybe_fire("kvstore_get", "injected: host-store "
                                       "read IO error")
        with self.lock:
            e = self._entries[entry_id]
            if (self.fault_plan is not None
                    and self.fault_plan.should_fire("kvstore_corrupt")):
                self._corrupt_entry(e)
            if touch:
                self._entries.move_to_end(entry_id)
                self._clock += 1
                e.hits += 1
                e.last_hit = self._clock
                self.stats["hits"] += 1
            else:
                self.stats["peeks"] += 1
            return e

    @staticmethod
    def _corrupt_entry(e: CacheEntry) -> None:
        """Bit-flip one byte of the entry's first non-empty cache leaf
        (simulated silent corruption).  The leaf is REPLACED with the
        flipped copy — host caches often hold read-only views of device
        exports, so in-place mutation is not guaranteed to work.
        ``e.digest`` is left at its original value, so the downstream
        digest check catches the flip."""
        def walk(node) -> bool:
            for k in sorted(node):
                v = node[k]
                if isinstance(v, dict):
                    if walk(v):
                        return True
                elif getattr(v, "nbytes", 0) > 0:
                    bad = np.ascontiguousarray(np.asarray(v)).copy()
                    bad.view(np.uint8).reshape(-1)[0] ^= 0xFF
                    node[k] = bad
                    return True
            return False
        walk(e.cache)

    def remove(self, entry_id: int) -> None:
        with self.lock:
            e = self._entries.pop(entry_id, None)
            if e is not None:
                self.total_bytes -= e.nbytes
                self._untrack_tenant(e)

    def evict_to_budget(self) -> List[int]:
        """Evict LRU entries until under max_bytes; returns evicted ids."""
        with self.lock:
            evicted = []
            if self.max_bytes is None:
                return evicted
            while self.total_bytes > self.max_bytes and self._entries:
                eid, e = self._entries.popitem(last=False)
                self.total_bytes -= e.nbytes
                self._untrack_tenant(e)
                self.evictions += 1
                evicted.append(eid)
                if self.on_evict is not None:
                    self.on_evict(eid)
            return evicted

    # ---- disk ----------------------------------------------------------
    def save_dir(self, path: str) -> None:
        """Entries are written in LRU order (coldest first) — the same
        order ``_entries`` maintains — so a reload under a byte budget
        keeps the hottest entries.  The json sidecar carries the tier
        state (hits / last_hit / clock) so a reload doesn't reset every
        entry to stone cold."""
        os.makedirs(path, exist_ok=True)
        meta = {}
        for eid, e in self._entries.items():
            np.savez(os.path.join(path, f"{eid}.npz"), **flatten_cache(e.cache))
            meta[str(eid)] = {
                "text": e.text,
                "token_ids": e.token_ids.tolist(),
                "length": e.length,
                "capacity": e.capacity,
                "hits": e.hits,
                "last_hit": e.last_hit,
                "tenant": e.tenant,
                "digest": e.digest,
            }
        with open(os.path.join(path, "index.json"), "w") as f:
            json.dump({"next_id": self._next_id, "clock": self._clock,
                       "entries": meta}, f)

    @classmethod
    def load_dir(cls, path: str, max_bytes: Optional[int] = None
                 ) -> "HostKVStore":
        """Reload a saved store.  The byte budget is enforced on load —
        previously a reload could exceed ``max_bytes`` indefinitely (no
        eviction ran until the next put) — and LRU/tier state (hits,
        last_hit, clock) round-trips through the sidecar instead of
        resetting to zero.  Quantized entries round-trip bit-exactly: npz
        stores the ``__q8__``/scale/dtype leaves verbatim.

        Corruption-hardened: a missing / truncated / unparseable npz, or
        one whose content digest no longer matches the sidecar's recorded
        ``digest``, skips that entry (counted in ``load_corrupt_skips``)
        instead of raising — one bad file must not take down a reload of
        the whole L2."""
        store = cls(max_bytes)
        with open(os.path.join(path, "index.json")) as f:
            meta = json.load(f)
        for eid_s, m in meta["entries"].items():
            eid = int(eid_s)
            try:
                with np.load(os.path.join(path, f"{eid}.npz")) as z:
                    cache = unflatten_cache({k: z[k] for k in z.files})
            except Exception:
                store.load_corrupt_skips += 1
                continue
            e = CacheEntry(eid, m["text"], np.asarray(m["token_ids"], np.int32),
                           cache, m["length"], m["capacity"],
                           hits=m.get("hits", 0),
                           last_hit=m.get("last_hit", -1),
                           tenant=m.get("tenant"))
            want = m.get("digest")
            if want and e.digest != want:
                store.load_corrupt_skips += 1
                continue
            store._entries[eid] = e
            store.total_bytes += e.nbytes
            if e.tenant is not None:
                store.tenant_bytes[e.tenant] = (
                    store.tenant_bytes.get(e.tenant, 0) + e.nbytes)
        store._next_id = meta["next_id"]
        store._clock = meta.get("clock", 0)
        store.evict_to_budget()
        return store
