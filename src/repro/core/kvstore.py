"""Host-resident KV cache store with disk serialization.

Paper §3.4: "We store KVs per layer on the CPU (torch.save) and reload them
together with the cached prompt's token IDs to enable exact prefix checks."
Here an entry is the model's whole cache pytree (any family: attention KV,
MLA latent, recurrent state) moved to host numpy, keyed by an integer id,
with byte accounting and LRU order for eviction.

Since the paged device pool (PR 2) this store is the **L2 tier** of a
two-level cache: the paged engine serves warm prefixes from device-resident
pool blocks (L1, ``core.radix.BlockTrie``) and only falls back here on an
L1 miss, promoting the prefix back to device in block-granular chunks.
Cold entries spill out of L1 under allocator pressure while their host copy
survives; per-entry ``hits``/``last_hit`` plus ``stats`` make the tier's
traffic observable (serving stats and benchmarks report them).

Disk format: one ``<id>.npz`` per entry ('/'-joined tree paths as npz keys)
plus a json sidecar with text/tokens/length — transparent and reloadable
across sessions, like the paper's CSV+torch.save layout.
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def flatten_cache(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_cache(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def unflatten_cache(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for path, arr in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return root


def to_host(cache) -> Any:
    """Device pytree -> host numpy pytree (the paper's ``.to(cpu)``)."""
    return jax.tree.map(lambda a: np.asarray(jax.device_get(a)), cache)


def tree_bytes(tree) -> int:
    return sum(np.asarray(a).nbytes for a in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# int8 host-cache compression (beyond paper; cf. its CacheGen citation).
# The paper notes host caches "grow large" (§6.1); symmetric per-vector int8
# halves bf16 KV bytes (4x for f32) at ~0.4% RMS error — recycled outputs
# stay semantically identical (validated in tests/benchmarks).
# ---------------------------------------------------------------------------
_QKEY = "__q8__"
_NO_COMPRESS = {"slot_pos"}


def quantize_tree(tree):
    """Float leaves -> {_QKEY: int8, "scale": f32 per last-dim vector}."""
    def walk(t, name=None):
        if isinstance(t, dict):
            return {k: walk(v, k) for k, v in t.items()}
        a = np.asarray(t)
        if name in _NO_COMPRESS or not np.issubdtype(a.dtype, np.floating):
            return a
        amax = np.max(np.abs(a.astype(np.float32)), axis=-1, keepdims=True)
        scale = (amax / 127.0 + 1e-12).astype(np.float32)
        q = np.clip(np.round(a.astype(np.float32) / scale), -127, 127)
        return {_QKEY: q.astype(np.int8), "scale": scale,
                "dtype": np.dtype(a.dtype).str}
    return walk(tree)


def dequantize_tree(tree):
    def walk(t):
        if isinstance(t, dict):
            if _QKEY in t:
                dt = t["dtype"]
                dt = dt.item() if hasattr(dt, "item") else dt
                a = t[_QKEY].astype(np.float32) * t["scale"]
                return a.astype(np.dtype(str(dt)))
            return {k: walk(v) for k, v in t.items()}
        return t
    return walk(tree)


def is_quantized(tree) -> bool:
    def walk(t):
        if isinstance(t, dict):
            return _QKEY in t or any(walk(v) for v in t.values())
        return False
    return walk(tree)


@dataclass
class CacheEntry:
    entry_id: int
    text: str
    token_ids: np.ndarray        # (k,) int32 — enables the exact prefix test
    cache: Any                   # host numpy cache pytree
    length: int                  # tokens covered (reuse depth ceiling)
    capacity: int                # slot capacity of the attention buffers
    nbytes: int = 0
    hits: int = 0                # times this entry served a lookup (tiering)
    last_hit: int = -1           # store clock at the last touching get()

    def __post_init__(self):
        if not self.nbytes:
            self.nbytes = tree_bytes(self.cache)


class HostKVStore:
    """LRU-ordered entry store with a byte budget."""

    def __init__(self, max_bytes: Optional[int] = None):
        self.max_bytes = max_bytes
        self._entries: "OrderedDict[int, CacheEntry]" = OrderedDict()
        self._next_id = 0
        self.total_bytes = 0
        self.evictions = 0
        self._clock = 0                        # touching-get counter
        self.stats = {"peeks": 0, "hits": 0}   # L2-tier traffic

    def __len__(self):
        return len(self._entries)

    def __contains__(self, entry_id: int):
        return entry_id in self._entries

    def ids(self) -> List[int]:
        return list(self._entries.keys())

    def put(self, text: str, token_ids, cache, length: int,
            capacity: Optional[int] = None) -> CacheEntry:
        token_ids = np.asarray(token_ids, np.int32)
        entry = CacheEntry(self._next_id, text, token_ids, cache,
                           int(length), int(capacity or length))
        self._next_id += 1
        self._entries[entry.entry_id] = entry
        self.total_bytes += entry.nbytes
        return entry

    def get(self, entry_id: int, *, touch: bool = True) -> CacheEntry:
        """``touch=True`` marks a *served hit*: LRU order moves, and the
        entry's tier accounting (hits / last_hit) is stamped.  Peeking
        candidates during retrieval uses touch=False and only counts as a
        peek, so hits / (hits + peeks-that-missed) stays meaningful."""
        e = self._entries[entry_id]
        if touch:
            self._entries.move_to_end(entry_id)
            self._clock += 1
            e.hits += 1
            e.last_hit = self._clock
            self.stats["hits"] += 1
        else:
            self.stats["peeks"] += 1
        return e

    def remove(self, entry_id: int) -> None:
        e = self._entries.pop(entry_id, None)
        if e is not None:
            self.total_bytes -= e.nbytes

    def evict_to_budget(self) -> List[int]:
        """Evict LRU entries until under max_bytes; returns evicted ids."""
        evicted = []
        if self.max_bytes is None:
            return evicted
        while self.total_bytes > self.max_bytes and self._entries:
            eid, e = self._entries.popitem(last=False)
            self.total_bytes -= e.nbytes
            self.evictions += 1
            evicted.append(eid)
        return evicted

    # ---- disk ----------------------------------------------------------
    def save_dir(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        meta = {}
        for eid, e in self._entries.items():
            np.savez(os.path.join(path, f"{eid}.npz"), **flatten_cache(e.cache))
            meta[str(eid)] = {
                "text": e.text,
                "token_ids": e.token_ids.tolist(),
                "length": e.length,
                "capacity": e.capacity,
            }
        with open(os.path.join(path, "index.json"), "w") as f:
            json.dump({"next_id": self._next_id, "entries": meta}, f)

    @classmethod
    def load_dir(cls, path: str, max_bytes: Optional[int] = None
                 ) -> "HostKVStore":
        store = cls(max_bytes)
        with open(os.path.join(path, "index.json")) as f:
            meta = json.load(f)
        for eid_s, m in meta["entries"].items():
            eid = int(eid_s)
            with np.load(os.path.join(path, f"{eid}.npz")) as z:
                cache = unflatten_cache({k: z[k] for k in z.files})
            e = CacheEntry(eid, m["text"], np.asarray(m["token_ids"], np.int32),
                           cache, m["length"], m["capacity"])
            store._entries[eid] = e
            store.total_bytes += e.nbytes
        store._next_id = meta["next_id"]
        return store
