"""End-to-end serving engine: the paper's evaluation loop on a reduced
DialoGPT, plus beyond-paper behaviours (admission, partial hits, eviction).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import HostKVStore, Recycler
from repro.data.pipeline import CACHE_PROMPTS, TEST_PROMPTS
from repro.models import init_params
from repro.serving import Engine, FIFOScheduler


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new_tokens=8, block_size=16)
    eng.precache(CACHE_PROMPTS[:4])
    return eng


def test_precache_populates_store(engine):
    assert len(engine.recycler.store) == 4


def test_recycled_output_identical_to_baseline(engine):
    """Paper §5.4: recycled generations preserve content; with greedy
    decoding and exact-prefix reuse they are bit-identical here."""
    for p in TEST_PROMPTS[:2]:
        base = engine.generate(p, use_recycling=False)
        rec = engine.generate(p)
        assert rec.cache_hit and rec.mode == "exact_prefix"
        assert rec.reuse_depth > 0
        assert rec.text == base.text
        assert rec.prompt_tokens == base.prompt_tokens


def test_no_overlap_behaves_like_baseline(engine):
    res = engine.generate("zzz qqq completely unrelated 12345")
    assert not res.cache_hit and res.reuse_depth == 0


def test_admission_enables_multiturn_reuse(engine):
    p = "tell me about rivers"
    r1 = engine.generate(p, admit=True)
    follow = p + engine.tok.decode([]) + ""  # same text, extended below
    r2 = engine.generate(p + " and lakes too")
    assert r2.cache_hit
    # reuse depth should cover the whole first prompt
    assert r2.reuse_depth >= r1.prompt_tokens - 1


def test_stats_accumulate(engine):
    s = engine.stats
    assert s["requests"] > 0
    assert s["tokens_reused"] > 0
    assert s["hits"] <= s["requests"]


def test_scheduler_fifo():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new_tokens=4, block_size=16)
    sched = FIFOScheduler(eng, max_batch=2)
    reqs = [sched.submit(p) for p in CACHE_PROMPTS[:3]]
    done = sched.run()
    assert len(done) == 3
    assert all(r.done for r in reqs)
    assert all(r.result.gen_tokens > 0 for r in reqs)


def test_partial_block_reuse_engine():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new_tokens=4, block_size=8,
                 enable_partial=True)
    eng.precache(["the quick brown fox jumps over the lazy dog today"])
    # shares a long text prefix but diverges before the end -> radix hit
    res = eng.generate("the quick brown fox jumps over a red fence")
    base = eng.generate("the quick brown fox jumps over a red fence",
                        use_recycling=False)
    assert res.mode in ("partial_block", "exact_prefix")
    assert res.cache_hit and res.reuse_depth >= 8
    assert res.text == base.text
