"""Quickstart: cross-prompt KV cache recycling in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced DialoGPT-style model, caches one prompt's KV states, then
serves an extended prompt — the engine retrieves the cached prefix by
embedding similarity, verifies the exact token-prefix condition, and
prefills only the suffix (the paper's "token recycling").
"""
import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving import Engine

cfg = get_config("dialogpt-medium").reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
engine = Engine(cfg, params, max_new_tokens=16)

# Phase 1 (paper §4.4 "Cache Construction"): one forward pass per cache
# prompt with caching enabled; KVs serialized to host memory.
engine.precache(["What is the capital of France?"])
print(f"cached entries: {len(engine.recycler.store)}, "
      f"{engine.recycler.store.total_bytes/1e6:.2f} MB on host")

# Phase 2: a new prompt that EXTENDS the cached one.
prompt = "What is the capital of France? Also mention a nearby tourist spot."

baseline = engine.generate(prompt, use_recycling=False)
recycled = engine.generate(prompt)

print(f"\nprompt tokens       : {recycled.prompt_tokens}")
print(f"recycled (reused)   : {recycled.reuse_depth} tokens "
      f"[mode={recycled.mode}, retrieval sim={recycled.prompt_similarity:.2f}]")
print(f"baseline latency    : {baseline.latency_s*1e3:.1f} ms")
print(f"recycled latency    : {recycled.latency_s*1e3:.1f} ms")
print(f"outputs identical   : {baseline.text == recycled.text}")
