"""Slot-pool invariants: slot free/reuse after EOS, mid-flight admission,
and per-slot length masking never attending across pool rows.

The masking tests exercise the layer primitives directly (attend_direct /
cache_write_batched / the Pallas batched decode kernel) so a cross-slot leak
is localized to the attention math rather than surfacing as a generation
diff three layers up.  Property-style variants run only when hypothesis is
installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.models.attention import (attend_direct, cache_write_batched,
                                    init_kv_cache)
from repro.serving import BatchedEngine, ContinuousBatchingScheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# attention-level isolation
# ---------------------------------------------------------------------------
def _row_state(rng, B, C, hkv, dh, lens):
    k = jnp.asarray(rng.normal(size=(B, C, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, C, hkv, dh)), jnp.float32)
    sp = np.full((B, C), -1, np.int32)
    for b, L in enumerate(lens):
        sp[b, :L] = np.arange(L)
    return k, v, jnp.asarray(sp)


def test_per_slot_masking_matches_single_row():
    """Each pool row's attention equals the same computation run alone."""
    rng = np.random.default_rng(0)
    B, C, H, hkv, dh = 4, 16, 4, 2, 8
    lens = [3, 16, 1, 9]
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
    k, v, sp = _row_state(rng, B, C, hkv, dh, lens)
    pos = jnp.asarray([L - 1 for L in lens], jnp.int32)

    batched = attend_direct(q, k, v, pos[:, None], sp, causal=True)
    for b in range(B):
        solo = attend_direct(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                             pos[b:b + 1, None], sp[b:b + 1], causal=True)
        np.testing.assert_allclose(batched[b], solo[0], rtol=0, atol=1e-6)


def test_perturbing_other_rows_never_changes_a_row():
    """Bit-exact isolation: scribbling over every other row's K/V and
    slot_pos leaves row 0's output unchanged."""
    rng = np.random.default_rng(1)
    B, C, H, hkv, dh = 3, 8, 2, 2, 4
    lens = [5, 8, 2]
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
    k, v, sp = _row_state(rng, B, C, hkv, dh, lens)
    pos = jnp.asarray([L - 1 for L in lens], jnp.int32)
    out = attend_direct(q, k, v, pos[:, None], sp, causal=True)

    k2 = k.at[1:].set(999.0)
    v2 = v.at[1:].set(-999.0)
    sp2 = sp.at[1:].set(0)                        # all slots "valid" pos 0
    out2 = attend_direct(q, k2, v2, pos[:, None], sp2, causal=True)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out2[0]))


def test_cache_write_batched_targets_own_row_only():
    cache = init_kv_cache(3, 8, 2, 4, jnp.float32, per_slot=True)
    k_new = jnp.ones((3, 1, 2, 4), jnp.float32)
    pos = jnp.asarray([0, 5, 7], jnp.int32)
    out = cache_write_batched(cache, k_new, 2 * k_new, pos)
    sp = np.asarray(out["slot_pos"])
    for b, p in enumerate([0, 5, 7]):
        row = np.full(8, -1)
        row[p % 8] = p
        np.testing.assert_array_equal(sp[b], row)
        assert np.asarray(out["k"])[b, p % 8].sum() == 8  # 2*4 ones
    # no row wrote anywhere else
    mask = np.ones((3, 8), bool)
    mask[[0, 1, 2], [0, 5, 7]] = False
    assert np.asarray(out["k"])[mask].sum() == 0


def test_pallas_batched_decode_matches_reference():
    from repro.kernels import ops
    rng = np.random.default_rng(2)
    B, C, H, hkv, dh = 3, 32, 4, 2, 8
    lens = [5, 17, 32]
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
    k, v, sp = _row_state(rng, B, C, hkv, dh, lens)
    pos = jnp.asarray([L - 1 for L in lens], jnp.int32)
    out = ops.decode_attention_batched(q, k, v, sp, pos, interpret=True)
    ref = attend_direct(q, k, v, pos[:, None], sp, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    outw = ops.decode_attention_batched(q, k, v, sp, pos, window=8,
                                        interpret=True)
    refw = attend_direct(q, k, v, pos[:, None], sp, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(outw), np.asarray(refw), atol=1e-5)


# ---------------------------------------------------------------------------
# pool lifecycle
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_slot_free_and_reuse(stack):
    """More requests than slots: finished rows are freed and refilled until
    the queue drains; afterwards every slot is free again."""
    cfg, params = stack
    eng = BatchedEngine(cfg, params, max_batch=2, capacity=64,
                        max_new_tokens=3, block_size=8)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(f"prompt number {i}") for i in range(5)]
    done = sched.run()
    assert len(done) == 5 and all(r.done for r in reqs)
    assert all(r.result.gen_tokens > 0 for r in reqs)
    assert sched.stats["slot_reuses"] >= 3        # 5 requests, 2 slots
    assert eng.free_slots() == [0, 1]
    assert not sched.in_flight and sched.pending() == 0


def test_midflight_admission(stack):
    """A request admitted after decoding started (slot freed by a short
    budget) completes with the same output as a fresh pool would give."""
    cfg, params = stack
    eng = BatchedEngine(cfg, params, max_batch=2, capacity=64,
                        max_new_tokens=6, block_size=8)
    sched = ContinuousBatchingScheduler(eng)
    sched.submit("a long running request", max_new_tokens=6)
    sched.submit("short one", max_new_tokens=2)
    late = sched.submit("late arrival joins mid flight", max_new_tokens=4)
    sched.run()

    eng2 = BatchedEngine(cfg, params, max_batch=2, capacity=64,
                         max_new_tokens=6, block_size=8)
    sched2 = ContinuousBatchingScheduler(eng2)
    alone = sched2.submit("late arrival joins mid flight", max_new_tokens=4)
    sched2.run()
    assert late.result.text == alone.result.text
    np.testing.assert_array_equal(late.result.token_ids,
                                  alone.result.token_ids)


def test_oversize_request_rejected(stack):
    cfg, params = stack
    eng = BatchedEngine(cfg, params, max_batch=2, capacity=16,
                        max_new_tokens=8, block_size=8)
    with pytest.raises(ValueError):
        eng.admit_slot(0, "this prompt is far too long for a 16-slot pool")


def test_oversize_request_fails_alone_not_the_run(stack):
    """One too-long request must be rejected (error recorded) without
    aborting the scheduler or starving the rest of the queue."""
    cfg, params = stack
    eng = BatchedEngine(cfg, params, max_batch=2, capacity=32,
                        max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(eng)
    ok1 = sched.submit("short a")
    bad = sched.submit("this prompt is definitely far too long to ever fit "
                       "into a thirty-two slot pool row")
    ok2 = sched.submit("short b")
    done = sched.run()
    assert len(done) == 3
    assert bad.done and bad.result is None and "capacity" in bad.error
    assert ok1.result.gen_tokens > 0 and ok2.result.gen_tokens > 0
    assert sched.stats["rejected"] == 1
    assert eng.free_slots() == [0, 1]         # rejection leaked no slot


def test_instant_finish_returned_by_step(stack):
    """max_new_tokens=1 finishes at admission; step() must still hand the
    completed request back to a caller driving the scheduler manually."""
    cfg, params = stack
    eng = BatchedEngine(cfg, params, max_batch=2, capacity=32,
                        max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(eng)
    req = sched.submit("hello", max_new_tokens=1)
    finished = sched.step()
    assert req in finished and req.result.gen_tokens == 1
    assert sched.stats["instant_finishes"] == 1
    assert sched.stats["decode_steps"] == 0   # nothing was in flight


def test_zero_admission_budget_rejected(stack):
    cfg, params = stack
    eng = BatchedEngine(cfg, params, max_batch=2, capacity=32,
                        max_new_tokens=4, block_size=8)
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(eng, max_admissions_per_step=0)


def test_pool_admission_matches_serial_footprint(stack):
    """admit=True from the pool must store the same bucketed cache width as
    the serial engine, not the full pool width (host-KV byte parity)."""
    cfg, params = stack
    from repro.serving import Engine
    p = "tell me about rivers"
    ser = Engine(cfg, params, max_new_tokens=4, block_size=8)
    ser.generate(p, admit=True)
    eng = BatchedEngine(cfg, params, max_batch=2, capacity=128,
                        max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(eng)
    sched.submit(p, admit=True)
    sched.run()
    (se,) = ser.recycler.store._entries.values()
    (be,) = eng.recycler.store._entries.values()
    assert be.cache["seg0"]["slot_pos"].shape == \
        se.cache["seg0"]["slot_pos"].shape
    assert be.nbytes == se.nbytes
    # and the shrunken entry still serves a hit
    follow = eng.recycler.lookup(p + " and lakes",
                                 eng.tok.encode(p + " and lakes"))
    assert follow.hit


def test_windowed_pool_recycled_hit(stack):
    """window > 0 shrinks pool rows to ring width; a recycled hit must load
    into the ring-sized row and still match the serial windowed engine."""
    cfg, params = stack
    from repro.serving import Engine
    cached = ["the quick brown fox jumps over the lazy dog"]
    ser = Engine(cfg, params, max_new_tokens=4, block_size=8, window=32)
    ser.precache(cached)
    bat = BatchedEngine(cfg, params, max_batch=2, capacity=64, window=32,
                        max_new_tokens=4, block_size=8)
    bat.precache(cached)
    p = cached[0] + " again"
    base = ser.generate(p)
    sched = ContinuousBatchingScheduler(bat)
    req = sched.submit(p)
    sched.run()
    assert req.result.cache_hit == base.cache_hit
    assert req.result.text == base.text
    np.testing.assert_array_equal(req.result.token_ids, base.token_ids)


def test_per_slot_pool_rejects_stateful_arch(stack):
    from repro.models import init_cache
    cfg = get_config("rwkv6-3b").reduced()
    with pytest.raises(NotImplementedError):
        init_cache(cfg, 2, 32, per_slot=True)


# ---------------------------------------------------------------------------
# property-style isolation (skipped without hypothesis)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    class TestSlotIsolationProperty:
        @given(lens=st.lists(st.integers(1, 16), min_size=2, max_size=5),
               seed=st.integers(0, 2**16))
        @settings(max_examples=25, deadline=None)
        def test_rows_equal_solo_rows(self, lens, seed):
            """For ANY per-row fill lengths, batched attention over a
            per-slot pool equals each row computed alone."""
            rng = np.random.default_rng(seed)
            B, C, H, hkv, dh = len(lens), 16, 2, 1, 4
            q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
            k, v, sp = _row_state(rng, B, C, hkv, dh, lens)
            pos = jnp.asarray([L - 1 for L in lens], jnp.int32)
            batched = attend_direct(q, k, v, pos[:, None], sp, causal=True)
            for b in range(B):
                solo = attend_direct(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                     pos[b:b + 1, None], sp[b:b + 1],
                                     causal=True)
                np.testing.assert_allclose(batched[b], solo[0], atol=1e-6)
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_rows_equal_solo_rows():
        pass
