"""rwkv6-3b — "Finch": attention-free RNN with data-dependent decay.

[arXiv:2404.05892] Eagle and Finch: RWKV with Matrix-Valued States and
Dynamic Recurrence.  32L, d_model=2560, d_ff=8960, vocab=65536; head_dim=64
(40 WKV heads).  Decode state is O(1): recycling restores the (wkv, shift)
state snapshot instead of a KV tensor (DESIGN.md §4).
"""
from repro.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    source="arXiv:2404.05892 (RWKV-6 Finch 3B)",
    num_layers=32,
    d_model=2560,
    num_heads=40,                # 2560 / 64
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    norm="layernorm",
    mlp="gelu_mlp",              # RWKV channel-mix (relu^2 handled in-model)
    sliding_window=0,            # attention-free: native long context
    rwkv=RWKVConfig(head_dim=64),
)
