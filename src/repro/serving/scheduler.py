"""Request scheduling: serial FIFO baseline + continuous batching.

Two schedulers share one Request surface:

``FIFOScheduler`` is the honest serial baseline — ``step()`` pops requests
off the queue and runs ``engine.generate`` one at a time.  It exists as the
reference the batched path is benchmarked (and tested token-for-token)
against.

``ContinuousBatchingScheduler`` drives a pool engine — the dense
``BatchedEngine`` slot pool or the paged ``PagedEngine`` block-table pool,
both behind the same ``free_slots``/``admit_slot``/``decode_batch``
surface: it owns the admission policy (FIFO order, admit-before-decode),
the slot allocator (free list over pool rows), and the in-flight set.  Every
``step()`` first fills free slots from the queue head, then advances ALL
in-flight requests with one ``decode_batch`` call.  What an admission costs
inside that call is the ENGINE's choice: the dense pool (and the paged pool
in ``prefill_mode="staged"``) runs the whole single-row prefill inside
``admit_slot``; the paged pool's chunked default instead queues the
admission and ``decode_batch`` advances it ONE fixed-size chunk per step,
interleaved with the batched decode dispatch — a long prompt admits over
several steps while the resident batch keeps emitting tokens, and its slot
counts as in-flight the whole time (``admit_slot`` returned None).  Rows
that hit EOS or their token budget are freed at the step boundary and the
next ``step()`` refills them mid-flight: the batch never drains to refill,
which is what "continuous" means and where the throughput over the serial
loop comes from (one dispatch per token-step instead of one per request).

Static shapes still rule everything: the pool is a fixed ``[max_batch,
capacity, ...]`` allocation, so the decode executable compiles exactly once
per pool shape regardless of arrival order, mix of hit/miss requests, or
occupancy.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.core.blockpool import PoolSaturated
from repro.serving.engine import BatchedEngine, Engine, GenResult


class RequestOutcome:
    """Typed terminal states.  Shedding and preemption-era failures are
    DATA, not exceptions: callers inspect ``req.outcome`` instead of
    catching anything, and a load test can assert on the exact mix."""
    OK = "ok"
    SHED_QUEUE_FULL = "shed_queue_full"   # bounded queue rejected at submit
    SHED_DEADLINE = "shed_deadline"       # TTL expired before admission
    ERRORED = "errored"                   # permanent reject / engine error


@dataclass
class Request:
    request_id: int
    prompt: str
    max_new_tokens: Optional[int] = None
    use_recycling: bool = True
    admit: bool = False
    # sampling controls; 0 temperature = greedy (the paper's
    # do_sample=False default).  Rows at different temperatures mix
    # freely in one pool dispatch (engine `sample_batched`).
    temperature: float = 0.0
    top_k: int = 0
    # owner for per-tenant L2 byte quotas (threaded engine -> recycler ->
    # HostKVStore; the scheduler's quota check reads the same accounting)
    tenant: Optional[str] = None
    submitted_at: float = field(default_factory=time.perf_counter)
    # SLO clock: enqueue_t stamps at submit (== submitted_at, kept under
    # both names for back-compat), admit_t when a slot is taken,
    # first_token_t when the first token lands.  core.metrics.slo_summary
    # consumes these.
    enqueue_t: float = field(default_factory=time.perf_counter)
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    result: Optional[GenResult] = None
    error: Optional[str] = None          # set when admission rejects it
    # SLO deadline: seconds from submit the request stays worth serving.
    # ``deadline_t`` is the absolute perf_counter stamp; expired requests
    # are shed from the queue BEFORE claiming any pool blocks, and the
    # engine's victim policy prefers preempting the latest deadline.
    deadline_s: Optional[float] = None
    deadline_t: Optional[float] = field(default=None, repr=False)
    outcome: Optional[str] = None        # RequestOutcome.* once terminal
    _ids: Optional[object] = field(default=None, repr=False)  # encode memo
    # preemption resume payload (engine "preempted" event) + requeue count
    _resume: Optional[dict] = field(default=None, repr=False)
    _requeues: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.deadline_s is not None and self.deadline_t is None:
            self.deadline_t = self.enqueue_t + self.deadline_s

    @property
    def done(self) -> bool:
        return self.result is not None or self.error is not None

    @property
    def queue_delay_s(self) -> Optional[float]:
        """Seconds spent queued before admission (None until admitted)."""
        if self.admit_t is None:
            return None
        return self.admit_t - self.enqueue_t


class FIFOScheduler:
    """Serial reference scheduler: one ``engine.generate`` per request."""

    def __init__(self, engine: Engine, *, max_batch: int = 8):
        self.engine = engine
        self.max_batch = max_batch
        self._queue: Deque[Request] = deque()
        self._next_id = 0
        self.completed: List[Request] = []

    def submit(self, prompt: str, **kw) -> Request:
        req = Request(self._next_id, prompt, **kw)
        self._next_id += 1
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    def step(self) -> List[Request]:
        """Serve up to max_batch requests from the queue head, sequentially
        (the engine's jit cache makes same-shape requests share one
        executable, but each still pays its own dispatch per token)."""
        served = []
        while self._queue and len(served) < self.max_batch:
            req = self._queue.popleft()
            req.admit_t = time.perf_counter()
            req.result = self.engine.generate(
                req.prompt, max_new_tokens=req.max_new_tokens,
                use_recycling=req.use_recycling, admit=req.admit,
                temperature=req.temperature, top_k=req.top_k,
                tenant=req.tenant)
            req.first_token_t = req.admit_t + req.result.ttft_s
            req.outcome = RequestOutcome.OK
            served.append(req)
            self.completed.append(req)
        return served

    def run(self) -> List[Request]:
        while self._queue:
            self.step()
        return self.completed


class ContinuousBatchingScheduler:
    """Admission policy + slot allocator over a ``BatchedEngine`` pool."""

    def __init__(self, engine: BatchedEngine, *,
                 max_admissions_per_step: Optional[int] = None,
                 admission_policy: str = "fifo",
                 tenant_quotas: Optional[Dict[str, int]] = None,
                 queue_limit: Optional[int] = None,
                 tenant_queue_limits: Optional[Dict[str, int]] = None,
                 max_requeues: int = 32):
        self.engine = engine
        # at most this many single-row prefills per step before decoding;
        # None = fill every free slot (prefill-heavy but maximal occupancy)
        if max_admissions_per_step is not None and max_admissions_per_step < 1:
            raise ValueError("max_admissions_per_step must be >= 1 (0 would "
                             "make run() spin forever admitting nothing)")
        if admission_policy not in ("fifo", "cache_aware"):
            raise ValueError(f"unknown admission_policy {admission_policy!r}")
        self.max_admissions = max_admissions_per_step
        # "fifo" refills strictly in arrival order; "cache_aware" prefers
        # the queued request with the DEEPEST resident prefix in the
        # engine's block trie (``trie.peek`` — recency untouched), so warm
        # requests admit with near-zero prefill work while the batch is
        # hot.  FIFO breaks ties (strict > comparison), so a queue of
        # all-cold requests degenerates to exact FIFO — no starvation of
        # equally-cold requests, though a steady warm stream can delay a
        # cold one (that's the policy's documented trade).
        self.admission_policy = admission_policy
        # tenant -> max L2 (HostKVStore) bytes.  Enforced at ADMIT time:
        # an over-quota tenant's request still decodes, but its
        # ``admit=True`` is downgraded so it cannot grow the store
        # further.  Serving is never rejected on quota.
        self.tenant_quotas = tenant_quotas
        # bounded backpressure: a full queue sheds AT SUBMIT with a typed
        # outcome instead of growing without bound (None = unbounded, the
        # pre-existing behavior).  ``tenant_queue_limits`` bounds each
        # tenant's share so one flooding tenant cannot occupy the whole
        # global budget.
        if queue_limit is not None and queue_limit < 1:
            raise ValueError("queue_limit must be >= 1")
        self.queue_limit = queue_limit
        self.tenant_queue_limits = tenant_queue_limits or {}
        if max_requeues < 1:
            raise ValueError("max_requeues must be >= 1")
        self.max_requeues = max_requeues
        self._queue: Deque[Request] = deque()
        self._next_id = 0
        self._free: List[int] = engine.free_slots()
        # feature-detect the preemption surface (PagedEngine); the dense
        # BatchedEngine has no resume/deadline kwargs and emits no events
        self._engine_preempts = hasattr(engine, "drain_events")
        self.in_flight: Dict[int, Request] = {}       # slot -> request
        self.completed: List[Request] = []
        self.stats = {"decode_steps": 0, "admissions": 0,
                      "instant_finishes": 0, "slot_reuses": 0,
                      "rejected": 0, "occupancy_sum": 0,
                      "quota_denied_admits": 0, "cache_aware_picks": 0,
                      "shed_queue_full": 0, "shed_deadline": 0,
                      "preemptions": 0, "resumes": 0,
                      "admissions_deferred": 0}

    # ------------------------------------------------------------------
    def _tenant_queued(self, tenant: Optional[str]) -> int:
        return sum(1 for r in self._queue if r.tenant == tenant)

    def submit(self, prompt: str, **kw) -> Request:
        req = Request(self._next_id, prompt, **kw)
        self._next_id += 1
        limit = self.tenant_queue_limits.get(req.tenant)
        if ((self.queue_limit is not None
                and len(self._queue) >= self.queue_limit)
                or (limit is not None
                    and self._tenant_queued(req.tenant) >= limit)):
            req.outcome = RequestOutcome.SHED_QUEUE_FULL
            req.error = "shed: queue full"
            self.completed.append(req)
            self.stats["shed_queue_full"] += 1
            return req
        self._queue.append(req)
        return req

    def _shed_expired(self) -> List[Request]:
        """Drop deadline-expired queued requests BEFORE they claim any
        pool blocks — serving a request that already missed its SLO only
        steals capacity from ones that can still make theirs."""
        now = time.perf_counter()
        shed: List[Request] = []
        if any(r.deadline_t is not None for r in self._queue):
            keep: Deque[Request] = deque()
            for r in self._queue:
                if r.deadline_t is not None and now >= r.deadline_t:
                    r.outcome = RequestOutcome.SHED_DEADLINE
                    r.error = "shed: deadline expired in queue"
                    self.completed.append(r)
                    self.stats["shed_deadline"] += 1
                    shed.append(r)
                else:
                    keep.append(r)
            self._queue = keep
        return shed

    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    def _pop_next(self) -> Request:
        """Select and remove the next request to admit.  FIFO pops the
        queue head; cache_aware scans the queue for the deepest resident
        prefix in the engine's block trie (peek — no recency stamp) and
        falls back to FIFO when the engine has no trie/tokenizer or
        nothing queued is warm (strict > keeps arrival order on ties)."""
        if self._queue and self._queue[0]._resume is not None:
            # a preempted request resumes ahead of new arrivals — its
            # blocks were taken from it, it does not also lose its turn
            return self._queue.popleft()
        if self.admission_policy == "cache_aware" and len(self._queue) > 1:
            trie = getattr(self.engine, "trie", None)
            tok = getattr(self.engine, "tok", None)
            if trie is not None and tok is not None:
                best_i, best_d = 0, -1
                for i, req in enumerate(self._queue):
                    if req._ids is None:
                        req._ids = tok.encode(req.prompt)
                    depth, _ = trie.peek(req._ids)
                    if depth > best_d:
                        best_i, best_d = i, depth
                if best_i > 0:
                    self.stats["cache_aware_picks"] += 1
                    req = self._queue[best_i]
                    del self._queue[best_i]
                    return req
        return self._queue.popleft()

    def _admit_allowed(self, req: Request) -> bool:
        """Admit-time quota gate: False when the request's tenant is at or
        over its L2 byte quota (serving still proceeds, admission to the
        host store is what gets denied)."""
        if not req.admit or not self.tenant_quotas or req.tenant is None:
            return req.admit
        quota = self.tenant_quotas.get(req.tenant)
        if quota is None:
            return True
        store = getattr(getattr(self.engine, "recycler", None), "store", None)
        if store is None or store.tenant_usage(req.tenant) < quota:
            return True
        self.stats["quota_denied_admits"] += 1
        return False

    def _admit(self) -> List[Request]:
        """Fill free slots from the queue; returns requests that
        completed during admission (rejections and instant finishes)."""
        done: List[Request] = []
        budget = (len(self._free) if self.max_admissions is None
                  else min(self.max_admissions, len(self._free)))
        while self._queue and budget > 0:
            slot = self._free.pop()
            req = self._pop_next()
            req.admit_t = req.admit_t or time.perf_counter()
            kw = {}
            if self._engine_preempts:
                kw["deadline_t"] = req.deadline_t
                if req._resume is not None:
                    kw["resume"] = req._resume
            try:
                res = self.engine.admit_slot(
                    slot, req.prompt, max_new_tokens=req.max_new_tokens,
                    use_recycling=req.use_recycling,
                    admit=self._admit_allowed(req),
                    temperature=req.temperature, top_k=req.top_k,
                    tenant=req.tenant, **kw)
            except PoolSaturated:
                # transient: in-flight work will free blocks — put the
                # request BACK at the head and stop admitting this step
                # (later queue entries would hit the same wall)
                self._free.append(slot)
                self._queue.appendleft(req)
                self.stats["admissions_deferred"] += 1
                break
            except ValueError as e:
                # reject THIS request (e.g. longer than the pool capacity)
                # without dropping the rest of the queue or the slot
                self._free.append(slot)
                req.error = str(e)
                req.outcome = RequestOutcome.ERRORED
                self.completed.append(req)
                self.stats["rejected"] += 1
                done.append(req)
                continue
            except Exception:
                self._free.append(slot)      # don't leak the slot
                raise
            if req._resume is not None:
                req._resume = None
                self.stats["resumes"] += 1
            self.stats["admissions"] += 1
            budget -= 1    # admission work happened either way (a staged
            #                prefill ran, or chunk steps were queued)
            if res is not None:                       # finished at token 0
                req.result = res
                req.outcome = RequestOutcome.OK
                if res.ttft_s and res.ttft_s > 0.0:
                    req.first_token_t = req.admit_t + res.ttft_s
                self.completed.append(req)
                self.stats["instant_finishes"] += 1
                self._free.append(slot)
                done.append(req)
                continue
            self.in_flight[slot] = req
        return done

    def _drain_engine_events(self, finished: List[Request]) -> None:
        """Apply the engine's typed lifecycle events: a "preempted" slot's
        request requeues AT THE HEAD with its resume payload (bounded by
        ``max_requeues`` — a request the pool can never hold errors out
        instead of cycling forever); an "errored" slot's request
        terminates with a typed outcome.  Slot->request mapping is stable
        within the step: the engine freed the row, but the scheduler only
        reuses a slot after processing its event here."""
        if not self._engine_preempts:
            return
        for kind, payload in self.engine.drain_events():
            slot = payload["slot"]
            req = self.in_flight.pop(slot, None)
            if req is None:
                continue     # already finalized (defensive)
            self._free.append(slot)
            if kind == "preempted" and req._requeues < self.max_requeues:
                req._resume = payload
                req._requeues += 1
                self.stats["preemptions"] += 1
                self._queue.appendleft(req)
                continue
            req.outcome = RequestOutcome.ERRORED
            req.error = (payload.get("error", "preempted: requeue limit")
                         if kind != "preempted"
                         else "preempted: requeue limit reached")
            self.completed.append(req)
            finished.append(req)

    def step(self) -> List[Request]:
        """Shed expired requests, admit into free slots, then advance
        every in-flight request one token.  Returns the requests that
        completed this step (including admission-time completions:
        rejections, sheds and instant finishes)."""
        finished: List[Request] = list(self._shed_expired())
        finished.extend(self._admit())
        decoded = bool(self.in_flight)
        self.stats["occupancy_sum"] += len(self.in_flight)
        for slot, result in self.engine.decode_batch():
            req = self.in_flight.pop(slot)
            req.result = result
            req.outcome = RequestOutcome.OK
            # first-token wall time, reconstructed from the engine's TTFT
            # measurement relative to this request's admit stamp (the
            # engine measures TTFT from its own admission start, which is
            # within one step() of admit_t — documented approximation)
            if (req.admit_t is not None and result.ttft_s
                    and result.ttft_s > 0.0):
                req.first_token_t = req.admit_t + result.ttft_s
            self.completed.append(req)
            finished.append(req)
            if self._queue:
                self.stats["slot_reuses"] += 1
            self._free.append(slot)
        self._drain_engine_events(finished)
        self.stats["decode_steps"] += int(decoded)
        return finished

    def run(self) -> List[Request]:
        while self._queue or self.in_flight:
            self.step()
        return self.completed

    # ------------------------------------------------------------------
    def mean_occupancy(self) -> float:
        steps = max(self.stats["decode_steps"], 1)
        return self.stats["occupancy_sum"] / steps
