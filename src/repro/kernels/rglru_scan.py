"""Blocked RG-LRU linear-recurrence kernel:  h_t = a_t * h_{t-1} + b_t.

The recurrence is elementwise (diagonal transition), so the MXU cannot
help — the roofline is HBM streaming of a/b and VPU multiply-adds.  The
kernel blocks over (batch, width, time): grid = (B, W/BW, S/T) with the
time dimension sequential; the running state lives in VMEM scratch and each
grid step streams one (T, BW) tile of a and b.  Versus the jnp
``associative_scan`` path this avoids materializing the O(S) scan tree in
HBM and keeps a single state tile resident.

Inside a tile the recurrence runs as an unrolled sequential loop —
numerically exact for any decay magnitude (the closed-form cumprod trick
divides by vanishing decays; see rwkv6_wkv.py where decays are bounded).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hT_ref, h_scr, *, T, nt):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)              # (T, BW)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, T, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ti == nt - 1)
    def _write():
        hT_ref[0] = h.astype(hT_ref.dtype)


def rglru_scan(a, b, h0, *, chunk: int = 64, block_w: int = 512,
               interpret: bool = True):
    """a, b: (B, S, W) f32; h0: (B, W) f32 -> (h (B,S,W), h_final (B,W))."""
    B, S, W = a.shape
    T = min(chunk, S)
    while S % T:
        T //= 2
    BW = min(block_w, W)
    while W % BW:
        BW //= 2
    nt = S // T

    kernel = functools.partial(_rglru_kernel, T=T, nt=nt)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, W // BW, nt),
        in_specs=[
            pl.BlockSpec((1, T, BW), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, T, BW), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, BW), lambda bi, wi, ti: (bi, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, BW), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, BW), lambda bi, wi, ti: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((BW,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y, hT
