"""Jit'd wrappers around the Pallas kernels (the model-facing surface)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.decode_attention import (decode_attention_batched
                                            as _decode_batched)
from repro.kernels.decode_attention import (paged_decode_attention
                                            as _decode_paged)
from repro.kernels.decode_attention import (paged_decode_attention_quant
                                            as _decode_paged_quant)
from repro.kernels.prefill_attention import (paged_prefill_attention
                                             as _prefill_paged)
from repro.kernels.prefill_attention import (paged_prefill_attention_quant
                                             as _prefill_paged_quant)
from repro.kernels.prefill_attention import (paged_prefill_attention_packed
                                             as _prefill_packed)
from repro.kernels.prefill_attention import (
    paged_prefill_attention_packed_quant as _prefill_packed_quant)
from repro.kernels.verify_attention import (paged_verify_attention
                                            as _verify_paged)
from repro.kernels.verify_attention import (paged_verify_attention_quant
                                            as _verify_paged_quant)
from repro.kernels.rwkv6_wkv import rwkv6_wkv as _wkv
from repro.kernels.rglru_scan import rglru_scan as _rglru


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_start",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_start=0,
                    block_q=128, block_k=128, interpret=True):
    bq = min(block_q, q.shape[1])
    while q.shape[1] % bq:
        bq //= 2
    bk = min(block_k, k.shape[1])
    while k.shape[1] % bk:
        bk //= 2
    return _flash(q, k, v, causal=causal, window=window, q_start=q_start,
                  block_q=bq, block_k=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, slot_pos, pos, *, window=0,
                     block_k=256, interpret=True):
    bk = min(block_k, k_cache.shape[1])
    while k_cache.shape[1] % bk:
        bk //= 2
    return _decode(q, k_cache, v_cache, slot_pos, pos, window=window,
                   block_k=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention_batched(q, k_cache, v_cache, slot_pos, pos, *, window=0,
                             block_k=256, interpret=True):
    """Per-row (continuous-batching) decode: slot_pos (B,C), pos (B,)."""
    bk = min(block_k, k_cache.shape[1])
    while k_cache.shape[1] % bk:
        bk //= 2
    return _decode_batched(q, k_cache, v_cache, slot_pos, pos, window=window,
                           block_k=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, pos, *,
                           interpret=True):
    """Block-table (paged pool) decode: pools (NB, bs, Hkv, D) shared by
    all rows; block_tables (B, NBt) scalar-prefetched so the kernel
    gathers each row's K/V blocks through its table; pos (B,)."""
    return _decode_paged(q, k_pool, v_pool, block_tables, pos,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_quant(q, k_pool, v_pool, k_scale, v_scale,
                                 k_tail, v_tail, block_tables, pos, *,
                                 interpret=True):
    """int8 block-table decode with the dequant fused into the table
    gather: pools (NB, bs, Hkv, D) int8 + per-vector f32 scales; the
    row's most recent blocks come from its fp ring tail (B, R*bs, Hkv, D)
    instead of the int8 pool."""
    return _decode_paged_quant(q, k_pool, v_pool, k_scale, v_scale,
                               k_tail, v_tail, block_tables, pos,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(q, k_chunk, v_chunk, k_pool, v_pool, table_row,
                            c0, w_eff, *, interpret=True):
    """Chunked-prefill attention: q / chunk K/V (1, C, H|Hkv, D) is one
    fixed-size admission chunk; history (< w_eff) is gathered through the
    scalar-prefetched block table, the chunk itself from the fp operands
    (it has not been sealed to the pool yet); c0 / w_eff are traced
    scalars, so ONE compiled executable serves every suffix length."""
    return _prefill_paged(q, k_chunk, v_chunk, k_pool, v_pool, table_row,
                          c0, w_eff, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention_quant(q, k_chunk, v_chunk, k_pool, v_pool,
                                  k_scale, v_scale, k_tail_row, v_tail_row,
                                  table_row, c0, w_eff, *, interpret=True):
    """int8 chunked prefill with the dequant fused into the history table
    gather; the last R history blocks come from the row's fp ring tail
    (R*bs, Hkv, D) instead of the int8 pool, and the chunk's own K/V from
    its fp operands."""
    return _prefill_paged_quant(q, k_chunk, v_chunk, k_pool, v_pool,
                                k_scale, v_scale, k_tail_row, v_tail_row,
                                table_row, c0, w_eff, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk_tiles", "interpret"))
def paged_prefill_attention_packed(q, k_chunk, v_chunk, k_pool, v_pool,
                                   tables, desc, *, chunk_tiles=None,
                                   interpret=True):
    """Ragged packed multi-admission prefill: q / chunk K/V (1, T, H|Hkv,
    D) concatenate EVERY pending admission's current chunk (segments
    bs-aligned, T a bucket size); tables (S, NBt) are the per-segment
    block tables and desc (4, QT) the per-query-tile [seg, c0, w_eff,
    qt0] descriptors, both scalar-prefetched — so ONE compiled executable
    per (bucket, segment-count) shape serves any number of concurrent
    admissions at any depth."""
    return _prefill_packed(q, k_chunk, v_chunk, k_pool, v_pool, tables,
                           desc, chunk_tiles=chunk_tiles,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk_tiles", "interpret"))
def paged_prefill_attention_packed_quant(q, k_chunk, v_chunk, k_pool,
                                         v_pool, k_scale, v_scale, k_tails,
                                         v_tails, tables, desc, *,
                                         chunk_tiles=None, interpret=True):
    """int8 ragged packed prefill with the dequant fused into the
    segment-table gather; each segment's last R history blocks come from
    its row's fp ring tail (S, R*bs, Hkv, D), gathered by the caller."""
    return _prefill_packed_quant(q, k_chunk, v_chunk, k_pool, v_pool,
                                 k_scale, v_scale, k_tails, v_tails,
                                 tables, desc, chunk_tiles=chunk_tiles,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention(q, k_chunk, v_chunk, k_pool, v_pool,
                           block_tables, c0s, *, interpret=True):
    """Batched speculative-verify attention: every row's (Cv,)-token
    draft bundle attends history through its scalar-prefetched block
    table and the bundle itself from the fp operands; c0s (B,) are the
    per-row bundle starts (armed rows have no write floor)."""
    return _verify_paged(q, k_chunk, v_chunk, k_pool, v_pool,
                         block_tables, c0s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention_quant(q, k_chunk, v_chunk, k_pool, v_pool,
                                 k_scale, v_scale, k_tails, v_tails,
                                 block_tables, c0s, *, interpret=True):
    """int8 batched verify with the dequant fused into the table gather;
    the per-QUERY recency gate reads fp history from each row's
    pre-round ring snapshot (B, R*bs, Hkv, D) instead of the live
    (draft-polluted) ring."""
    return _verify_paged_quant(q, k_chunk, v_chunk, k_pool, v_pool,
                               k_scale, v_scale, k_tails, v_tails,
                               block_tables, c0s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, w, u, s0, *, chunk=16, interpret=True):
    c = min(chunk, r.shape[1])
    while r.shape[1] % c:
        c //= 2
    return _wkv(r, k, v, w, u, s0, chunk=c, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_scan(a, b, h0, *, chunk=64, block_w=512, interpret=True):
    return _rglru(a, b, h0, chunk=chunk, block_w=block_w,
                  interpret=interpret)
