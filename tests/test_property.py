"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.index import EmbeddingIndex
from repro.core.kvstore import HostKVStore
from repro.core.radix import RadixPrefixCache
from repro.core.recycler import common_prefix_len, trim_to_depth
from repro.data.tokenizer import ByteTokenizer

tokens_st = st.lists(st.integers(0, 50), min_size=0, max_size=40)


class TestRadixInvariants:
    @given(st.lists(tokens_st, min_size=1, max_size=8), tokens_st,
           st.integers(1, 5))
    @settings(max_examples=200, deadline=None)
    def test_lookup_is_true_block_prefix(self, corpus, query, block):
        """I1/I2: lookup depth is block-aligned, <= len(query), the returned
        entry really covers that prefix, and depth is maximal."""
        r = RadixPrefixCache(block_size=block)
        corpus = [np.asarray(c, np.int32) for c in corpus]
        for i, c in enumerate(corpus):
            r.insert(c, i)
        q = np.asarray(query, np.int32)
        depth, eid = r.lookup(q)
        assert depth % block == 0 and depth <= len(q)
        if eid is not None:
            assert depth > 0
            assert common_prefix_len(q, corpus[eid]) >= depth
        # maximality over the corpus
        best = 0
        for c in corpus:
            lcp = common_prefix_len(q, c)
            best = max(best, (min(lcp, (len(c) // block) * block)
                              // block) * block)
        assert depth == best

    @given(st.lists(tokens_st, min_size=2, max_size=6), st.data())
    @settings(max_examples=100, deadline=None)
    def test_forget_makes_unreachable(self, corpus, data):
        """I3: forgotten entries never serve a hit."""
        r = RadixPrefixCache(block_size=2)
        for i, c in enumerate(corpus):
            r.insert(np.asarray(c, np.int32), i)
        victim = data.draw(st.integers(0, len(corpus) - 1))
        r.forget_entry(victim)
        for c in corpus:
            depth, eid = r.lookup(np.asarray(c, np.int32))
            assert eid != victim

    @given(tokens_st, st.integers(1, 4))
    @settings(max_examples=100, deadline=None)
    def test_self_lookup(self, toks, block):
        """Inserting t then looking up t finds floor(len/block)*block."""
        r = RadixPrefixCache(block_size=block)
        t = np.asarray(toks, np.int32)
        r.insert(t, 0)
        depth, eid = r.lookup(t)
        assert depth == (len(t) // block) * block
        assert (eid == 0) == (depth > 0)


class TestPrefixProperties:
    @given(tokens_st, tokens_st)
    @settings(max_examples=200, deadline=None)
    def test_common_prefix_len_definition(self, a, b):
        r = common_prefix_len(a, b)
        assert a[:r] == b[:r]
        if r < min(len(a), len(b)):
            assert a[r] != b[r]

    @given(st.text(max_size=60), st.text(max_size=30))
    @settings(max_examples=200, deadline=None)
    def test_tokenizer_prefix_consistency(self, base, ext):
        """Text prefix => token prefix (what makes the paper's exact prefix
        test equivalent to a text-prefix test)."""
        tok = ByteTokenizer(512)
        a = tok.encode(base)
        ab = tok.encode(base + ext)
        assert common_prefix_len(a, ab) == len(a)

    @given(st.text(max_size=60))
    @settings(max_examples=100, deadline=None)
    def test_tokenizer_deterministic(self, text):
        tok = ByteTokenizer(1024)
        np.testing.assert_array_equal(tok.encode(text), tok.encode(text))


class TestIndexInvariants:
    @given(st.lists(st.tuples(st.sampled_from("ar"), st.integers(0, 7)),
                    max_size=40))
    @settings(max_examples=200, deadline=None)
    def test_one_row_per_id(self, ops):
        """After any mix of add (including duplicate re-adds) and remove:
        one row per id, the id->row map is exact, and search/similarity
        read the LATEST vector for every live id."""
        dim = 8
        idx = EmbeddingIndex(dim)
        live = {}
        version = 0
        for op, eid in ops:
            if op == "a":
                version += 1
                v = np.zeros(dim, np.float32)
                v[eid % dim] = 1.0
                v[(eid + version) % dim] += 0.5
                v /= np.linalg.norm(v)
                idx.add(eid, v)
                live[eid] = v
            else:
                idx.remove(eid)
                live.pop(eid, None)
            # structural invariant: len == |_row| == rows of _vecs
            assert len(idx) == len(idx._row) == idx._vecs.shape[0]
            assert set(idx.ids()) == set(live)
            for j, v in live.items():
                assert idx.similarity(j, v) == pytest.approx(1.0, abs=1e-5)
        if live:
            got = dict(idx.search(np.ones(dim, np.float32),
                                  k=len(live) + 3))
            assert set(got) == set(live)


class TestStoreInvariants:
    @given(st.lists(st.tuples(st.sampled_from("pgre"), st.integers(0, 5),
                              st.integers(1, 4)),
                    max_size=30),
           st.integers(1, 8))
    @settings(max_examples=150, deadline=None)
    def test_byte_accounting(self, ops, budget_blocks):
        """``total_bytes == sum(e.nbytes)`` and ``total_bytes <=
        max_bytes`` after any interleaving of put / get / remove /
        evict_to_budget, with on_evict firing exactly once per budget
        eviction."""
        unit = np.zeros(16, np.float32).nbytes   # 64 bytes per size-1 put
        store = HostKVStore(max_bytes=budget_blocks * unit)
        evicted = []
        store.on_evict = evicted.append
        put_ids = []
        for op, key, size in ops:
            if op == "p":
                cache = {"k": np.zeros(16 * size, np.float32)}
                put_ids.append(store.put(f"t{key}", np.arange(4), cache,
                                         4).entry_id)
            elif op == "g" and put_ids:
                eid = put_ids[key % len(put_ids)]
                if eid in store:
                    store.get(eid)
            elif op == "r" and put_ids:
                store.remove(put_ids[key % len(put_ids)])
            else:
                store.evict_to_budget()
            assert store.total_bytes == sum(e.nbytes
                                            for e in store.entries())
            assert store.total_bytes <= store.max_bytes
        # every evicted id is gone, reported once, and was never removed
        assert len(evicted) == len(set(evicted)) == store.evictions
        for eid in evicted:
            assert eid not in store


class TestTrimProperty:
    @given(st.integers(0, 20), st.integers(1, 20))
    @settings(max_examples=50, deadline=None)
    def test_trim_never_exposes_deeper_positions(self, depth, filled):
        sp = np.where(np.arange(20) < filled, np.arange(20), -1)
        cache = {"k": np.zeros((1, 20, 1, 2)), "slot_pos": sp.astype(np.int32)}
        t = trim_to_depth(cache, depth)
        assert (t["slot_pos"] < depth).all()
        # positions below depth that existed are preserved
        keep = (sp >= 0) & (sp < depth)
        np.testing.assert_array_equal(t["slot_pos"][keep], sp[keep])
