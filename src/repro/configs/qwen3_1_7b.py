"""qwen3-1.7b — dense GQA decoder with QK-norm.

[hf:Qwen/Qwen3-8B family card, assigned 1.7B dims] 28L, d_model=2048,
16 heads (GQA kv=8), d_ff=6144, vocab=151936, qk_norm, no attention bias.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B (family card, assigned 1.7B dims)",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    sliding_window=8192,
)
