"""Unit tests for the recycling core: store, index, recycler policies."""
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.core import (BlockLSH, EmbeddingIndex, HashEmbedder, HostKVStore,
                        RadixPrefixCache, Recycler)
from repro.core.lsh import match_mask
from repro.core.recycler import (common_prefix_len, grow_capacity,
                                 is_trimmable, trim_to_depth)


def _attn_cache(n_slots=16, filled=8):
    sp = np.where(np.arange(n_slots) < filled, np.arange(n_slots), -1)
    return {"seg0": {"k": np.random.randn(2, 1, n_slots, 2, 4).astype(np.float32),
                     "v": np.random.randn(2, 1, n_slots, 2, 4).astype(np.float32),
                     "slot_pos": np.tile(sp, (2, 1)).astype(np.int32)}}


def _state_cache():
    return {"seg0": {"wkv": np.zeros((2, 1, 2, 4, 4), np.float32),
                     "shift_t": np.zeros((2, 1, 8), np.float32),
                     "shift_c": np.zeros((2, 1, 8), np.float32)}}


class TestEmbedder:
    def test_deterministic(self):
        e = HashEmbedder()
        a = e.encode("hello world")
        np.testing.assert_array_equal(a, e.encode("hello world"))
        assert abs(np.linalg.norm(a) - 1.0) < 1e-5

    def test_extended_prefix_similar(self):
        e = HashEmbedder()
        base = e.encode("Explain machine learning in simple terms.")
        ext = e.encode("Explain machine learning in simple terms. "
                       "Give an example application.")
        other = e.encode("How do airplanes fly?")
        assert float(base @ ext) > 0.6
        assert float(base @ other) < 0.3

    def test_pinned_embedding_values(self):
        """Regression for the ``hash(kind)`` seed bug: these constants
        were generated from the blake2b-seeded embedder and must hold on
        every machine, process, and PYTHONHASHSEED forever.  The old
        builtin-hash seeding produced a different vector per process."""
        from repro.core.embedder import _KIND_SEEDS
        assert _KIND_SEEDS == {"w": 17069, "b": 23418, "c": 40538,
                               "r": 6956}
        v = HashEmbedder(dim=64).encode("hello world")
        assert np.nonzero(v)[0].tolist() == [13, 18, 23, 26, 38, 48, 49,
                                             51, 57, 58, 62, 63]
        assert v[13] == pytest.approx(-0.288675, abs=1e-5)
        assert v[18] == pytest.approx(0.288675, abs=1e-5)

    def test_stable_across_hash_seeds(self):
        """Embeddings from subprocesses with DIFFERENT PYTHONHASHSEED
        values must be bit-identical — the property the store's disk
        persistence depends on (a reloaded index is useless if every
        process embeds the same text differently)."""
        import repro.core.embedder as emb
        script = (
            "import importlib.util, hashlib\n"
            f"spec = importlib.util.spec_from_file_location("
            f"'emb', {emb.__file__!r})\n"
            "m = importlib.util.module_from_spec(spec)\n"
            "spec.loader.exec_module(m)\n"
            "v = m.HashEmbedder(dim=64).encode('seed stability probe')\n"
            "print(hashlib.sha256(v.tobytes()).hexdigest())\n")
        digests = set()
        for seed in ("0", "1", "424242"):
            env = {**os.environ, "PYTHONHASHSEED": seed}
            out = subprocess.run([sys.executable, "-c", script], env=env,
                                 capture_output=True, text=True, check=True)
            digests.add(out.stdout.strip())
        assert len(digests) == 1, digests


class TestIndex:
    def test_search_and_remove(self):
        e = HashEmbedder(dim=64)
        idx = EmbeddingIndex(64)
        texts = ["alpha beta", "gamma delta", "alpha beta gamma"]
        for i, t in enumerate(texts):
            idx.add(i, e.encode(t))
        top = idx.search(e.encode("alpha beta"), k=2)
        assert top[0][0] == 0
        idx.remove(0)
        top = idx.search(e.encode("alpha beta"), k=1)
        assert top[0][0] == 2

    def test_duplicate_add_replaces(self):
        """Re-adding an id must replace its vector — the old append-only
        behavior left a stale duplicate row that ``remove`` only half
        deleted and ``similarity`` kept reading."""
        e = HashEmbedder(dim=32)
        idx = EmbeddingIndex(32)
        idx.add(7, e.encode("old text"))
        idx.add(7, e.encode("new text"))
        assert len(idx) == 1
        q = e.encode("new text")
        assert idx.similarity(7, q) == pytest.approx(1.0, abs=1e-5)
        assert idx.search(q, k=5) == [(7, pytest.approx(1.0, abs=1e-5))]
        idx.remove(7)
        assert len(idx) == 0 and 7 not in idx
        assert idx.search(q, k=1) == []
        assert np.isnan(idx.similarity(7, q))


class TestStore:
    def test_lru_eviction_budget(self):
        cache = _attn_cache()
        entry_bytes = sum(a.nbytes for seg in cache.values()
                          for a in seg.values())
        store = HostKVStore(max_bytes=int(entry_bytes * 2.5))
        for i in range(4):
            store.put(f"p{i}", np.arange(6), _attn_cache(), 6)
            store.evict_to_budget()
        assert len(store) == 2
        assert store.total_bytes <= store.max_bytes
        assert store.evictions == 2

    def test_put_enforces_budget_itself(self):
        """``put`` alone must keep the store under max_bytes — direct
        store users used to be able to exceed the budget indefinitely
        because only Recycler.admit ever called evict_to_budget."""
        cache = _attn_cache()
        entry_bytes = sum(a.nbytes for seg in cache.values()
                          for a in seg.values())
        store = HostKVStore(max_bytes=int(entry_bytes * 2.5))
        evicted = []
        store.on_evict = evicted.append
        ids = [store.put(f"p{i}", np.arange(6), _attn_cache(), 6).entry_id
               for i in range(5)]
        assert store.total_bytes <= store.max_bytes
        assert len(store) == 2
        # LRU order: the oldest three fell out, each reported on_evict
        assert evicted == ids[:3]
        assert ids[3] in store and ids[4] in store

    def test_oversize_entry_refused(self):
        """An entry bigger than the WHOLE budget is evicted inside put —
        the store honestly refuses to hold it rather than blowing the
        budget."""
        store = HostKVStore(max_bytes=64)
        e = store.put("big", np.arange(6), _attn_cache(), 6)
        assert e.entry_id not in store
        assert len(store) == 0 and store.total_bytes == 0

    def test_byte_accounting_mixed_sequence(self):
        """total_bytes == sum(entry.nbytes) after any mix of put / get /
        remove / evict_to_budget (the invariant the budget enforcement
        rests on)."""
        cache = _attn_cache()
        entry_bytes = sum(a.nbytes for seg in cache.values()
                          for a in seg.values())
        store = HostKVStore(max_bytes=int(entry_bytes * 3.5))

        def check():
            assert store.total_bytes == sum(e.nbytes
                                            for e in store.entries())

        ids = []
        for i in range(3):
            ids.append(store.put(f"p{i}", np.arange(4), _attn_cache(),
                                 4).entry_id)
            check()
        store.get(ids[0])                       # LRU touch
        store.remove(ids[1])
        check()
        store.remove(ids[1])                    # double remove: no-op
        check()
        for i in range(4):
            store.put(f"q{i}", np.arange(4), _attn_cache(), 4)
            check()
        store.evict_to_budget()
        check()
        assert store.total_bytes <= store.max_bytes

    def test_disk_roundtrip(self):
        store = HostKVStore()
        e = store.put("prompt a", np.arange(8), _attn_cache(), 8, 16)
        with tempfile.TemporaryDirectory() as d:
            store.save_dir(d)
            loaded = HostKVStore.load_dir(d)
        e2 = loaded.get(e.entry_id)
        assert e2.text == "prompt a" and e2.length == 8
        np.testing.assert_array_equal(
            e2.cache["seg0"]["k"], e.cache["seg0"]["k"])


class TestCacheSurgery:
    def test_trimmable(self):
        assert is_trimmable(_attn_cache())
        assert not is_trimmable(_state_cache())

    def test_trim_masks_slots(self):
        t = trim_to_depth(_attn_cache(filled=8), 5)
        sp = t["seg0"]["slot_pos"]
        assert (sp[:, :5] >= 0).all() and (sp[:, 5:] == -1).all()

    def test_grow_capacity(self):
        g = grow_capacity(_attn_cache(n_slots=16), 32)
        assert g["seg0"]["k"].shape[2] == 32
        assert (g["seg0"]["slot_pos"][:, 16:] == -1).all()

    def test_grow_capacity_1d_slot_pos(self):
        # regression: a bare 1-D slot_pos leaf (no layer stack) must pad on
        # its only axis with -1, and a leaf with fewer dims than its named
        # capacity axis must be left alone instead of padding a wrong axis
        cache = {"slot_pos": np.full((4,), -1, np.int32),
                 "k": np.zeros((8, 2), np.float32)}  # ndim 2 < |axis -3|
        g = grow_capacity(cache, 8)
        assert g["slot_pos"].shape == (8,)
        assert (g["slot_pos"] == -1).all()
        assert g["k"].shape == (8, 2)                # untouched

    def test_grow_capacity_per_slot_pos(self):
        # batched-pool layout: slot_pos carries a batch axis (B, C)
        cache = {"slot_pos": np.where(np.arange(6) < 3, np.arange(6),
                                      -1).astype(np.int32)[None].repeat(2, 0)}
        g = grow_capacity(cache, 12)
        assert g["slot_pos"].shape == (2, 12)
        assert (g["slot_pos"][:, 6:] == -1).all()

    def test_common_prefix_len(self):
        assert common_prefix_len([1, 2, 3], [1, 2, 3, 4]) == 3
        assert common_prefix_len([1, 2, 9], [1, 2, 3, 4]) == 2
        assert common_prefix_len([], [1]) == 0


class TestRecycler:
    def test_exact_prefix_hit(self):
        r = Recycler()
        toks = np.arange(10)
        r.admit("what is the capital of france?", toks, _attn_cache(), 10)
        res = r.lookup("what is the capital of france? and italy?",
                       np.concatenate([toks, [11, 12, 13]]))
        assert res.hit and res.mode == "exact_prefix" and res.reuse_depth == 10

    def test_identical_prompt_leaves_one_token(self):
        r = Recycler()
        toks = np.arange(10)
        r.admit("same prompt", toks, _attn_cache(), 10)
        res = r.lookup("same prompt", toks)
        assert res.hit and res.reuse_depth == 9

    def test_recurrent_state_requires_full_prefix(self):
        r = Recycler()
        toks = np.arange(10)
        r.admit("state prompt xyz", toks, _state_cache(), 10)
        # identical prompt: state can't rewind to m-1 -> miss
        res = r.lookup("state prompt xyz", toks)
        assert not res.hit
        # strict extension: full state reuse OK
        res2 = r.lookup("state prompt xyz etc",
                        np.concatenate([toks, [11, 12]]))
        assert res2.hit and res2.reuse_depth == 10

    def test_partial_block_hit(self):
        r = Recycler(enable_partial=True, block_size=4)
        r.admit("p", np.arange(12), _attn_cache(filled=12), 12)
        res = r.lookup("q", np.asarray([0, 1, 2, 3, 4, 5, 99, 98, 97]))
        assert res.hit and res.mode == "partial_block" and res.reuse_depth == 4
        sp = res.cache["seg0"]["slot_pos"]
        assert (sp[:, 4:] == -1).all()      # trimmed beyond reuse depth

    def test_partial_hit_reports_entry_own_similarity(self):
        """The similarity on a partial_block result must describe the hit
        entry itself, not whatever sim_best the (rejected) exact-path
        retrieval loop happened to see."""
        r = Recycler(enable_partial=True, block_size=4)
        e = r.admit("shared tokens prompt", np.arange(12),
                    _attn_cache(filled=12), 12)
        # textually unrelated query whose TOKENS share a block-aligned
        # prefix: retrieval similarity to the entry is near zero, but the
        # radix still finds the 8-token overlap
        res = r.lookup("completely different words here",
                       np.asarray([0, 1, 2, 3, 4, 5, 6, 7, 55, 66]))
        assert res.hit and res.mode == "partial_block"
        own = r.index.similarity(e.entry_id,
                                 r.embedder.encode(
                                     "completely different words here"))
        assert res.similarity == pytest.approx(own)

    def test_radix_lookup_prefers_true_recency(self):
        """Two entries cover the same block prefix; lookup must prefer the
        most recently TOUCHED one (served hit), not the highest id."""
        rx = RadixPrefixCache(block_size=4)
        rx.insert(np.arange(8), entry_id=1, length=8)
        rx.insert(np.arange(8), entry_id=2, length=8)   # newer insert wins
        assert rx.lookup(np.arange(8))[1] == 2
        rx.touch(1)                                     # old entry re-hit
        assert rx.lookup(np.arange(8))[1] == 1          # id order would say 2
        rx.touch(2)
        assert rx.lookup(np.arange(8))[1] == 2

    def test_recycler_hit_refreshes_radix_recency(self):
        """Serving a hit must stamp the entry in the radix too, so lookup
        preference tracks the same LRU order the store eviction uses."""
        r = Recycler(enable_partial=True, block_size=4)
        toks = np.arange(8)
        r.admit("first", toks, _attn_cache(), 8)
        r.admit("second", toks.copy(), _attn_cache(), 8)
        # both cover the same tokens; the radix serves the newer (id 1)...
        assert r.radix.lookup(np.asarray([0, 1, 2, 3]))[1] == 1
        # ...but after entry 0 serves an exact hit, IT is the fresher one
        res = r.lookup("first", np.concatenate([toks, [20]]))
        assert res.hit and res.entry.entry_id == 0
        assert r.radix.lookup(np.asarray([0, 1, 2, 3]))[1] == 0

    def test_eviction_reaches_index_and_radix(self):
        cache = _attn_cache()
        entry_bytes = sum(a.nbytes for seg in cache.values()
                          for a in seg.values())
        r = Recycler(HostKVStore(max_bytes=int(entry_bytes * 1.5)),
                     enable_partial=True, block_size=4)
        e0 = r.admit("first prompt", np.arange(8), _attn_cache(), 8)
        e1 = r.admit("second prompt", np.arange(100, 108), _attn_cache(), 8)
        assert e0.entry_id not in r.store          # evicted
        assert e0.entry_id not in r.radix
        res = r.lookup("first prompt zz", np.arange(10))
        assert not res.hit

    def test_prepopulated_store_rebuilds_mirrors(self):
        """A Recycler built over a pre-populated store (the load_dir
        reload path) must rebuild the embedding index, radix, and LSH —
        persisted entries used to be invisible to every retrieval path
        until the next admit."""
        r = Recycler(enable_partial=True, block_size=4)
        toks = np.arange(16)
        r.admit("reload me please", toks, _attn_cache(filled=16), 16)
        with tempfile.TemporaryDirectory() as d:
            r.store.save_dir(d)
            loaded = HostKVStore.load_dir(d)
        r2 = Recycler(loaded, enable_partial=True, block_size=4,
                      semantic=True)
        # exact-prefix path sees the reloaded entry
        res = r2.lookup("reload me please and more",
                        np.concatenate([toks, [20, 21]]))
        assert res.hit and res.mode == "exact_prefix"
        assert res.reuse_depth == 16
        # radix partial path was rebuilt too
        res2 = r2.lookup("totally different words",
                         np.asarray([0, 1, 2, 3, 4, 5, 99, 98]))
        assert res2.hit and res2.mode == "partial_block"
        assert res2.reuse_depth == 4
        # ...and the block LSH: interior blocks of the reloaded donor are
        # graftable for a query that misses both prefix paths
        q = toks.copy()
        q[0] = 77                     # break the prefix at token 0
        plan = r2.lookup_semantic("unrelated head same tail", q)
        assert plan is not None and plan.entry.text == "reload me please"

    def test_reload_after_budget_eviction_keeps_mirrors_consistent(self):
        """load_dir enforces the budget; the Recycler's rebuilt mirrors
        must cover exactly the surviving entries."""
        cache = _attn_cache()
        entry_bytes = sum(a.nbytes for seg in cache.values()
                          for a in seg.values())
        r = Recycler(enable_partial=True, block_size=4)
        r.admit("cold entry", np.arange(8), _attn_cache(), 8)
        kept = r.admit("hot entry", np.arange(50, 58), _attn_cache(), 8)
        with tempfile.TemporaryDirectory() as d:
            r.store.save_dir(d)
            loaded = HostKVStore.load_dir(d,
                                          max_bytes=int(entry_bytes * 1.5))
        r2 = Recycler(loaded, enable_partial=True, block_size=4)
        assert len(r2.index) == len(loaded) == 1
        assert kept.entry_id in r2.index
        assert not r2.lookup("cold entry zz", np.arange(10)).hit
        res = r2.lookup("hot entry zz",
                        np.concatenate([np.arange(50, 58), [9]]))
        assert res.hit and res.entry.entry_id == kept.entry_id


class TestBlockLSH:
    def test_identical_blocks_always_collide(self):
        lsh = BlockLSH(4)
        lsh.add(1, np.arange(12), 12)
        cands = lsh.candidates(np.arange(12), 12)
        assert cands[1] == {0, 1, 2}

    def test_position_aligned(self):
        """Matching CONTENT at a different block position must not
        collide — a KV block only stands in at the absolute positions it
        was computed for."""
        lsh = BlockLSH(4)
        lsh.add(1, np.arange(12), 12)
        shifted = np.arange(4, 16)    # donor's blocks 1,2 at positions 0,1
        assert lsh.candidates(shifted, 12).get(1, set()) == set()

    def test_partial_tail_block_not_indexed(self):
        lsh = BlockLSH(4)
        lsh.add(1, np.arange(10), 10)           # 2 full blocks + 2 tokens
        assert len(lsh.signatures(np.arange(10), 10)) == 2
        cands = lsh.candidates(np.arange(12), 12)
        assert cands[1] == {0, 1}

    def test_remove_and_readd_replaces(self):
        lsh = BlockLSH(4)
        a, b = np.arange(8), np.arange(100, 108)
        lsh.add(1, a, 8)
        lsh.remove(1)
        assert 1 not in lsh and lsh.candidates(a, 8) == {}
        lsh.add(1, a, 8)
        lsh.add(1, b, 8)              # re-add replaces, no stale buckets
        assert lsh.candidates(a, 8).get(1, set()) == set()
        assert lsh.candidates(b, 8)[1] == {0, 1}

    def test_match_mask_agreement_and_gating(self):
        q = np.arange(8)
        d = np.arange(8).copy()
        d[5] = 99                     # block 1 agrees 3/4
        assert match_mask(q, d, 4, {0, 1}, 1.0) == [1.0, 0.0]
        assert match_mask(q, d, 4, {0, 1}, 0.7) == [1.0, 0.75]
        assert match_mask(q, d, 4, {1}, 0.7) == [0.0, 0.75]  # 0 not cand


class TestSemanticLookup:
    def _recycler(self, **kw):
        kw.setdefault("semantic", True)
        kw.setdefault("block_size", 4)
        return Recycler(**kw)

    def test_graft_plan_geometry(self):
        r = self._recycler()
        donor = np.arange(20)
        r.admit("donor prompt", donor, _attn_cache(n_slots=20, filled=20),
                20)
        q = donor.copy()
        q[1] = 99                     # break block 0; blocks 1..4 agree
        plan = r.lookup_semantic("query prompt", q)
        assert plan is not None
        # last block (4, holds the final prompt token) is ungraftable;
        # the run is blocks [1, 4): boundary block 1 recomputed, interior
        # blocks [2, 4) grafted
        assert (plan.b0, plan.b1, plan.boundary) == (1, 4, 1)
        assert plan.seg1_end == 8 and plan.graft_end == 16
        assert plan.interior_tokens == 8
        assert plan.agreement == 1.0

    def test_semantic_off_returns_none(self):
        r = Recycler(block_size=4)    # semantic left at default False
        r.admit("donor", np.arange(20), _attn_cache(n_slots=20, filled=20),
                20)
        assert r.lsh is None
        assert r.lookup_semantic("q", np.arange(20)) is None

    def test_too_short_for_interior(self):
        """With boundary=1 the query needs its final token beyond block 2
        (recompute boundary + graft >=1 interior + recompute last)."""
        r = self._recycler()
        r.admit("donor", np.arange(20), _attn_cache(n_slots=20, filled=20),
                20)
        assert r.lookup_semantic("q", np.arange(8)) is None   # last_block 1
        # 12 tokens with block 0 broken: the agreeing run is a single
        # block — all boundary, no interior -> no plan
        q = np.arange(12)
        q[0] = 55
        assert r.lookup_semantic("q", q) is None

    def test_longest_run_wins(self):
        r = self._recycler()
        short = np.arange(24)
        long = np.arange(24).copy()
        long[2] = 77                  # long donor disagrees in block 0
        r.admit("short run donor", short,
                _attn_cache(n_slots=24, filled=24), 12)   # covers 3 blocks
        r.admit("long run donor", long,
                _attn_cache(n_slots=24, filled=24), 24)
        q = np.arange(24).copy()
        q[0] = 55                     # miss both donors' block 0
        plan = r.lookup_semantic("query", q)
        # short donor offers blocks [1,3) -> 1 interior block; long donor
        # offers [1,5) -> 3 interior blocks and must win
        assert plan is not None and plan.entry.text == "long run donor"
        assert (plan.b0, plan.b1) == (1, 5)
        assert plan.interior_tokens == 12

    def test_boundary_blocks_widen_recompute(self):
        r = self._recycler(graft_boundary_blocks=2)
        donor = np.arange(24)
        r.admit("donor", donor, _attn_cache(n_slots=24, filled=24), 24)
        q = donor.copy()
        q[1] = 99
        plan = r.lookup_semantic("query", q)
        assert plan is not None
        assert plan.boundary == 2
        assert plan.seg1_end == plan.b0 * 4 + 8
        assert plan.interior_tokens >= 4

    def test_min_agree_gates_noisy_blocks(self):
        r = self._recycler(graft_min_agree=1.0)
        donor = np.arange(20)
        r.admit("donor", donor, _attn_cache(n_slots=20, filled=20), 20)
        q = donor.copy()
        # noise on block 2's FIRST token: only 1 of 3 shingles is lost,
        # so the LSH still surfaces the block and min_agree decides
        q[8] = 99
        # strict agreement: the run splits at the noisy block, leaving
        # only blocks [0, 2) graftable (1 interior block)
        strict = r.lookup_semantic("query", q)
        assert strict is not None and (strict.b0, strict.b1) == (0, 2)
        assert strict.agreement == 1.0
        r2 = self._recycler(graft_min_agree=0.7)
        r2.admit("donor", donor, _attn_cache(n_slots=20, filled=20), 20)
        loose = r2.lookup_semantic("query", q)
        assert loose is not None and (loose.b0, loose.b1) == (0, 4)
        assert loose.agreement == pytest.approx((1 + 1 + 0.75 + 1) / 4)
