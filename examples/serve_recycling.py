"""End-to-end serving driver: batched requests through the FIFO scheduler
against a recycling engine, reproducing the paper's full evaluation and the
beyond-paper partial-prefix mode.

    PYTHONPATH=src python examples/serve_recycling.py [--full] [--partial]

``--full`` uses the paper's real 345M DialoGPT config (slow on CPU).
"""
import argparse
import json

import jax

from repro.configs import get_config
from repro.core import HashEmbedder
from repro.core.metrics import RunMetrics, summarize_runs
from repro.data.pipeline import paper_prompt_sets
from repro.models import init_params
from repro.serving import Engine, FIFOScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--partial", action="store_true")
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config("dialogpt-medium")
    if not args.full:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_new_tokens=args.max_new,
                    enable_partial=args.partial, block_size=16)

    cache_prompts, test_prompts = paper_prompt_sets("data")
    engine.precache(cache_prompts)
    print(f"precached {len(engine.recycler.store)} prompts "
          f"({engine.recycler.store.total_bytes/1e6:.1f} MB host KV)")

    # batched requests through the scheduler: baseline pass then recycled
    sched = FIFOScheduler(engine, max_batch=4)
    for p in test_prompts:                   # warm compile for both shapes
        engine.warmup(p, use_recycling=False)
        engine.warmup(p)
    for p in test_prompts:
        sched.submit(p, use_recycling=False)
    baseline_reqs = sched.run()
    sched.completed.clear()
    for p in test_prompts:
        sched.submit(p, admit=True)          # recycled + admit for reuse
    recycled_reqs = sched.run()

    rows_b = [RunMetrics(r.prompt, "baseline", r.result.latency_s,
                         r.result.prompt_tokens, r.result.gen_tokens,
                         output_text=r.result.text) for r in baseline_reqs]
    rows_r = [RunMetrics(r.prompt, "recycled", r.result.latency_s,
                         r.result.prompt_tokens, r.result.gen_tokens,
                         r.result.reuse_depth, r.result.cache_hit,
                         r.result.prompt_similarity, r.result.mode,
                         r.result.text) for r in recycled_reqs]

    print("\nper-request:")
    for b, r in zip(rows_b, rows_r):
        sp = (b.latency_s - r.latency_s) / b.latency_s * 100
        print(f"  reuse {r.reuse_depth:3d}/{r.prompt_tokens:3d} tok  "
              f"{b.latency_s*1e3:7.1f} -> {r.latency_s*1e3:7.1f} ms "
              f"({sp:+5.1f}%)  same-output={b.output_text == r.output_text}")

    print("\npaper Table-1 summary:")
    print(json.dumps(summarize_runs(rows_b, rows_r,
                                    embedder=HashEmbedder()), indent=1))
    print("\nengine stats:", engine.stats)


if __name__ == "__main__":
    main()
