"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

The KV cache stores only the compressed latent ``c_kv`` (kv_lora_rank) plus a
single shared RoPE key (qk_rope_head_dim) per token — 512+64 floats vs
128 heads x 256 for an equivalent MHA.  This makes MLA the best-case
architecture for the paper's KV *recycling*: host-serialized prefix caches
are ~50x smaller (DESIGN.md §4).

Decode uses the *absorbed* formulation: W_uk is folded into the query and
W_uv into the output so attention runs directly in latent space —
per-step FLOPs are O(H * (r + d_r)) per cached token with no per-token
up-projection of the whole cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, split_tree, apply_rope
from repro.models.attention import _mask_bias, NEG_INF


def init_mla(cfg: ModelConfig, key, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dq, dn, dr, dv = m.q_lora_rank, m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = split_tree(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, dq), dtype),
        "q_norm": jnp.ones((dq,), jnp.float32),
        "w_uq": dense_init(ks[1], (dq, h * (dn + dr)), dtype),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "w_kr": dense_init(ks[3], (d, dr), dtype),
        "w_uk": dense_init(ks[4], (m.kv_lora_rank, h * dn), dtype),
        "w_uv": dense_init(ks[5], (m.kv_lora_rank, h * dv), dtype),
        "wo": dense_init(ks[6], (h * dv, d), dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
        "slot_pos": jnp.full((capacity,), -1, jnp.int32),
    }


def _project_latent(cfg: ModelConfig, p, x, positions):
    """x (B,S,d) -> (c_kv (B,S,r), k_rope (B,S,dr)) — the cacheable pair."""
    m = cfg.mla
    ckv = rmsnorm(x @ p["w_dkv"], p["kv_norm"])
    krope = apply_rope(x @ p["w_kr"], positions, cfg.rope_theta)
    return ckv, krope


def _project_q(cfg: ModelConfig, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.num_heads
    q = rmsnorm(x @ p["w_dq"], p["q_norm"]) @ p["w_uq"]
    q = q.reshape(B, S, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _absorbed_attend(cfg: ModelConfig, p, q_nope, q_rope, ckv, krope,
                     q_pos, kv_pos, *, window=0):
    """Attention in latent space.  q_nope (B,Sq,H,dn), ckv (B,Skv,r)."""
    m = cfg.mla
    B, Sq, H, dn = q_nope.shape
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, dn)
    # absorb: q_lat[b,s,h,r] = q_nope . W_uk[:,h,:].  f32 accumulation via
    # preferred_element_type — operand .astype(f32) would materialize f32
    # copies of the latent cache / weights (hoisted out of the layer scan).
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk,
                       preferred_element_type=jnp.float32)
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshd,btd->bhst", q_rope, krope,
                        preferred_element_type=jnp.float32)
    scores = (s_lat + s_rope) * scale
    scores = scores + _mask_bias(q_pos, kv_pos, causal=True, window=window)
    w = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", w.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", o_lat.astype(w_uv.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H * m.v_head_dim).astype(q_nope.dtype)


def _absorbed_attend_chunked(cfg, p, q_nope, q_rope, ckv, krope, q_pos, kv_pos,
                             *, window=0, q_chunk=256, kv_chunk=1024):
    """Online-softmax version for 32k prefill (avoids (S x S x H) scores)."""
    m = cfg.mla
    B, Sq, H, dn = q_nope.shape
    Skv = ckv.shape[1]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, dn)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    from repro.models.attention import pick_chunks
    qc, kc = pick_chunks(B, H, Sq, Skv, q_chunk=q_chunk, kv_chunk=kv_chunk)
    nq, nk = Sq // qc, Skv // kc

    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk,
                       preferred_element_type=jnp.float32)
    q_lat = q_lat.reshape(B, nq, qc, H, m.kv_lora_rank)
    q_rope_c = q_rope.reshape(B, nq, qc, H, m.qk_rope_head_dim)
    q_pos_c = q_pos.reshape(nq, qc)

    @jax.checkpoint      # flash-style backward: recompute per q-chunk
    def q_step(_, qi):
        ql, qr, qp = q_lat[:, qi], q_rope_c[:, qi], q_pos_c[qi]

        def kv_step(carry, ki):
            mx, l, acc = carry
            cb = jax.lax.dynamic_slice_in_dim(ckv, ki * kc, kc, 1)
            rb = jax.lax.dynamic_slice_in_dim(krope, ki * kc, kc, 1)
            pb = jax.lax.dynamic_slice_in_dim(kv_pos, ki * kc, kc, 0)
            s = (jnp.einsum("bshr,btr->bhst", ql.astype(cb.dtype), cb,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bshd,btd->bhst", qr.astype(rb.dtype), rb,
                              preferred_element_type=jnp.float32)) * scale
            s = s + _mask_bias(qp, pb, causal=True, window=window)
            m_new = jnp.maximum(mx, jnp.max(s, axis=-1))
            alpha = jnp.exp(mx - m_new)
            pr = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(pr, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhst,btr->bhsr", pr.astype(cb.dtype), cb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, m.kv_lora_rank), jnp.float32)
        (mx, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                       jnp.arange(nk, dtype=jnp.int32))
        o_lat = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,H,qc,r)
        out = jnp.einsum("bhsr,rhv->bshv", o_lat.astype(w_uv.dtype), w_uv,
                         preferred_element_type=jnp.float32)
        return None, out

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq, dtype=jnp.int32))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H * m.v_head_dim)
    return out.astype(q_nope.dtype)


def mla_cache_write(cache, ckv_new, krope_new, start_pos):
    C = cache["ckv"].shape[1]
    n = ckv_new.shape[1]
    pos = start_pos + jnp.arange(n, dtype=jnp.int32)
    slots = pos % C
    return {
        "ckv": cache["ckv"].at[:, slots].set(ckv_new),
        "krope": cache["krope"].at[:, slots].set(krope_new),
        "slot_pos": cache["slot_pos"].at[slots].set(pos),
    }


def mla_prefill(cfg: ModelConfig, p, x, *, start_pos=0, cache=None,
                window=0, rt=None):
    B, S, _ = x.shape
    positions = start_pos + jnp.arange(S, dtype=jnp.int32)
    ckv, krope = _project_latent(cfg, p, x, positions)
    q_nope, q_rope = _project_q(cfg, p, x, positions)
    if cache is not None:
        cache = mla_cache_write(cache, ckv, krope, start_pos)
        kv_c, kr_c, kp = cache["ckv"], cache["krope"], cache["slot_pos"]
    else:
        kv_c, kr_c, kp = ckv, krope, positions
    big = S * kv_c.shape[1] * cfg.num_heads > 1 << 24
    fn = _absorbed_attend_chunked if big else _absorbed_attend
    out = fn(cfg, p, q_nope, q_rope, kv_c, kr_c, positions, kp, window=window)
    return out @ p["wo"], cache


def mla_decode(cfg: ModelConfig, p, x, cache, pos, *, window=0, rt=None):
    positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
    ckv, krope = _project_latent(cfg, p, x, positions)
    q_nope, q_rope = _project_q(cfg, p, x, positions)
    cache = mla_cache_write(cache, ckv, krope, positions[0])
    out = _absorbed_attend(cfg, p, q_nope, q_rope, cache["ckv"],
                           cache["krope"], positions, cache["slot_pos"],
                           window=window)
    return out @ p["wo"], cache
