"""Deterministic fault injection for pressure/chaos testing.

A :class:`FaultPlan` decides — purely from ``(seed, site, call_index)``
— whether the Nth arrival at a named injection *site* fires a fault.
Determinism matters twice over: the chaos suite must be replayable
(hypothesis shrinks on seeds, CI reruns bit-identically), and the
"injected faults never alter unaffected rows" property only makes
sense when the same faults fire at the same points on every run.

Sites are plain strings threaded behind optional ``fault_plan``
attributes; production code never imports this module on the hot path
beyond an ``is None`` check.  The sites wired through the stack:

==================  ====================================================
``alloc``           ``BlockAllocator.alloc``/``alloc_many`` raise
                    ``BlockPoolExhausted`` as if the pool were empty.
``kvstore_get``     ``HostKVStore.get`` raises :class:`InjectedFault`
                    (host-store read IO error).
``kvstore_put``     ``HostKVStore.put`` raises :class:`InjectedFault`
                    (host-store write IO error).
``kvstore_corrupt`` ``HostKVStore.get`` silently bit-flips one byte of
                    the returned entry's cache (detected downstream by
                    the digest check in ``Recycler``).
``replica_step``    ``PagedEngine.decode_batch`` raises
                    :class:`InjectedFault` before doing any work —
                    models a replica dying mid-serve.
==================  ====================================================

Two trigger forms compose per site:

* ``rate`` — fire pseudo-randomly at roughly that fraction of calls,
  derived from ``blake2b(f"{seed}:{site}:{n}")`` so the firing pattern
  is a pure function of the plan seed.
* ``at`` — fire exactly on the given 0-based call indices (for
  regression tests that need fault #3 and nothing else).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Tuple


#: every site wired through the stack (see module docstring); a plan
#: naming anything else is a typo, not a new failure mode — reject it.
KNOWN_SITES = ("alloc", "kvstore_get", "kvstore_put", "kvstore_corrupt",
               "replica_step")


class InjectedFault(RuntimeError):
    """Raised by an injection site standing in for an IO/runtime error.

    Deliberately NOT a subclass of OSError: containment code is written
    to catch ``(InjectedFault, OSError)`` so a handler that only knows
    real IO errors still fails loudly under injection until hardened.
    """


@dataclass
class FaultSpec:
    """Per-site trigger: a firing ``rate`` and/or exact ``at`` indices."""
    rate: float = 0.0
    at: Tuple[int, ...] = ()

    def __post_init__(self):
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        self.at = tuple(sorted(set(int(i) for i in self.at)))
        if self.at and self.at[0] < 0:
            raise ValueError(f"at indices must be >= 0, got {self.at}")


@dataclass
class FaultPlan:
    """Seeded, site-keyed fault schedule.

    ``sites`` maps site name -> :class:`FaultSpec` (or a bare float
    rate / iterable of indices, normalised on construction).  Call
    counters live on the plan so one plan threaded through several
    components sees a single global arrival order per site.
    """
    seed: int = 0
    sites: Dict[str, FaultSpec] = field(default_factory=dict)
    calls: Dict[str, int] = field(default_factory=dict)
    fired: Dict[str, int] = field(default_factory=dict)

    def __post_init__(self):
        norm = {}
        for site, spec in self.sites.items():
            if site not in KNOWN_SITES:
                raise ValueError(f"unknown fault site {site!r}; "
                                 f"known: {KNOWN_SITES}")
            if isinstance(spec, FaultSpec):
                norm[site] = spec
            elif isinstance(spec, (int, float)):
                norm[site] = FaultSpec(rate=float(spec))
            else:
                norm[site] = FaultSpec(at=tuple(spec))
        self.sites = norm

    def should_fire(self, site: str) -> bool:
        """Advance the site's call counter; True when this call faults."""
        spec = self.sites.get(site)
        n = self.calls.get(site, 0)
        self.calls[site] = n + 1
        if spec is None:
            return False
        fire = n in spec.at
        if not fire and spec.rate > 0.0:
            h = hashlib.blake2b(f"{self.seed}:{site}:{n}".encode(),
                                digest_size=8).digest()
            fire = int.from_bytes(h, "big") / float(1 << 64) < spec.rate
        if fire:
            self.fired[site] = self.fired.get(site, 0) + 1
        return fire

    def maybe_fire(self, site: str, message: Optional[str] = None) -> None:
        """Raise :class:`InjectedFault` when the site fires this call."""
        if self.should_fire(site):
            raise InjectedFault(message or f"injected fault at {site} "
                                f"(call {self.calls[site] - 1})")

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {site: {"calls": self.calls.get(site, 0),
                       "fired": self.fired.get(site, 0)}
                for site in set(self.calls) | set(self.sites)}


def plan_from_spec(seed: int, **site_specs) -> FaultPlan:
    """Convenience: ``plan_from_spec(7, alloc=0.1, kvstore_get=(2, 5))``."""
    return FaultPlan(seed=seed, sites=dict(site_specs))
