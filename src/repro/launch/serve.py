"""Serving launcher: build a recycling engine and run the paper's two-phase
evaluation (cache construction + baseline vs recycled), or an interactive
request loop.

  PYTHONPATH=src python -m repro.launch.serve --arch dialogpt-medium \
      --reduced --max-new 16 [--partial] [--compare]

``ShardedServer`` (PR 8) is the mesh-sharded front end: N data-parallel
``PagedEngine`` replicas, each TP-sharding its block pool over a
(data=1, model=T) sub-mesh, all sharing ONE host L2 (``HostKVStore``
behind one ``Recycler``).  A prefix admitted on replica 0 is a
block-granular host promotion — not a recompute — on replica 1.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve --reduced --mesh 2 2
"""
from __future__ import annotations

import argparse
import json
import os
import threading
from typing import List, Optional, Sequence, Union

import jax

from repro.configs import get_config
from repro.core import HashEmbedder
from repro.core.metrics import RunMetrics, summarize_runs
from repro.data.pipeline import paper_prompt_sets
from repro.models import init_params
from repro.serving import Engine, PagedEngine
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     RequestOutcome)


class _SharedRecycler:
    """One replica's view of the SHARED Recycler (the L2 tier).

    All replicas funnel admit/lookup through one Recycler instance; this
    proxy serializes each whole operation (the recycler mutates its store
    plus three retrieval mirrors, which must stay consistent under the
    replica threads) and tags every admitted entry with the replica that
    produced it, so a hit on an entry admitted ELSEWHERE is counted as a
    cross-replica promotion candidate."""

    def __init__(self, inner, replica: int, lock, admitted_by: dict,
                 shared_stats: dict):
        self._inner = inner
        self._replica = replica
        self._lock = lock
        self._admitted_by = admitted_by
        self._shared_stats = shared_stats

    def admit(self, *args, **kw):
        with self._lock:
            entry = self._inner.admit(*args, **kw)
            if entry is not None:     # None = store refused (IO fault)
                self._admitted_by[entry.entry_id] = self._replica
            return entry

    def lookup(self, *args, **kw):
        with self._lock:
            res = self._inner.lookup(*args, **kw)
            if res.hit and res.entry is not None:
                src = self._admitted_by.get(res.entry.entry_id,
                                            self._replica)
                if src != self._replica:
                    self._shared_stats["cross_replica_promotions"] += 1
            return res

    def lookup_semantic(self, *args, **kw):
        with self._lock:
            return self._inner.lookup_semantic(*args, **kw)

    def __getattr__(self, name):
        return getattr(self._inner, name)


class ShardedServer:
    """N data-parallel ``PagedEngine`` replicas over a shared host L2.

    Replica r owns its own devices ((data=1, model=tp) sub-mesh — see
    ``launch.mesh.serving_meshes``), its own block pool (TP-sharded over
    the KV-head axis), allocator, trie, and table mirrors.  What is
    SHARED is the host tier: one ``Recycler``/``HostKVStore``, so any
    replica serves any admitted prefix as a block-granular promotion.

    ``residency`` is the cross-replica read view of block residency: it
    peeks every replica's L1 trie (no recency stamping) and the router
    prefers the replica already holding the deepest resident prefix.
    The tries themselves stay replica-local — the view is advisory for
    routing, NOT a coherent directory (a block can be evicted between
    the peek and the admission; the admission then falls back to the
    shared L2 or a recompute, token output unchanged either way).

    ``run`` drives each replica's ``ContinuousBatchingScheduler`` in its
    own thread: engine dispatches release the GIL while the devices
    compute, so replicas genuinely overlap."""

    def __init__(self, cfg, params, *, replicas: int = 1, tp: int = 1,
                 meshes=None, use_pallas: bool = False, **engine_kw):
        from repro.launch.mesh import serving_meshes
        from repro.sharding import serving_runtime

        if meshes is None:
            meshes = serving_meshes(replicas, tp)
        self.lock = threading.RLock()
        self._admitted_by: dict = {}
        self.shared_stats = {"cross_replica_promotions": 0,
                             "replica_failures": 0,
                             "rerouted_requests": 0}
        self.engines: List[PagedEngine] = []
        shared = None
        for r, mesh in enumerate(meshes):
            rt = serving_runtime(mesh, use_pallas=use_pallas)
            kw = dict(engine_kw)
            if shared is not None:
                kw["recycler"] = shared
            eng = PagedEngine(cfg, params, rt=rt, **kw)
            if shared is None:
                shared = eng.recycler          # replica 0's becomes the L2
            eng.recycler = _SharedRecycler(shared, r, self.lock,
                                           self._admitted_by,
                                           self.shared_stats)
            self.engines.append(eng)
        self.recycler = shared

    # ------------------------------------------------------------------
    def residency(self, token_ids) -> List[int]:
        """Per-replica resident prefix depth for ``token_ids`` (trie peek,
        no recency stamp) — the cross-replica read view."""
        return [eng.trie.peek(token_ids)[0] for eng in self.engines]

    def _route(self, prompt: str, load: List[int]) -> int:
        ids = self.engines[0].tok.encode(prompt)
        depths = self.residency(ids)
        best = max(range(len(self.engines)),
                   key=lambda r: (depths[r], -load[r]))
        return best

    # ------------------------------------------------------------------
    def run(self, prompts: Sequence[str], *,
            replica: Union[None, int, Sequence[int]] = None,
            concurrent: Optional[bool] = None, **req_kw):
        """Route + serve ``prompts``; returns GenResults in input order.

        ``replica`` pins requests to a replica (int: all; sequence:
        per-prompt); None routes by residency then load.  ``concurrent``
        None = auto: replica threads only when the host has more than
        one core (engine dispatches release the GIL during device
        compute, but on a single core interleaved threads can only add
        contention, so the replicas run back-to-back instead)."""
        scheds = [ContinuousBatchingScheduler(eng) for eng in self.engines]
        load = [0] * len(self.engines)
        placed = []
        for i, p in enumerate(prompts):
            if replica is None:
                r = self._route(p, load)
            elif isinstance(replica, int):
                r = replica
            else:
                r = replica[i]
            placed.append((r, scheds[r].submit(p, **req_kw)))
            load[r] += 1
        if concurrent is None:
            concurrent = (os.cpu_count() or 1) > 1
        failed: dict = {}          # replica -> error message
        if concurrent and len(self.engines) > 1:
            threads = [threading.Thread(
                target=self._run_contained, args=(r, s, failed),
                daemon=True) for r, s in enumerate(scheds)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        else:
            for r, s in enumerate(scheds):
                self._run_contained(r, s, failed)
        if failed:
            self._reroute_failed(scheds, failed)
        return [req.result if req.result is not None else req.error
                for _, req in placed]

    def _run_contained(self, r: int, sched, failed: dict) -> None:
        """Drive one replica's scheduler; a replica failure is CONTAINED:
        only ITS in-flight requests terminate (typed ERRORED — their pool
        rows died with the replica), its untouched queue survives for
        rerouting, and the other replicas never notice."""
        try:
            sched.run()
        except Exception as e:              # noqa: BLE001 — containment
            failed[r] = f"replica {r} failed: {e}"
            with self.lock:
                self.shared_stats["replica_failures"] += 1
            for slot, req in list(sched.in_flight.items()):
                req.outcome = RequestOutcome.ERRORED
                req.error = failed[r]
                sched.completed.append(req)
            sched.in_flight.clear()

    def _reroute_failed(self, scheds, failed: dict) -> None:
        """Resubmit failed replicas' QUEUED (never-admitted) requests to
        the first healthy replica — serially, after the fleet drained, so
        the reroute cannot race a second failure (and a failure DURING
        the reroute re-enters the same containment).  Anything the shared
        L2 learned before the failure still serves these requests warm."""
        pending = list(failed)
        while pending:
            r = pending.pop(0)
            leftovers = list(scheds[r]._queue)
            scheds[r]._queue.clear()
            if not leftovers:
                continue
            healthy = [x for x in range(len(scheds)) if x not in failed]
            if not healthy:
                for req in leftovers:
                    req.outcome = RequestOutcome.ERRORED
                    req.error = "no healthy replica to reroute to"
                    scheds[r].completed.append(req)
                continue
            dst = healthy[0]
            for req in leftovers:
                scheds[dst]._queue.append(req)
                with self.lock:
                    self.shared_stats["rerouted_requests"] += 1
            before = set(failed)
            self._run_contained(dst, scheds[dst], failed)
            pending.extend(x for x in failed if x not in before)

    def check_invariants(self) -> None:
        for eng in self.engines:
            eng.check_invariants()

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        per = []
        for eng in self.engines:
            per.append({
                "stats": dict(eng.stats),
                "device_kv_bytes_in_use": eng.device_kv_bytes_in_use(),
                "device_kv_bytes_per_device":
                    eng.device_kv_bytes_per_device(),
                "kv_tp_degree": eng.kv_tp_degree(),
            })
        agg = {
            "replicas": len(self.engines),
            "cross_replica_promotions":
                self.shared_stats["cross_replica_promotions"],
            "host_entries": len(self.recycler.store),
            "host_bytes": self.recycler.store.total_bytes,
            "per_replica": per,
        }
        return agg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dialogpt-medium")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--partial", action="store_true",
                    help="enable beyond-paper block-radix partial reuse")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--compare", action="store_true",
                    help="run the paper's baseline-vs-recycled table")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", type=int, nargs=2, metavar=("D", "T"),
                    default=None,
                    help="serve through ShardedServer: D data-parallel "
                         "PagedEngine replicas x T-way TP block pools "
                         "(needs D*T devices; force host devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    if args.mesh is not None:
        dp, tp = args.mesh
        server = ShardedServer(cfg, params, replicas=dp, tp=tp,
                               max_new_tokens=args.max_new,
                               enable_partial=args.partial,
                               block_size=args.block_size)
        cache_prompts, test_prompts = paper_prompt_sets("data")
        print(f"mesh {dp}x{tp}: admitting {len(cache_prompts)} prompts "
              f"on replica 0 ...")
        server.run(cache_prompts, replica=0, admit=True)
        results = server.run(test_prompts)
        for p, r in zip(test_prompts, results):
            print(f"[{r.mode:13s}] reuse={r.reuse_depth:3d}/{r.prompt_tokens}"
                  f"  '{p[:40]}...'")
        print(json.dumps(server.stats(), indent=1, default=str))
        return

    eng = Engine(cfg, params, max_new_tokens=args.max_new,
                 enable_partial=args.partial, block_size=args.block_size)

    cache_prompts, test_prompts = paper_prompt_sets("data")
    print(f"building KV cache for {len(cache_prompts)} prompts ...")
    eng.precache(cache_prompts)
    print(f"store: {len(eng.recycler.store)} entries, "
          f"{eng.recycler.store.total_bytes / 1e6:.1f} MB host bytes")

    if not args.compare:
        for p in test_prompts:
            r = eng.generate(p)
            print(f"[{r.mode:13s}] reuse={r.reuse_depth:3d}/{r.prompt_tokens}"
                  f" {r.latency_s*1e3:7.1f} ms  sim={r.prompt_similarity:.2f}"
                  f"  '{p[:40]}...'")
        return

    # paper §4.4 two-phase comparison with warmup (jit compile excluded)
    for p in test_prompts:
        eng.warmup(p, use_recycling=False)
        eng.warmup(p)
    base, rec = [], []
    for p in test_prompts:
        b = eng.generate(p, use_recycling=False)
        r = eng.generate(p)
        base.append(RunMetrics(p, "baseline", b.latency_s, b.prompt_tokens,
                               b.gen_tokens, output_text=b.text))
        rec.append(RunMetrics(p, "recycled", r.latency_s, r.prompt_tokens,
                              r.gen_tokens, r.reuse_depth, r.cache_hit,
                              r.prompt_similarity, r.mode, r.text))
        sp = (b.latency_s - r.latency_s) / b.latency_s * 100
        print(f"reuse={r.reuse_depth:3d}/{r.prompt_tokens:3d} "
              f"base={b.latency_s*1e3:7.1f}ms rec={r.latency_s*1e3:7.1f}ms "
              f"speedup={sp:5.1f}%  same_output={b.text == r.text}")
    table = summarize_runs(base, rec, embedder=HashEmbedder())
    print(json.dumps(table, indent=1))


if __name__ == "__main__":
    main()
