"""Training loop: jit'd (optionally pjit-sharded) train step + driver.

``make_train_step`` closes over config/runtime and returns a donated-state
step function; the launcher supplies in/out shardings for the production
mesh (repro.launch.train), while examples run it on CPU unsharded.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import train_loss
from repro.runtime import Runtime, LOCAL
from repro.training.optimizer import adamw_init, adamw_update, cosine_lr


def make_train_step(cfg: ModelConfig, rt: Runtime = LOCAL, *,
                    lr=3e-4, warmup: int = 100, total_steps: int = 1000,
                    jit: bool = True):
    schedule = cosine_lr(lr, warmup, total_steps) if not callable(lr) else lr

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch, rt), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, schedule)
        return params, opt_state, {**metrics, **opt_metrics}

    if jit:
        step = jax.jit(step, donate_argnums=(0, 1))
    return step


def train(cfg: ModelConfig, params, batches: Iterable[Dict], *,
          steps: int, rt: Runtime = LOCAL, lr=3e-4, warmup: int = 100,
          log_every: int = 10, callback: Optional[Callable] = None):
    """Simple driver: returns (params, opt_state, history)."""
    opt_state = adamw_init(params)
    step_fn = make_train_step(cfg, rt, lr=lr, warmup=warmup,
                              total_steps=steps)
    history = []
    it = iter(batches)
    t0 = time.perf_counter()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(m)
    return params, opt_state, history
