"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table entry).

[arXiv:2501.kimi2] Kimi K2.  61L, d_model=7168, 64 heads (GQA kv=8),
expert d_ff=2048, vocab=163840; 384 routed experts top-8 + 1 shared,
first layer dense (d_ff 18432).
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    source="arXiv:2501.kimi2 (Kimi K2 1T-A32B)",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,                   # routed-expert d_ff (assigned)
    vocab_size=163_840,
    head_dim=128,
    sliding_window=8192,
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        first_dense_layers=1,
        dense_d_ff=18432,
        capacity_factor=1.25,
    ),
)
