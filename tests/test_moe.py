"""MoE dispatch correctness: shard_map-free path vs dense oracle, aux loss,
capacity behaviour, and gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import _moe_shard, init_moe, moe_ffn, moe_ffn_oracle


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("kimi-k2-1t-a32b").reduced()
    p = init_moe(cfg, jax.random.PRNGKey(3), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))
    return cfg, p, x


def test_moe_matches_oracle(setup):
    cfg, p, x = setup
    y, aux = moe_ffn(cfg, p, x)
    y_ref = moe_ffn_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.0


def test_moe_deepseek_shared_experts(setup):
    cfg = get_config("deepseek-v2-236b").reduced()
    p = init_moe(cfg, jax.random.PRNGKey(5), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 8, cfg.d_model))
    y, aux = moe_ffn(cfg, p, x)
    y_ref = moe_ffn_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)


def test_moe_grads_flow_to_all_parts(setup):
    cfg, p, x = setup

    def loss(p):
        y, aux = moe_ffn(cfg, p, x)
        return jnp.sum(jnp.square(y.astype(jnp.float32))) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0      # router learns
    assert float(jnp.abs(g["we_gate"]).sum()) > 0     # experts learn
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())


def test_moe_permutation_invariance(setup):
    """Shuffling token order then unshuffling gives the same outputs
    (dispatch is per-token)."""
    cfg, p, x = setup
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    perm = jax.random.permutation(jax.random.PRNGKey(9), B * S)
    y1, _ = moe_ffn(cfg, p, x)
    y2p, _ = moe_ffn(cfg, p, xf[perm].reshape(B, S, d))
    y2 = jnp.zeros_like(xf).at[perm].set(y2p.reshape(B * S, d))
    np.testing.assert_allclose(np.asarray(y1.reshape(B * S, d)),
                               np.asarray(y2), rtol=2e-3, atol=2e-3)


def test_capacity_drop_is_bounded():
    """With tiny capacity_factor, dropped tokens produce zero output (not
    garbage) — the standard Switch behaviour."""
    import dataclasses
    cfg = get_config("kimi-k2-1t-a32b").reduced()
    p = init_moe(cfg, jax.random.PRNGKey(3), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 512, cfg.d_model))
    y, aux = moe_ffn(cfg, p, x)
    assert bool(jnp.isfinite(y).all())
