"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (produced by ``repro.launch.dryrun --all``)
and emits one row per (arch x shape x mesh) with the three terms, the
dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(pattern="*__pod16x16.json"):
    recs = []
    for f in sorted(glob.glob(os.path.join(ROOT, pattern))):
        with open(f) as fh:
            d = json.load(fh)
        if d.get("ok"):
            recs.append(d)
    return recs


def roofline_rows(pattern="*.json"):
    out = []
    for d in load_records(pattern):
        r = d["roofline"]
        tag = f"{d['arch']}.{d['shape']}.{d['mesh']}"
        if d.get("recycled"):
            tag += ".rec"
        dominant = r["bottleneck"]
        term_us = {"compute": r["compute_s"], "memory": r["memory_s"],
                   "collective": r["collective_s"]}[dominant] * 1e6
        out.append((f"roofline.{tag}", term_us,
                    f"bn={dominant};c={r['compute_s']:.4f}s;"
                    f"m={r['memory_s']:.4f}s;coll={r['collective_s']:.4f}s;"
                    f"useful={min(r['useful_ratio'], 1.0):.2f};"
                    f"fits={d.get('fits_hbm')}"))
    if not out:
        out.append(("roofline.missing", 0.0,
                    "run: python -m repro.launch.dryrun --all"))
    return out
