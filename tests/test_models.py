"""Model-layer unit tests: attention paths, caches, RoPE, norms, RG-LRU,
RWKV state semantics, MLA equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import apply_rope, layernorm, rmsnorm

RNG = np.random.default_rng(11)


def _randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


class TestNormsRope:
    def test_rmsnorm_unit_scale(self):
        x = _randn(4, 64)
        y = rmsnorm(x, jnp.ones((64,)))
        rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)

    def test_layernorm_zero_mean(self):
        x = _randn(4, 64)
        y = layernorm(x, jnp.ones((64,)), jnp.zeros((64,)))
        np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0,
                                   atol=1e-5)

    def test_rope_preserves_norm_and_relative(self):
        x = _randn(1, 8, 2, 32)
        pos = jnp.arange(8)
        y = apply_rope(x, pos, 10000.0)
        np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                                   np.asarray(jnp.linalg.norm(x, axis=-1)),
                                   rtol=1e-5)
        # relative property: <R(p)q, R(p+d)k> independent of p
        q, k = _randn(1, 1, 1, 32), _randn(1, 1, 1, 32)
        def dot_at(p, d):
            qa = apply_rope(q, jnp.asarray([p]), 10000.0)
            ka = apply_rope(k, jnp.asarray([p + d]), 10000.0)
            return float(jnp.sum(qa * ka))
        assert abs(dot_at(3, 5) - dot_at(40, 5)) < 1e-3


class TestAttentionPaths:
    def test_chunked_equals_direct(self):
        q = _randn(2, 64, 4, 32)
        k = _randn(2, 64, 2, 32)
        v = _randn(2, 64, 2, 32)
        pos = jnp.arange(64)
        a = attn.attend_direct(q, k, v, pos, pos, causal=True)
        b = attn.attend_chunked(q, k, v, pos, pos, causal=True,
                                q_chunk=16, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)

    def test_chunked_window_equals_direct_window(self):
        q = _randn(1, 128, 2, 16)
        k = _randn(1, 128, 2, 16)
        v = _randn(1, 128, 2, 16)
        pos = jnp.arange(128)
        a = attn.attend_direct(q, k, v, pos, pos, causal=True, window=24)
        b = attn.attend_chunked(q, k, v, pos, pos, causal=True, window=24,
                                q_chunk=32, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)

    def test_cache_ring_wraps(self):
        cache = attn.init_kv_cache(1, 8, 1, 4, jnp.float32)
        k = _randn(1, 4, 1, 4)
        v = _randn(1, 4, 1, 4)
        cache = attn.cache_write(cache, k, v, 6)   # positions 6..9 wrap
        sp = np.asarray(cache["slot_pos"])
        assert sp[6] == 6 and sp[7] == 7 and sp[0] == 8 and sp[1] == 9
        np.testing.assert_array_equal(np.asarray(cache["k"][0, 0]),
                                      np.asarray(k[0, 2]))

    def test_bidirectional_no_causal(self):
        q = _randn(1, 8, 2, 16)
        k = _randn(1, 8, 2, 16)
        v = _randn(1, 8, 2, 16)
        pos = jnp.arange(8)
        out = attn.attend_direct(q, k, v, pos, pos, causal=False)
        # position 0 attends to everything: differs from causal result
        out_c = attn.attend_direct(q, k, v, pos, pos, causal=True)
        assert not np.allclose(np.asarray(out[0, 0]), np.asarray(out_c[0, 0]))


class TestMLA:
    def test_absorbed_chunked_equals_direct(self):
        cfg = get_config("deepseek-v2-236b").reduced()
        p = mla_mod.init_mla(cfg, jax.random.PRNGKey(1), jnp.float32)
        x = _randn(2, 32, cfg.d_model)
        pos = jnp.arange(32)
        ckv, krope = mla_mod._project_latent(cfg, p, x, pos)
        qn, qr = mla_mod._project_q(cfg, p, x, pos)
        a = mla_mod._absorbed_attend(cfg, p, qn, qr, ckv, krope, pos, pos)
        b = mla_mod._absorbed_attend_chunked(cfg, p, qn, qr, ckv, krope,
                                             pos, pos, q_chunk=8,
                                             kv_chunk=8)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)

    def test_latent_cache_is_small(self):
        """The recycling-synergy claim: MLA cache bytes << MHA equivalent."""
        cfg = get_config("deepseek-v2-236b")
        m = cfg.mla
        latent = m.kv_lora_rank + m.qk_rope_head_dim
        mha = 2 * cfg.num_heads * cfg.head_dim
        assert latent * 45 < mha                   # >45x smaller per token


class TestRecurrent:
    def test_rglru_prefill_equals_stepwise(self):
        cfg = get_config("recurrentgemma-9b").reduced()
        p = rglru_mod.init_rglru(cfg, jax.random.PRNGKey(2), jnp.float32)
        x = _randn(2, 12, cfg.d_model)
        st0 = rglru_mod.init_rglru_state(cfg, 2, jnp.float32)
        y_all, st_all = rglru_mod.rglru_prefill(cfg, p, x, st0)
        st = st0
        ys = []
        for t in range(12):
            y, st = rglru_mod.rglru_decode(cfg, p, x[:, t:t + 1], st)
            ys.append(y)
        y_step = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_all), np.asarray(y_step),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st_all["h"]),
                                   np.asarray(st["h"]), rtol=2e-4, atol=2e-4)

    def test_rwkv_tmix_prefill_equals_stepwise(self):
        cfg = get_config("rwkv6-3b").reduced()
        p = rwkv_mod.init_rwkv_tmix(cfg, jax.random.PRNGKey(3), jnp.float32)
        x = _randn(1, 10, cfg.d_model)
        st0 = rwkv_mod.init_rwkv_state(cfg, 1, jnp.float32)
        y_all, st_all = rwkv_mod.rwkv_tmix(cfg, p, x, st0)
        st = dict(st0)
        ys = []
        for t in range(10):
            y, st = rwkv_mod.rwkv_tmix(cfg, p, x[:, t:t + 1], st)
            ys.append(y)
        np.testing.assert_allclose(np.asarray(y_all),
                                   np.asarray(jnp.concatenate(ys, 1)),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st_all["wkv"]),
                                   np.asarray(st["wkv"]),
                                   rtol=2e-4, atol=2e-4)
