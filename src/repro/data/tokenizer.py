"""Deterministic byte-level tokenizer.

The paper uses GPT-2 BPE via HF; offline we cannot ship merge tables, so the
framework tokenizes at the byte level (vocab = 256 bytes + specials) and
*folds* ids into the model's vocab size when smaller (reduced smoke configs).
What matters for the paper's mechanism is that tokenization is a pure
deterministic function of the text — the exact-prefix test then behaves
identically to BPE: equal text prefixes <=> equal token prefixes.
"""
from __future__ import annotations

from typing import List

import numpy as np

PAD, BOS, EOS = 0, 1, 2
_SPECIALS = 3


class ByteTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size >= 16
        self.vocab_size = vocab_size

    def _fold(self, b: int) -> int:
        return _SPECIALS + b % (self.vocab_size - _SPECIALS)

    def encode(self, text: str, *, bos: bool = True) -> np.ndarray:
        ids = [BOS] if bos else []
        ids.extend(self._fold(b) for b in text.encode("utf-8"))
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        out = bytearray()
        for t in np.asarray(ids).ravel():
            t = int(t)
            if t < _SPECIALS:
                continue
            out.append((t - _SPECIALS) % 256)
        return out.decode("utf-8", errors="replace")
