"""Token sampling: deterministic greedy (the paper's do_sample=False) plus
temperature / top-k for the examples.

Every function is batch-shaped: logits (B, V) in, tokens (B,) out — greedy
reduces over the vocab axis only, and ``sample_batched`` draws one
independent categorical per row, so the same functions serve the serial
engine (B=1) and the continuous-batching slot pool (B=max_batch) without a
reshape."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_logits(logits, rng, *, temperature: float = 1.0, top_k: int = 0):
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[..., -1:], -jnp.inf, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)


def _static_off(x, off) -> bool:
    """True when a penalty parameter is STATICALLY known to be inert
    (python/numpy scalar equal to its no-op value) — lets the zero-penalty
    fast paths below stay untouched without tracing a data-dependent
    branch."""
    if isinstance(x, jax.core.Tracer):
        return False
    try:
        import numpy as _np
        return bool(_np.all(_np.asarray(x) == off))
    except Exception:          # pragma: no cover - exotic array types
        return False


def apply_penalties(logits, gen_tokens, *, repetition_penalty=1.0,
                    presence_penalty=0.0):
    """Per-row repetition / presence penalties over each row's OWN
    generated-token set: logits (B, V), ``gen_tokens`` (B, G) int32 with
    -1 padding for rows that generated fewer than G tokens.

    One fused scatter builds the (B, V) seen-mask — tokens < 0 land in a
    dummy column V that is sliced away, so padding never penalises token
    0.  Repetition penalty follows the CTRL convention (divide positive
    logits, multiply negative); presence penalty subtracts a flat amount
    from every seen token.  Both accept a scalar or per-row (B,) vector;
    rows at (1.0, 0.0) are returned bit-identical."""
    B, V = logits.shape
    rp = jnp.broadcast_to(jnp.asarray(repetition_penalty, jnp.float32), (B,))
    pp = jnp.broadcast_to(jnp.asarray(presence_penalty, jnp.float32), (B,))
    tok = jnp.asarray(gen_tokens, jnp.int32)
    tok = jnp.where(tok >= 0, tok, V)           # padding -> dummy column
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    seen = jnp.zeros((B, V + 1), jnp.bool_).at[rows, tok].set(True)[:, :V]
    pen = jnp.where(logits > 0, logits / rp[:, None], logits * rp[:, None])
    pen = pen - pp[:, None]
    active = (rp != 1.0) | (pp != 0.0)          # zero-penalty rows exact
    return jnp.where(seen & active[:, None], pen, logits)


def sample_batched(logits, rng, *, temperature=0.0, top_k=0,
                   top_k_cap: int = 64, repetition_penalty=1.0,
                   presence_penalty=0.0, gen_tokens=None):
    """Per-row sampling for the slot/paged pools: logits (B, V) -> (B,).

    ``temperature`` may be a scalar or a per-row (B,) vector — rows at
    temperature 0 decode greedily while others sample, so one pool can mix
    deterministic and sampled requests in a single dispatch.  The rng is
    split per row; pass a fresh key each step.

    ``top_k`` likewise accepts a scalar (static, back-compat) or a per-row
    (B,) int vector: row b keeps its ``top_k[b]`` best logits (0 = no
    filter).  Per-row k is dynamic, so one sort of the top ``top_k_cap``
    (static) logits serves every row; callers that know the batch's max k
    should pass it as the cap (the pool engines do) — a row asking for
    k > top_k_cap is clamped to the cap.

    ``repetition_penalty`` / ``presence_penalty`` (scalar or per-row (B,))
    reshape the logits over each row's generated-token set ``gen_tokens``
    (B, G; -1-padded) BEFORE the greedy/temperature split, so penalties
    affect greedy rows too; when both are statically inert (1.0 / 0.0) the
    transform is skipped entirely and every fast path below — including
    bit-identical greedy — is preserved."""
    penalties_on = gen_tokens is not None and not (
        _static_off(repetition_penalty, 1.0)
        and _static_off(presence_penalty, 0.0))
    if penalties_on:
        logits = apply_penalties(logits, gen_tokens,
                                 repetition_penalty=repetition_penalty,
                                 presence_penalty=presence_penalty)
    if isinstance(temperature, (int, float)) and temperature <= 0.0:
        return greedy(logits)                # static shortcut: trace-safe
    if not isinstance(temperature, jax.core.Tracer):
        # concrete all-greedy batch (every row at temperature 0): plain
        # batched argmax — no rng split, no per-row dynamic top-k sort.
        # Tracer-guarded so the check never forces a value inside jit.
        if not bool(jnp.any(jnp.asarray(temperature) > 0.0)):
            return greedy(logits)
    temperature = jnp.asarray(temperature, jnp.float32)
    t = jnp.broadcast_to(temperature, (logits.shape[0],))
    scaled = logits / jnp.maximum(t, 1e-6)[:, None]
    if isinstance(top_k, (int,)) and top_k:
        vals, _ = jax.lax.top_k(scaled, top_k)
        scaled = jnp.where(scaled < vals[..., -1:], -jnp.inf, scaled)
    elif not isinstance(top_k, int):
        k = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32),
                             (logits.shape[0],))
        cap = min(top_k_cap, logits.shape[-1])
        vals, _ = jax.lax.top_k(scaled, cap)          # (B, cap) sorted desc
        idx = jnp.clip(k, 1, cap) - 1
        thr = jnp.take_along_axis(vals, idx[:, None], axis=1)
        scaled = jnp.where((k > 0)[:, None] & (scaled < thr),
                           -jnp.inf, scaled)
    keys = jax.random.split(rng, logits.shape[0])
    drawn = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(t > 0.0, drawn, greedy(logits))
