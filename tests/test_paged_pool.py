"""Paged block-table pool: token-for-token equivalence with the dense
slot pool (and therefore with serial ``generate``) across every admission
mode, real prefix sharing (refcount > 1, fewer device bytes than the
dense pool), zero host→device traffic on warm-prefix admissions, and the
block-allocator invariants (refcounts, free/live partition, no aliasing).

The dense ``BatchedEngine`` stays the equivalence reference: every paged
behavior is asserted against it (or serial) rather than against golden
outputs.  Property-style allocator tests run only when hypothesis is
installed, mirroring tests/test_slot_pool.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import BlockAllocator, BlockPoolExhausted, BlockTrie
from repro.data.tokenizer import EOS
from repro.models import init_params
from repro.models.cache import cache_bytes
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           Engine, PagedEngine)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CACHED = [
    "the quick brown fox jumps over the lazy dog today",
    "what is the capital of france and why",
]
REQUESTS = [
    (CACHED[0] + " and tomorrow", "exact_prefix"),
    ("the quick brown fox jumps over a red fence", "partial_block"),
    ("zzz qqq completely unrelated 12345", "miss"),
    (CACHED[1] + " is it paris", "exact_prefix"),
]


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engines(stack, *, max_new=6, max_batch=3, capacity=128):
    cfg, params = stack
    ser = Engine(cfg, params, max_new_tokens=max_new, block_size=8,
                 enable_partial=True)
    ser.precache(CACHED)
    pag = PagedEngine(cfg, params, max_batch=max_batch, capacity=capacity,
                      max_new_tokens=max_new, block_size=8,
                      enable_partial=True)
    pag.precache(CACHED)
    return ser, pag


# ---------------------------------------------------------------------------
# equivalence: paged == dense == serial, all admission modes
# ---------------------------------------------------------------------------
def test_paged_equals_serial_all_modes(stack):
    ser, pag = _engines(stack)
    serial = {p: ser.generate(p) for p, _ in REQUESTS}

    sched = ContinuousBatchingScheduler(pag)
    reqs = [sched.submit(p) for p, _ in REQUESTS]
    sched.run()
    pag.check_invariants()

    for (p, want_mode), req in zip(REQUESTS, reqs):
        s, b = serial[p], req.result
        # the paged tier may upgrade a host hit to resident_block when the
        # prefix became device-resident mid-batch; tokens must not drift
        assert b.mode in (want_mode, "resident_block"), (p, b.mode)
        assert b.text == s.text, (p, b.mode)
        np.testing.assert_array_equal(b.token_ids, s.token_ids)
        assert b.gen_tokens == s.gen_tokens
        assert b.prompt_tokens == s.prompt_tokens


def test_paged_equals_dense_pool(stack):
    """Same workload through the dense slot pool and the paged pool over
    identical recycler contents: identical tokens, request by request."""
    cfg, params = stack
    dense = BatchedEngine(cfg, params, max_batch=3, capacity=128,
                          max_new_tokens=6, block_size=8,
                          enable_partial=True)
    dense.precache(CACHED)
    _, pag = _engines(stack)

    dsched = ContinuousBatchingScheduler(dense)
    dreqs = [dsched.submit(p) for p, _ in REQUESTS]
    dsched.run()
    psched = ContinuousBatchingScheduler(pag)
    preqs = [psched.submit(p) for p, _ in REQUESTS]
    psched.run()

    for (p, _), d, q in zip(REQUESTS, dreqs, preqs):
        assert q.result.text == d.result.text, p
        np.testing.assert_array_equal(q.result.token_ids,
                                      d.result.token_ids)


def test_paged_early_eos_equivalence(stack, monkeypatch):
    """Early-EOS rows free their blocks mid-flight; survivors must keep
    decoding exactly like their serial runs (same greedy remap trick as
    the dense-pool test, patched once for all three paths)."""
    import repro.serving.engine as engine_mod

    def eos_greedy(logits):
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(g % 5 == 1, jnp.int32(EOS), g)

    monkeypatch.setattr(engine_mod, "greedy", eos_greedy)
    ser, pag = _engines(stack, max_new=8)
    serial = {p: ser.generate(p) for p, _ in REQUESTS}
    assert any(r.gen_tokens < 8 and r.token_ids[-1] == EOS
               for r in serial.values()), "remap produced no early EOS"

    sched = ContinuousBatchingScheduler(pag)
    reqs = [sched.submit(p) for p, _ in REQUESTS]
    sched.run()
    pag.check_invariants()
    for (p, _), req in zip(REQUESTS, reqs):
        s, b = serial[p], req.result
        assert b.text == s.text and b.gen_tokens == s.gen_tokens
        np.testing.assert_array_equal(b.token_ids, s.token_ids)


def test_paged_mixed_budgets_and_refill(stack):
    """Mid-flight slot refill over the paged pool: different budgets free
    rows at different steps; outputs stay identical to serial."""
    ser, pag = _engines(stack, max_new=8, max_batch=2)
    prompts = [p for p, _ in REQUESTS] + ["one more cold prompt"]
    budgets = [8, 3, 5, 2, 8]
    serial = [ser.generate(p, max_new_tokens=n)
              for p, n in zip(prompts, budgets)]

    sched = ContinuousBatchingScheduler(pag)
    reqs = [sched.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, budgets)]
    sched.run()
    pag.check_invariants()
    assert sched.stats["slot_reuses"] >= 1
    for s, req in zip(serial, reqs):
        assert req.result.text == s.text
        np.testing.assert_array_equal(req.result.token_ids, s.token_ids)


# ---------------------------------------------------------------------------
# sharing: one physical prefix, many tables
# ---------------------------------------------------------------------------
def test_shared_prefix_blocks_refcount_gt_one(stack):
    """Two in-flight requests extending the same cached prompt must NAME
    the same pool blocks (refcount > 1), not hold private copies."""
    cfg, params = stack
    pag = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=8, block_size=8, enable_partial=True)
    pag.precache(CACHED)
    sched = ContinuousBatchingScheduler(pag)
    sched.submit(CACHED[0] + " tonight")
    sched.submit(CACHED[0] + " tomorrow")
    sched.step()                      # both admitted, both still in flight
    pag.check_invariants()

    rows = [set(b for b in pag._tables[i] if b != 0) for i in range(2)]
    both = rows[0] & rows[1]
    assert both, "no pool block is shared between the two tables"
    assert all(pag.allocator.refcount(b) >= 2 for b in both)
    sched.run()
    pag.check_invariants()


def test_paged_uses_fewer_device_bytes_than_dense(stack):
    """At batch 8 the dense pool pays max_batch * capacity slots up
    front; the paged pool's referenced bytes track actual lengths and
    shared prefixes are counted once."""
    cfg, params = stack
    dense = BatchedEngine(cfg, params, max_batch=8, capacity=128,
                          max_new_tokens=6, block_size=8,
                          enable_partial=True)
    dense.precache(CACHED)
    pag = PagedEngine(cfg, params, max_batch=8, capacity=128,
                      max_new_tokens=6, block_size=8, enable_partial=True)
    pag.precache(CACHED)

    sched = ContinuousBatchingScheduler(pag)
    for i in range(8):                # all extend the same cached prompt
        sched.submit(CACHED[0] + f" variant {i}")
    sched.step()
    pag.check_invariants()
    used = pag.device_kv_bytes_in_use()
    dense_bytes = cache_bytes(dense.pool)
    assert used < dense_bytes, (used, dense_bytes)
    sched.run()


def test_warm_admission_no_host_copy(stack):
    """Once a prefix is device-resident, re-admitting it must perform no
    host→device cache copy at all (the L1 zero-copy contract)."""
    _, pag = _engines(stack)
    sched = ContinuousBatchingScheduler(pag)
    sched.submit(CACHED[0] + " first pass")
    sched.run()
    h2d = pag.stats["h2d_copies"]
    assert pag.stats["host_promotions"] >= 1

    sched2 = ContinuousBatchingScheduler(pag)
    r = sched2.submit(CACHED[0] + " second pass")
    sched2.run()
    assert r.result.cache_hit and r.result.mode == "resident_block"
    assert np.isnan(r.result.prompt_similarity)   # no retrieval backs L1
    assert pag.stats["h2d_copies"] == h2d         # zero new host traffic
    pag.check_invariants()


def test_cow_never_mutates_shared_blocks(stack):
    """A divergent admission sharing a prefix must copy the boundary
    block, never write the donor's: the donor's pool content is bitwise
    unchanged after the sharer runs."""
    cfg, params = stack
    pag = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=4, block_size=8, enable_partial=True)
    sched = ContinuousBatchingScheduler(pag)
    sched.submit("the quick brown fox jumps over the lazy dog today")
    sched.run()
    donor_blocks = sorted(pag.trie.blocks())
    before = {
        seg: np.asarray(c["k"][:, donor_blocks])
        for seg, c in pag.pool.items()
    }

    sched2 = ContinuousBatchingScheduler(pag)
    # extends the donor EXACTLY, so the chain includes its partial tail
    # block -> the sharer must CoW it before writing its own suffix
    r = sched2.submit("the quick brown fox jumps over the lazy dog today"
                      " tonight")
    sched2.run()
    assert r.result.cache_hit and r.result.mode == "resident_block"
    assert r.result.reuse_depth % pag.block != 0   # genuinely mid-block
    assert pag.stats["cow_copies"] >= 1
    for seg, c in pag.pool.items():
        np.testing.assert_array_equal(
            np.asarray(c["k"][:, donor_blocks]), before[seg])
    pag.check_invariants()


# ---------------------------------------------------------------------------
# lifecycle and pressure
# ---------------------------------------------------------------------------
def test_pool_exhaustion_rejects_cleanly(stack):
    """A request the allocator cannot promise blocks for is rejected with
    an error (scheduler records it); the rest of the queue proceeds."""
    cfg, params = stack
    pag = PagedEngine(cfg, params, max_batch=2, capacity=64,
                      max_new_tokens=4, block_size=8,
                      num_blocks=6)          # sentinel + 5 usable
    sched = ContinuousBatchingScheduler(pag)
    ok = sched.submit("ab")                  # tiny: 1 block now + later
    bad = sched.submit("a prompt long enough to need many more blocks "
                       "than five")
    sched.run()
    assert ok.result is not None and ok.result.gen_tokens > 0
    assert bad.result is None and "exhausted" in bad.error
    assert sched.stats["rejected"] == 1
    pag.check_invariants()
    assert pag.free_slots() == [0, 1]


def test_trie_eviction_under_pressure(stack):
    """When the free list runs dry, cold L1 prefixes are evicted (LRU)
    instead of failing the admission; tokens stay correct."""
    cfg, params = stack
    ser = Engine(cfg, params, max_new_tokens=4, block_size=8)
    pag = PagedEngine(cfg, params, max_batch=1, capacity=64,
                      max_new_tokens=4, block_size=8, num_blocks=12)
    sched = ContinuousBatchingScheduler(pag)
    prompts = [f"pressure prompt number {i} with padding words" for i in
               range(4)]
    serial = [ser.generate(p) for p in prompts]
    reqs = [sched.submit(p) for p in prompts]
    sched.run()
    assert pag.stats["trie_evictions"] >= 1
    pag.check_invariants()
    for s, r in zip(serial, reqs):
        assert r.result.text == s.text


def test_instant_finish_keeps_prefix_warm(stack):
    """max_new_tokens=1 never occupies a row, but its prompt blocks stay
    indexed in L1 and serve the next admission residentially."""
    _, pag = _engines(stack)
    sched = ContinuousBatchingScheduler(pag)
    req = sched.submit("warm me up please", max_new_tokens=1)
    finished = sched.step()
    assert req in finished and req.result.gen_tokens == 1
    pag.check_invariants()
    assert len(pag.trie) > 0

    r2 = sched.submit("warm me up please and continue")
    sched.run()
    assert r2.result.mode == "resident_block"
    pag.check_invariants()


def test_paged_admit_feeds_host_store(stack):
    """admit=True harvests the row from pool blocks back into the host
    (L2) store at prompt depth, like the dense engines."""
    cfg, params = stack
    pag = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(pag)
    p = "tell me about rivers"
    sched.submit(p, admit=True)
    sched.run()
    assert len(pag.recycler.store) == 1
    follow = pag.recycler.lookup(p + " and lakes too",
                                 pag.tok.encode(p + " and lakes too"))
    assert follow.hit and follow.reuse_depth >= len(pag.tok.encode(p)) - 1


def test_paged_rejects_window_and_quant(stack):
    cfg, params = stack
    with pytest.raises(NotImplementedError):
        PagedEngine(cfg, params, window=32)
    with pytest.raises(NotImplementedError):
        PagedEngine(cfg, params, kv_quant=True)


def test_paged_pool_rejects_stateful_arch():
    from repro.models import init_paged_pool
    cfg = get_config("rwkv6-3b").reduced()
    with pytest.raises(NotImplementedError):
        init_paged_pool(cfg, 8, 8, 2, 4)


# ---------------------------------------------------------------------------
# kernel: block-table gather matches the reference gather
# ---------------------------------------------------------------------------
def test_paged_kernel_matches_reference():
    from repro.kernels import ops
    from repro.models.attention import attend_paged
    rng = np.random.default_rng(3)
    B, NB, bs, H, hkv, dh = 3, 12, 8, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    tables = jnp.asarray([[3, 5, 7, 0], [1, 2, 0, 0], [9, 8, 6, 4]],
                         jnp.int32)
    pos = jnp.asarray([25, 12, 31], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, tables, pos, interpret=True)
    ref = attend_paged(q, {"k": kp, "v": vp, "block_tables": tables}, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_gather_equals_row_alone():
    """Each row's paged attention equals the same row attended over a
    dense buffer built from its blocks (no cross-table leakage)."""
    from repro.models.attention import attend_direct, attend_paged
    rng = np.random.default_rng(4)
    B, NB, bs, H, hkv, dh = 3, 10, 4, 2, 1, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    tables = jnp.asarray([[1, 2, 3], [4, 5, 0], [6, 0, 0]], jnp.int32)
    pos = jnp.asarray([11, 6, 2], jnp.int32)
    out = attend_paged(q, {"k": kp, "v": vp, "block_tables": tables}, pos)
    for b in range(B):
        k = kp[tables[b]].reshape(1, -1, hkv, dh)
        v = vp[tables[b]].reshape(1, -1, hkv, dh)
        kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        solo = attend_direct(q[b:b + 1], k, v, pos[b:b + 1, None], kv_pos,
                             causal=True)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(solo[0]),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# allocator invariants (property-style; skipped without hypothesis)
# ---------------------------------------------------------------------------
def test_allocator_basics():
    a = BlockAllocator(6, 8)
    b1, b2 = a.alloc(), a.alloc()
    assert b1 != b2 and 0 not in (b1, b2)
    a.ref(b1)
    assert a.refcount(b1) == 2
    a.unref(b1)
    a.unref(b1)
    assert a.refcount(b1) == 0 and b1 in a.free_blocks()
    a.check()
    with pytest.raises(ValueError):
        a.unref(b1)                          # double free
    with pytest.raises(ValueError):
        a.ref(0)                             # sentinel is pinned
    for _ in range(4):
        a.alloc()
    with pytest.raises(BlockPoolExhausted):
        a.alloc()


def test_trie_register_lookup_evict():
    trie = BlockTrie(4)
    a = BlockAllocator(10, 4)
    ids = list(range(10))
    blocks = [a.alloc(), a.alloc(), a.alloc()]
    for b in trie.register(ids, 10, blocks):
        a.ref(b)
    depth, chain = trie.lookup(ids)
    assert depth == 10 and [b for b, _ in chain] == blocks
    assert chain[-1][1] == 2                 # partial tail fill
    d2, c2 = trie.lookup(ids[:6] + [99, 98])
    assert d2 == 4 and [b for b, _ in c2] == blocks[:1]
    # evict: only refcount-1 blocks, leaves first
    for b in blocks:
        a.unref(b)                           # drop the "table" refs
    dropped = trie.evict(10, lambda b: a.refcount(b) == 1)
    assert set(dropped) == set(blocks)
    assert trie.lookup(ids)[0] == 0


if HAVE_HYPOTHESIS:
    class TestAllocatorProperty:
        @given(ops=st.lists(st.tuples(st.integers(0, 2),
                                      st.integers(0, 30)),
                            min_size=1, max_size=200),
               nb=st.integers(2, 12))
        @settings(max_examples=60, deadline=None)
        def test_random_op_sequences_hold_invariants(self, ops, nb):
            """For ANY interleaving of alloc/ref/unref: refcounts >= 0,
            free ∪ live partitions the pool, and a block is never handed
            out twice without an intervening free."""
            a = BlockAllocator(nb, 8)
            held = []                        # (block, holders) we own
            for op, pick in ops:
                if op == 0:                  # alloc
                    try:
                        held.append([a.alloc(), 1])
                    except BlockPoolExhausted:
                        assert a.num_free() == 0
                elif op == 1 and held:       # ref
                    h = held[pick % len(held)]
                    a.ref(h[0])
                    h[1] += 1
                elif op == 2 and held:       # unref
                    i = pick % len(held)
                    h = held[i]
                    left = a.unref(h[0])
                    h[1] -= 1
                    assert left == h[1]
                    if h[1] == 0:
                        del held[i]
                a.check()
                for b, n in held:
                    assert a.refcount(b) == n
            live_expected = {b for b, _ in held}
            assert a.live_blocks() == live_expected

    class TestPagedEngineInvariantFuzz:
        @given(seed=st.integers(0, 2**16))
        @settings(max_examples=5, deadline=None)
        def test_scheduler_run_holds_invariants(self, seed):
            """Random mixed workloads through the scheduler: after every
            step, refcounts equal table+trie holders exactly and the free
            list never aliases a live block."""
            cfg = get_config("dialogpt-medium").reduced()
            params = init_params(cfg, jax.random.PRNGKey(0))
            rng = np.random.default_rng(seed)
            pag = PagedEngine(cfg, params, max_batch=2, capacity=64,
                              max_new_tokens=4, block_size=8,
                              enable_partial=True, num_blocks=20)
            sched = ContinuousBatchingScheduler(pag)
            words = ["alpha", "beta", "gamma", "delta"]
            for i in range(6):
                n = rng.integers(2, 6)
                sched.submit(" ".join(rng.choice(words) for _ in range(n)),
                             max_new_tokens=int(rng.integers(1, 5)),
                             admit=bool(rng.integers(0, 2)))
            while sched.pending() or sched.in_flight:
                sched.step()
                pag.check_invariants()
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocator_property():
        pass
