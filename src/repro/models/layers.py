"""Shared building blocks: norms, MLPs, positional encodings, init helpers."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LLM pretraining setups)."""
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def split_tree(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layernorm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32)}


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key, d_model: int, d_ff: int, dtype):
    ks = split_tree(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def apply_mlp(cfg: ModelConfig, p, x, rt=None):
    """Dense FFN.  With a mesh, d_ff is model-sharded (Megatron column/row)."""
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        if rt is not None and rt.model_axes:
            h = rt.hint_last(h, rt.model_axes)
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    if rt is not None and rt.model_axes:
        h = rt.hint_last(h, rt.model_axes)
    return h @ p["w_down"] + p["b_down"]


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------
def rope_freqs(cfg: ModelConfig, dim: int):
    half = dim // 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv          # (..., S, half)
    while x.ndim > ang.ndim + 1:                                  # head dim etc.
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(positions, dim: int, dtype=jnp.float32):
    """Classic transformer sinusoids, computed on the fly (whisper variant —
    DESIGN.md notes the learned->sinusoid substitution for long shapes)."""
    half = dim // 2
    inv = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                  * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def position_embedding(cfg: ModelConfig, params, positions, dtype):
    if cfg.pos == "learned":
        return params["wpe"][positions]
    if cfg.pos == "sinusoid":
        return sinusoid_positions(positions, cfg.d_model, dtype)
    return None  # rope handled inside attention


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------
def init_embeddings(cfg: ModelConfig, key, dtype):
    ks = split_tree(key, 3)
    p = {"wte": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02)}
    if cfg.pos == "learned":
        p["wpe"] = dense_init(ks[1], (min(cfg.max_seq_len, 8192), cfg.d_model),
                              dtype, scale=0.02)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size), dtype)
    return p


def unembed(cfg: ModelConfig, params, x, rt=None):
    emb = params["embed"]
    if cfg.tie_embeddings:
        logits = x @ emb["wte"].T
    else:
        logits = x @ emb["lm_head"]
    if rt is not None and rt.model_axes:
        logits = rt.hint_last(logits, rt.model_axes)
    return logits


def chunked_cross_entropy(cfg, params, x, labels, rt=None, *,
                          target_tokens: int = 1 << 20):
    """Sequence-chunked fused unembed + CE.

    Materializing (B, S, V) logits for a 1M-token batch costs tens of GB per
    device even vocab-sharded; instead we scan over sequence chunks,
    recomputing each chunk's logits in the backward pass (jax.checkpoint).
    This is the standard fused-CE memory trick.
    """
    from repro.models.layers import unembed as _unembed  # self-import safe
    B, S, d = x.shape
    sc = S
    while B * sc > target_tokens and sc % 2 == 0:
        sc //= 2
    while S % sc:
        sc -= 1
    nc = S // sc
    xc = x.reshape(B, nc, sc, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, sc).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(x_chunk, l_chunk):
        logits = _unembed(cfg, params, x_chunk, rt).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l_chunk, 0)[..., None], axis=-1)[..., 0]
        valid = (l_chunk >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    def body(carry, xs):
        nll, cnt = carry
        a, b = chunk_loss(*xs)
        return (nll + a, cnt + b), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    return nll / jnp.maximum(cnt, 1.0)


def cross_entropy(logits, labels, mask=None):
    """Token-level CE in f32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & mask
    valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
