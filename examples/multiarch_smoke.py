"""All ten assigned architectures through the same public API: reduced
variants, one prefill + one decode step each, plus a cross-prompt recycling
round-trip per architecture FAMILY (attention KV / MLA latent / recurrent
state all recycle through the same Recycler).

    PYTHONPATH=src python examples/multiarch_smoke.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.kvstore import to_host, tree_bytes
from repro.models import decode_step, init_cache, init_params, prefill

print(f"{'arch':22s} {'family':8s} {'params':>9s} {'prefill':>9s} "
      f"{'decode':>9s} {'cache/tok':>10s} nan")
for arch in ASSIGNED_ARCHS:
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    B, S = 2, 24
    rng = jax.random.PRNGKey(1)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    frontend = None
    extra = 0
    if cfg.frontend is not None:
        frontend = jax.random.normal(
            rng, (B, cfg.frontend.num_tokens, cfg.frontend.embed_dim),
            jnp.float32)
        if not cfg.frontend.cross_attention:
            extra = cfg.frontend.num_tokens

    cache = init_cache(cfg, B, 64 + extra)
    t0 = time.perf_counter()
    logits, cache = prefill(cfg, params, tokens, cache, frontend=frontend)
    jax.block_until_ready(logits)
    t_pre = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.perf_counter()
    logits2, cache = decode_step(cfg, params, tok, cache, S + extra)
    jax.block_until_ready(logits2)
    t_dec = time.perf_counter() - t0

    per_tok = tree_bytes(to_host(cache)) / (S + extra) / B
    has_nan = bool(jnp.isnan(logits2).any())
    print(f"{arch:22s} {cfg.arch_type:8s} {n/1e6:8.1f}M {t_pre*1e3:8.1f}ms "
          f"{t_dec*1e3:8.1f}ms {per_tok/1024:9.1f}KB {has_nan}")
print("\n(cache/tok shows the recycling-bytes asymmetry: MLA latent and "
      "recurrent-state families serialize far less per recycled token)")
