"""Chunked RWKV-6 WKV recurrence kernel.

The recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
is not associative (data-dependent decay), but within a chunk of T steps it
has a closed form over cumulative decays cw_t = prod_{j<=t} w_j:

    y_t   = (r_t . cw_{t-1}) S_0  +  tril_strict((r~ k~^T)) V  +  (r_t.(u.k_t)) v_t
    S_T   = cw_T . S_0 (row-wise)  +  sum_i (cw_T / cw_i . k_i) v_i^T

with r~_t = r_t * cw_{t-1}, k~_i = k_i / cw_i — i.e. two (T x D)x(D x D)
matmuls + one (T x T) masked matmul per chunk: MXU work instead of a
length-S serial scan.  Grid = (B*H, S/T) with the time dimension sequential;
the (D x D) state lives in VMEM scratch.  Chunk size 16 keeps 1/cw_i
bounded in f32 (validated against the step-by-step oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                s_scr, *, T, D, nt):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)                  # (T, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                  # (D,)
    S0 = s_scr[...]                                   # (D, D)

    cw = jnp.cumprod(w, axis=0)                       # (T, D) inclusive
    cw_prev = jnp.concatenate([jnp.ones((1, D), jnp.float32), cw[:-1]], 0)
    r_t = r * cw_prev                                 # r~
    k_t = k / jnp.maximum(cw, 1e-30)                  # k~

    # state contribution + intra-chunk strictly-causal + u-bonus diagonal
    y = r_t @ S0                                      # (T, D)
    a = r_t @ k_t.T                                   # (T, T)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (T, T), 1))
    y += jnp.where(mask, a, 0.0) @ v
    y += jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    y_ref[0] = y.astype(y_ref.dtype)

    # end-of-chunk state: S_T = cw_T . S_0 + sum_i (cw_T / cw_i . k_i) v_i^T
    kd = k * (cw[-1][None, :] / jnp.maximum(cw, 1e-30))   # (T, D)
    s_scr[...] = cw[-1][:, None] * S0 + kd.T @ v

    @pl.when(ti == nt - 1)
    def _write():
        sT_ref[0] = s_scr[...].astype(sT_ref.dtype)


def rwkv6_wkv(r, k, v, w, u, s0, *, chunk=16, interpret=True):
    """r,k,v,w: (B,S,H,D); u: (H,D); s0: (B,H,D,D) f32.
    Returns (y (B,S,H,D) f32, s_final (B,H,D,D) f32)."""
    B, S, H, D = r.shape
    T = min(chunk, S)
    assert S % T == 0, (S, T)
    nt = S // T

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, D).astype(jnp.float32)

    rr, kk, vv, ww = map(flat, (r, k, v, w))
    s0r = s0.reshape(B * H, D, D).astype(jnp.float32)
    ur = u.astype(jnp.float32)

    kernel = functools.partial(_wkv_kernel, T=T, D=D, nt=nt)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B * H, nt),
        in_specs=[
            pl.BlockSpec((1, T, D), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, T, D), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, T, D), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, T, D), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, D), lambda bh, ti: (bh % H, 0)),
            pl.BlockSpec((1, D, D), lambda bh, ti: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, D), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, D, D), lambda bh, ti: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B * H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, ur, s0r)
    y = y.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    return y, sT.reshape(B, H, D, D)
