"""End-to-end serving driver: batched requests through the FIFO scheduler
against a recycling engine, reproducing the paper's full evaluation and the
beyond-paper partial-prefix mode.

    PYTHONPATH=src python examples/serve_recycling.py [--full] [--partial]
    PYTHONPATH=src python examples/serve_recycling.py --continuous --batch 8
    PYTHONPATH=src python examples/serve_recycling.py --paged --batch 8

``--full`` uses the paper's real 345M DialoGPT config (slow on CPU).
``--continuous`` serves the recycled pass through the continuous-batching
dense slot pool instead of serial FIFO and reports the throughput ratio.
``--paged`` serves it through the paged block-table pool: requests sharing
a prefix reference the same ref-counted device blocks (copy-on-write on
divergence), warm prefixes are re-admitted with zero host→device copies,
and the host store acts as an L2 tier behind the device-resident L1 —
the run reports resident hits, host promotions and device KV bytes in use.
``--speculative`` decodes the paged pool self-speculatively (sparse-view
drafter + single-dispatch verify; greedy rows only, token-identical
output) and reports rounds, acceptance rate and tokens per round.
"""
import argparse
import json

import jax

from repro.configs import get_config
from repro.core import HashEmbedder
from repro.core.metrics import RunMetrics, summarize_runs
from repro.data.pipeline import paper_prompt_sets
from repro.models import init_params
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           Engine, FIFOScheduler, PagedEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--partial", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="serve the recycled pass on the dense slot pool")
    ap.add_argument("--paged", action="store_true",
                    help="serve the recycled pass on the paged block-table "
                         "pool (ref-counted prefix sharing, device-resident "
                         "L1 + host L2 tiering)")
    ap.add_argument("--int8", action="store_true",
                    help="store the device KV cache in int8 (kv_quant); "
                         "on the paged pool this is the fused-dequant tier "
                         "with the fp ring tail — ~2-4x more resident "
                         "blocks per HBM byte")
    ap.add_argument("--staged-prefill", action="store_true",
                    help="serve paged admissions through the legacy "
                         "staging-cache round-trip instead of the default "
                         "paged-native chunked prefill (reference "
                         "baseline; compiles one prefill executable per "
                         "distinct suffix length)")
    ap.add_argument("--speculative", action="store_true",
                    help="decode the paged pool self-speculatively: the "
                         "same weights refine --gamma draft guesses by "
                         "fixed-point sweeps over a pre-gathered sparse "
                         "sink+recent block view and ONE batched "
                         "dispatch verifies the bundle — greedy rows "
                         "only, token-identical output, reports the "
                         "acceptance stats (implies --paged)")
    ap.add_argument("--gamma", type=int, default=4,
                    help="draft tokens per speculative round")
    ap.add_argument("--mesh", nargs=2, type=int, metavar=("D", "T"),
                    help="serve the recycled pass on D data-parallel "
                         "paged-engine replicas, each with a T-way "
                         "tensor-parallel (KV-head-sharded) block pool, "
                         "sharing one host L2 with prefix-affinity "
                         "routing (implies --paged; needs D*T devices — "
                         "on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=<D*T> "
                         "before launching)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request SLO deadline in seconds: requests "
                         "whose deadline expires while still queued are "
                         "shed BEFORE claiming pool blocks (typed "
                         "outcome shed_deadline), and under pool "
                         "pressure the engine sacrifices the "
                         "latest-deadline row first (continuous/paged "
                         "schedulers only)")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bound the scheduler admission queue: submits "
                         "beyond this depth are shed immediately with "
                         "the typed outcome shed_queue_full instead of "
                         "growing the queue without bound "
                         "(continuous/paged schedulers only)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    if args.speculative:
        args.paged = True
    if args.mesh:
        args.paged = True

    cfg = get_config("dialogpt-medium")
    if not args.full:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    server = None
    if args.paged:
        args.continuous = True
        paged_kw = dict(max_batch=args.batch, capacity=args.capacity,
                        max_new_tokens=args.max_new,
                        enable_partial=args.partial, block_size=16,
                        kv_quant=args.int8,
                        prefill_mode=("staged" if args.staged_prefill
                                      else "chunked"),
                        speculative=args.speculative, gamma=args.gamma)
        if args.mesh:
            from repro.launch.serve import ShardedServer
            dp, tp = args.mesh
            server = ShardedServer(cfg, params, replicas=dp, tp=tp,
                                   **paged_kw)
            # the serial baseline pass and precache run on replica 0;
            # its recycler IS the shared L2, so every replica sees the
            # precached prefixes
            engine = server.engines[0]
        else:
            engine = PagedEngine(cfg, params, **paged_kw)
    elif args.continuous:
        engine = BatchedEngine(cfg, params, max_batch=args.batch,
                               capacity=args.capacity,
                               max_new_tokens=args.max_new,
                               enable_partial=args.partial, block_size=16,
                               kv_quant=args.int8)
    else:
        engine = Engine(cfg, params, max_new_tokens=args.max_new,
                        enable_partial=args.partial, block_size=16,
                        kv_quant=args.int8)

    cache_prompts, test_prompts = paper_prompt_sets("data")
    engine.precache(cache_prompts)
    print(f"precached {len(engine.recycler.store)} prompts "
          f"({engine.recycler.store.total_bytes/1e6:.1f} MB host KV)")

    # baseline pass stays serial (it is the paper's reference numbers)
    sched = FIFOScheduler(engine, max_batch=4)
    for p in test_prompts:                   # warm compile for both shapes
        engine.warmup(p, use_recycling=False)
        engine.warmup(p)
    for p in test_prompts:
        sched.submit(p, use_recycling=False)
    # copy: run() returns the scheduler's own completed list, which the
    # clear() below would otherwise empty out from under us
    baseline_reqs = list(sched.run())
    sched.completed.clear()
    if server is not None:
        from types import SimpleNamespace
        server.run(test_prompts)             # untimed: compiles every replica
        results = server.run(test_prompts, admit=True)   # residency-routed
        recycled_reqs = [SimpleNamespace(prompt=p,
                                         result=(None if isinstance(r, str)
                                                 else r),
                                         error=(r if isinstance(r, str)
                                                else None))
                         for p, r in zip(test_prompts, results)]
        st = server.stats()
        print(f"sharded serving: {st['replicas']} replica(s), "
              f"{st['cross_replica_promotions']} cross-replica "
              f"promotion(s), {st['host_entries']} shared-L2 entries "
              f"({st['host_bytes']/1e6:.1f} MB)")
        for i, pr in enumerate(st["per_replica"]):
            print(f"  replica {i}: tp={pr['kv_tp_degree']}, "
                  f"{pr['stats']['resident_hits']} L1 hits, "
                  f"{pr['stats']['host_promotions']} L2 promotions, "
                  f"{pr['device_kv_bytes_per_device']/1e6:.2f} MB KV "
                  f"per device")
        server.check_invariants()
    elif args.continuous:
        csched = ContinuousBatchingScheduler(engine,
                                             queue_limit=args.queue_limit)
        # full untimed pass (admit=False): compiles the pool decode step AND
        # every per-suffix-length prefill the timed pass will dispatch
        for p in test_prompts:
            csched.submit(p)
        csched.run()
        csched.completed.clear()
        for k in csched.stats:               # report the timed pass only
            csched.stats[k] = 0
        # keep submission order: run() returns requests in COMPLETION order
        # (early-EOS rows finish first), which would misalign the zip below
        recycled_reqs = [csched.submit(p, admit=True,
                                       deadline_s=args.deadline_s)
                         for p in test_prompts]
        csched.run()
        print(f"continuous batching: {csched.stats['decode_steps']} decode "
              f"steps for {len(recycled_reqs)} requests, mean occupancy "
              f"{csched.mean_occupancy():.2f}/{args.batch}")
        if args.queue_limit is not None or args.deadline_s is not None:
            print(f"backpressure: queue_limit={args.queue_limit}, "
                  f"deadline_s={args.deadline_s}, "
                  f"{csched.stats['shed_queue_full']} shed (queue full), "
                  f"{csched.stats['shed_deadline']} shed (deadline), "
                  f"{csched.stats['preemptions']} preemption requeue(s)")
        if args.paged:
            print(f"paged pool: {engine.stats['resident_hits']} resident "
                  f"(L1) hits, {engine.stats['host_promotions']} host (L2) "
                  f"promotions, {engine.stats['cow_copies']} CoW copies, "
                  f"{engine.stats['h2d_bytes']/1e6:.2f} MB host->device, "
                  f"{engine.device_kv_bytes_in_use()/1e6:.2f} MB device KV "
                  f"in use")
            print(f"admission ({engine.prefill_mode}): "
                  f"{engine.stats['prefill_chunks']} chunk steps, "
                  f"{engine.stats['staging_prefills']} staged prefills, "
                  f"{engine.stats['spec_preallocs']} speculative block "
                  f"reservations, {engine.prefill_compiles()} compiled "
                  f"prefill executable(s)")
            if args.speculative:
                st = engine.stats
                acc = (st["spec_accepted_tokens"]
                       / max(st["spec_draft_tokens"], 1))
                print(f"speculative (gamma={args.gamma}): "
                      f"{st['spec_rounds']} rounds, "
                      f"{100 * acc:.0f}% drafts accepted, "
                      f"{st['spec_emitted_tokens'] / max(st['spec_rounds'], 1):.2f} "
                      f"tokens/round, {st['spec_fallback_steps']} "
                      f"fallback steps")
        print("NOTE: per-request latency below spans the whole shared batch "
              "(queue wait included); batching trades it for throughput — "
              "see benchmarks/continuous_batching.py for tokens/s")
    else:
        for p in test_prompts:
            sched.submit(p, admit=True)      # recycled + admit for reuse
        recycled_reqs = list(sched.run())

    rejected = [r for r in recycled_reqs if r.result is None]
    if rejected:               # e.g. prompt > pool capacity, or shed under
        for r in rejected:     # a queue bound / expired deadline (typed)
            why = r.error or getattr(r, "outcome", None) or "rejected"
            print(f"rejected: {r.prompt[:40]!r}: {why}")
        keep = {id(r) for r in rejected}
        baseline_reqs, recycled_reqs = zip(*[
            (b, r) for b, r in zip(baseline_reqs, recycled_reqs)
            if id(r) not in keep])
    rows_b = [RunMetrics(r.prompt, "baseline", r.result.latency_s,
                         r.result.prompt_tokens, r.result.gen_tokens,
                         output_text=r.result.text) for r in baseline_reqs]
    rows_r = [RunMetrics(r.prompt, "recycled", r.result.latency_s,
                         r.result.prompt_tokens, r.result.gen_tokens,
                         r.result.reuse_depth, r.result.cache_hit,
                         r.result.prompt_similarity, r.result.mode,
                         r.result.text) for r in recycled_reqs]

    print("\nper-request:")
    for b, r in zip(rows_b, rows_r):
        sp = (b.latency_s - r.latency_s) / b.latency_s * 100
        print(f"  reuse {r.reuse_depth:3d}/{r.prompt_tokens:3d} tok  "
              f"{b.latency_s*1e3:7.1f} -> {r.latency_s*1e3:7.1f} ms "
              f"({sp:+5.1f}%)  same-output={b.output_text == r.output_text}")

    print("\npaper Table-1 summary:")
    print(json.dumps(summarize_runs(rows_b, rows_r,
                                    embedder=HashEmbedder()), indent=1))
    print("\nengine stats:", engine.stats)


if __name__ == "__main__":
    main()
