"""Config registry: exact assigned dims, reduced-variant invariants."""
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, list_configs
from repro.config import INPUT_SHAPES


def test_all_assigned_archs_present():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS:
        assert get_config(a).name == a


def test_input_shapes():
    s = INPUT_SHAPES
    assert s["train_4k"].seq_len == 4096 and s["train_4k"].global_batch == 256
    assert s["prefill_32k"].seq_len == 32768 and s["prefill_32k"].global_batch == 32
    assert s["decode_32k"].seq_len == 32768 and s["decode_32k"].global_batch == 128
    assert s["long_500k"].seq_len == 524288 and s["long_500k"].global_batch == 1
    assert s["long_500k"].long_context


EXACT = {
    "whisper-base": dict(num_layers=6, d_model=512, num_heads=8,
                         num_kv_heads=8, d_ff=2048, vocab_size=51865),
    "qwen2.5-3b": dict(num_layers=36, d_model=2048, num_heads=16,
                       num_kv_heads=2, d_ff=11008, vocab_size=151936),
    "recurrentgemma-9b": dict(num_layers=38, d_model=4096, num_heads=16,
                              num_kv_heads=1, d_ff=12288, vocab_size=256000),
    "deepseek-v2-236b": dict(num_layers=60, d_model=5120, num_heads=128,
                             d_ff=1536, vocab_size=102400),
    "qwen1.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                        num_kv_heads=40, d_ff=27392, vocab_size=152064),
    "rwkv6-3b": dict(num_layers=32, d_model=2560, d_ff=8960,
                     vocab_size=65536),
    "qwen3-1.7b": dict(num_layers=28, d_model=2048, num_heads=16,
                       num_kv_heads=8, d_ff=6144, vocab_size=151936),
    "command-r-35b": dict(num_layers=40, d_model=8192, num_heads=64,
                          num_kv_heads=8, d_ff=22528, vocab_size=256000),
    "internvl2-76b": dict(num_layers=80, d_model=8192, num_heads=64,
                          num_kv_heads=8, d_ff=28672, vocab_size=128256),
    "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                            num_kv_heads=8, d_ff=2048, vocab_size=163840),
}


@pytest.mark.parametrize("arch", sorted(EXACT))
def test_exact_assigned_dims(arch):
    cfg = get_config(arch)
    for k, v in EXACT[arch].items():
        assert getattr(cfg, k) == v, (arch, k)


def test_moe_configs():
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.num_experts == 160 and ds.moe.top_k == 6
    assert ds.moe.num_shared_experts == 2
    assert ds.mla.kv_lora_rank == 512
    k2 = get_config("kimi-k2-1t-a32b")
    assert k2.moe.num_experts == 384 and k2.moe.top_k == 8
    # ~1T total, ~32B active
    assert 0.9e12 < k2.param_count() < 1.15e12
    assert 25e9 < k2.active_param_count() < 40e9


def test_hybrid_pattern():
    rg = get_config("recurrentgemma-9b")
    assert rg.hybrid.pattern == ("rglru", "rglru", "local_attn")
    assert rg.hybrid.local_window == 2048


@pytest.mark.parametrize("arch", sorted(list_configs()))
def test_reduced_invariants(arch):
    r = get_config(arch).reduced()
    blk = len(r.hybrid.pattern) if r.hybrid else 2
    assert r.num_layers <= max(2, blk)
    assert r.d_model <= 512
    if r.moe:
        assert r.moe.num_experts <= 4
