"""RWKV-6 "Finch" time/channel mixing (arXiv:2404.05892).

Attention-free: per-head matrix-valued state S (D x D) with data-dependent
per-channel decay  S_t = diag(w_t) S_{t-1} + k_t^T v_t  and readout
y_t = r_t (S_{t-1} + diag(u) k_t^T v_t).  Decode state is O(1) in sequence
length — the recycled "cache" is the (S, shift) snapshot, and long_500k is
native.  Prefill is a time scan in jnp; the chunked Pallas kernel
(``repro.kernels.rwkv6_wkv``) computes the same recurrence blockwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, split_tree, rmsnorm

_LORA_DIM = 64


def init_rwkv_tmix(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    D = cfg.rwkv.head_dim
    H = d // D
    ks = split_tree(key, 10)
    return {
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "w0": jnp.full((d,), -1.0, jnp.float32),     # decay bias (pre -exp(exp))
        "lora_a": dense_init(ks[0], (d, _LORA_DIM), dtype, scale=0.01),
        "lora_b": dense_init(ks[1], (_LORA_DIM, d), dtype, scale=0.01),
        "w_r": dense_init(ks[2], (d, d), dtype),
        "w_k": dense_init(ks[3], (d, d), dtype),
        "w_v": dense_init(ks[4], (d, d), dtype),
        "w_g": dense_init(ks[5], (d, d), dtype),
        "u": dense_init(ks[6], (H, D), jnp.float32, scale=0.5),
        "ln_w": jnp.ones((d,), jnp.float32),         # per-head group norm
        "w_o": dense_init(ks[7], (d, d), dtype),
    }


def init_rwkv_cmix(cfg: ModelConfig, key, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = split_tree(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "w_k": dense_init(ks[0], (d, f), dtype),
        "w_v": dense_init(ks[1], (f, d), dtype),
        "w_r": dense_init(ks[2], (d, d), dtype),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    D = cfg.rwkv.head_dim
    H = d // D
    return {
        "wkv": jnp.zeros((batch, H, D, D), jnp.float32),
        "shift_t": jnp.zeros((batch, d), dtype),
        "shift_c": jnp.zeros((batch, d), dtype),
    }


def _shift(x, prev):
    """token shift: x_{t-1} with carried state.  x (B,S,d), prev (B,d)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, xp, mu):
    return x + (xp - x) * mu.astype(x.dtype)


def _decay(p, xw):
    """data-dependent per-channel decay w_t in (0,1).  xw (B,S,d)."""
    lora = jnp.tanh(xw @ p["lora_a"]) @ p["lora_b"]
    w = p["w0"] + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(w))                              # (B,S,d)


def _wkv_step(S, w, k, v, r, u):
    """One recurrence step.  S (B,H,D,D); w,k,v,r (B,H,D); u (H,D).
    y[b,h,j] = sum_i r[i] * (S[i,j] + u[i] k[i] v[j]);
    S' = diag(w) S + k^T v."""
    kv = k[..., :, None] * v[..., None, :]                   # (B,H,D,D)
    y = jnp.einsum("bhi,bhij->bhj", r, S + u[None, :, :, None] * kv)
    S_new = w[..., :, None] * S + kv
    return S_new, y


def rwkv_tmix(cfg: ModelConfig, p, x, state, rt=None):
    """Time mixing over S steps.  Returns (y, new_state)."""
    B, S, d = x.shape
    D = cfg.rwkv.head_dim
    H = d // D
    xp = _shift(x, state["shift_t"])
    xw, xk, xv, xr, xg = (_mix(x, xp, p[f"mu_{n}"]) for n in "wkvrg")
    w = _decay(p, xw).reshape(B, S, H, D)
    k = (xk @ p["w_k"]).astype(jnp.float32).reshape(B, S, H, D)
    v = (xv @ p["w_v"]).astype(jnp.float32).reshape(B, S, H, D)
    r = (xr @ p["w_r"]).astype(jnp.float32).reshape(B, S, H, D)
    g = jax.nn.silu(xg @ p["w_g"])

    use_kernel = rt is not None and rt.use_pallas and S > 1
    if use_kernel:
        from repro.kernels import ops
        y, S_fin = ops.rwkv6_wkv(r, k, v, w, p["u"], state["wkv"],
                                 interpret=rt.pallas_interpret)
    else:
        def step(Sm, inp):
            w_t, k_t, v_t, r_t = inp
            return _wkv_step(Sm, w_t, k_t, v_t, r_t, p["u"])

        S_fin, y = jax.lax.scan(
            step, state["wkv"],
            (w.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
             v.transpose(1, 0, 2, 3), r.transpose(1, 0, 2, 3)))
        y = y.transpose(1, 0, 2, 3)                          # (B,S,H,D)

    y = rmsnorm(y.reshape(B, S, H, D), p["ln_w"].reshape(H, D)[None, None])
    y = y.reshape(B, S, d).astype(x.dtype) * g
    new_state = {"wkv": S_fin, "shift_t": x[:, -1, :],
                 "shift_c": state["shift_c"]}
    return y @ p["w_o"], new_state


def rwkv_cmix(cfg: ModelConfig, p, x, state, rt=None):
    """Channel mixing (relu^2 MLP with token shift)."""
    xp = _shift(x, state["shift_c"])
    xk = _mix(x, xp, p["mu_k"])
    xr = _mix(x, xp, p["mu_r"])
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    if rt is not None and rt.model_axes:
        k = rt.hint_last(k, rt.model_axes)
    y = jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"])
    new_state = dict(state, shift_c=x[:, -1, :])
    return y, new_state
