"""internvl2-76b — VLM: InternViT vision encoder + InternLM2-style LLM.

[arXiv:2404.16821] InternVL 1.5/2 report.  Language trunk: 80L, d_model=8192,
64 heads (GQA kv=8), d_ff=28672, vocab=128256.  The InternViT encoder +
MLP projector are a STUB — ``input_specs`` provides projected patch
embeddings (256 tokens after pixel-shuffle) which are concatenated ahead of
the text tokens (prefix-concat, no cross attention).
"""
from repro.config import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    source="arXiv:2404.16821 (InternVL2-Llama3-76B)",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    sliding_window=8192,
    frontend=FrontendConfig(
        kind="vision",
        num_tokens=256,           # patches after pixel-shuffle, per image
        embed_dim=8192,           # projector output = d_model
        cross_attention=False,    # prefix concat
    ),
)
