"""Batched multi-token speculative verification attention.

Self-speculative decoding verifies a bundle of ``gamma`` draft tokens
(plus the pending token that produced them) in ONE dispatch: every row's
``Cv = gamma + 1`` query tokens at absolute positions [c0[b], c0[b] + Cv)
attend their full history through the row's scalar-prefetched block
table — exactly the chunked-prefill gather — and the bundle's own K/V
from the fresh fp operands (flash style, the bundle has not been sealed
yet).  The difference from ``paged_prefill_attention`` is batch shape:
prefill admits ONE row per dispatch with scalar (c0, w_eff); verify runs
EVERY speculating row at once with a per-row c0 vector, which is what
makes speculation pay at batch > 1 (2 dispatches per round regardless of
batch size).  Verification never has a write floor: the history/bundle
boundary is exactly c0 (armed rows are fully admitted), so c0 is the
only per-row scalar.

Grid = (B, kv_heads, table_entries + bundle_tiles) with the kv tile axis
innermost, so the (Cv*G, d) online-softmax state lives in VMEM scratch
across one row's tiles.  Bundle padding queries (the engine rounds Cv up
to a block multiple) produce garbage the caller discards; padding KEYS
sit at positions >= c0 + n_valid and are causally invisible to every
valid query, so no n_valid operand is needed.

``paged_verify_attention_quant`` is the int8-pool twin.  Because the
bundle spans several positions, the fp-ring recency gate is PER QUERY:
query at position qp reads history block t at full precision iff
t > qp//bs - R — the same window the int8 decode kernel would apply at
position qp — which keeps speculative attention bit-identical to the
non-speculative schedule.  The fp blocks come from a pre-round SNAPSHOT
of the row's ring tail (operand, not the pool's live ring): the engine
snapshots the ring anyway for the exact rollback restore, and the
snapshot provably covers every block any verify query gates to fp.
Since the gate differs per query row, the value accumulation selects
per (query, key) between the ring tile and the dequantized int8 tile —
two matmuls instead of one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import check_shard_view

NEG_INF = -1e30


def _accumulate(ti, ntiles, s, vmm, o_ref, m_scr, l_scr, acc_scr):
    """One online-softmax step over a pre-masked score tile ``s``
    (Cv*G, bs); ``vmm(p)`` maps the softmax numerator tile to its
    (Cv*G, d) value contribution — plain ``p @ v`` for fp, a per-query
    ring/int8 select for the quant kernel.  Same recurrence as
    ``kernels.prefill_attention._accumulate`` (fp-vs-int8 lockstep), but
    the out ref carries the batched grid's (1, 1, CG, d) block."""
    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_new = acc_scr[...] * alpha[:, None] + vmm(p)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ti == ntiles - 1)
    def _write():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def _verify_mask(ti, c0, CG, bs, G, nbt):
    """Validity for tile ``ti`` against one row's query bundle: history
    tiles hold implicit pool positions valid strictly below c0 (armed
    rows have no write floor, so history/bundle split AT c0); bundle
    tiles hold operand positions c0 + (ti - nbt)*bs + j, causally
    visible up to each query's own position c0 + r//G."""
    j = jax.lax.broadcasted_iota(jnp.int32, (CG, bs), 1)
    qp = c0 + jax.lax.broadcasted_iota(jnp.int32, (CG, bs), 0) // G
    is_hist = ti < nbt
    kp = jnp.where(is_hist, ti * bs + j, c0 + (ti - nbt) * bs + j)
    return (kp <= qp) & jnp.where(is_hist, kp < c0, kp >= c0)


def _verify_kernel(tbl_ref, c0_ref, q_ref, k_ref, v_ref, kc_ref, vc_ref,
                   o_ref, m_scr, l_scr, acc_scr, *, scale, bs, nbt, G, cb):
    """One (row, kv_head, tile) program: history tiles were resolved by
    the BlockSpec index map through row b's table entry ti; bundle tiles
    to the matching slice of the bundle's fp K/V operands."""
    b, ti = pl.program_id(0), pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)               # (Cv*G, d)
    is_hist = ti < nbt
    k = jnp.where(is_hist, k_ref[0, 0], kc_ref[0, 0, 0]).astype(jnp.float32)
    v = jnp.where(is_hist, v_ref[0, 0], vc_ref[0, 0, 0]).astype(jnp.float32)
    s = q @ k.T * scale                               # (Cv*G, bs)
    s = jnp.where(_verify_mask(ti, c0_ref[b], q.shape[0], bs, G, nbt),
                  s, NEG_INF)
    _accumulate(ti, nbt + cb, s, lambda p: p @ v, o_ref, m_scr, l_scr,
                acc_scr)


def _verify_layouts(q, k_chunk, v_chunk, bs):
    """(B, Cv, H|Hkv, D) -> kernel layouts: q (B, Hkv, Cv*G, D) with
    query row r = (token r // G, group r % G); bundle K/V
    (B, Hkv, Cv/bs, bs, D)."""
    B, Cv, H, D = q.shape
    Hkv = k_chunk.shape[2]
    G = H // Hkv
    qr = (q.reshape(B, Cv, Hkv, G, D).transpose(0, 2, 1, 3, 4)
          .reshape(B, Hkv, Cv * G, D))
    kcr = (k_chunk.reshape(B, Cv // bs, bs, Hkv, D).transpose(0, 3, 1, 2, 4))
    vcr = (v_chunk.reshape(B, Cv // bs, bs, Hkv, D).transpose(0, 3, 1, 2, 4))
    return qr, kcr, vcr


def paged_verify_attention(q, k_chunk, v_chunk, k_pool, v_pool,
                           block_tables, c0s, *, scale=None, interpret=True):
    """Batched speculative-verify attention through per-row block tables.

    q / k_chunk / v_chunk (B, Cv, H|Hkv, D): every row's draft bundle's
    roped projections at absolute positions [c0s[b], c0s[b] + Cv); pools
    (NB, bs, Hkv, D) shared by all rows; block_tables (B, NBt) int32 and
    c0s (B,) int32 are scalar-prefetched.  History (< c0) reads through
    the table; the bundle itself (>= c0) from the fp operands — sealing
    happens after attention, per layer, like chunked prefill.  Inactive
    rows carry sentinel tables and c0 = 0 and produce garbage the engine
    discards.  Returns (B, Cv, H, D)."""
    B, Cv, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = block_tables.shape[1]
    CB = Cv // bs
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr, kcr, vcr = _verify_layouts(q, k_chunk, v_chunk, bs)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D)
    vr = v_pool.transpose(2, 0, 1, 3)

    def q_ix(b, h, ti, tbl, c0):
        return (b, h, 0, 0)

    def hist_ix(b, h, ti, tbl, c0, n=NBt):
        return (h, tbl[b, jnp.minimum(ti, n - 1)], 0, 0)

    def chunk_ix(b, h, ti, tbl, c0, n=NBt, c=CB):
        return (b, h, jnp.clip(ti - n, 0, c - 1), 0, 0)

    kernel = functools.partial(_verify_kernel, scale=scale, bs=bs, nbt=NBt,
                               G=G, cb=CB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block tables + per-row c0
        grid=(B, Hkv, NBt + CB),
        in_specs=[
            pl.BlockSpec((1, 1, Cv * G, D), q_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, 1, bs, D), chunk_ix),
            pl.BlockSpec((1, 1, 1, bs, D), chunk_ix),
        ],
        out_specs=pl.BlockSpec((1, 1, Cv * G, D), q_ix),
        scratch_shapes=[
            pltpu.VMEM((Cv * G,), jnp.float32),
            pltpu.VMEM((Cv * G,), jnp.float32),
            pltpu.VMEM((Cv * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Cv * G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), c0s.astype(jnp.int32), qr, kr, vr,
      kcr, vcr)
    return (out.reshape(B, Hkv, Cv, G, D).transpose(0, 2, 1, 3, 4)
            .reshape(B, Cv, H, D))


def _verify_kernel_quant(tbl_ref, c0_ref, q_ref, k_ref, v_ref, ks_ref,
                         vs_ref, kt_ref, vt_ref, kc_ref, vc_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale, bs, nbt, G, cb,
                         rtail):
    """int8 variant: the recency gate is PER QUERY ROW — query at
    position qp reads history block ti at fp iff ti > qp//bs - rtail,
    matching what the int8 decode kernel would have done token by token.
    fp history comes from the pre-round ring SNAPSHOT operand (slot
    ti % rtail), not the pool's draft-polluted live ring.  Scores and
    values are computed on both views and selected per (query, key);
    bundle tiles collapse to the fp operands on both views, so the
    select is a no-op there."""
    b, ti = pl.program_id(0), pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)               # (Cv*G, d)
    k8 = k_ref[0, 0].astype(jnp.float32)              # (bs, d) int8 tile
    v8 = v_ref[0, 0].astype(jnp.float32)
    ks = ks_ref[0, 0].astype(jnp.float32)             # (bs,) f32 scales
    vs = vs_ref[0, 0].astype(jnp.float32)
    kt = kt_ref[0, 0, 0].astype(jnp.float32)          # (bs, d) ring snapshot
    vt = vt_ref[0, 0, 0].astype(jnp.float32)
    kc = kc_ref[0, 0, 0].astype(jnp.float32)          # (bs, d) bundle tile
    vc = vc_ref[0, 0, 0].astype(jnp.float32)

    is_hist = ti < nbt
    k_int = jnp.where(is_hist, k8 * ks[:, None], kc)  # int8 view of tile
    v_int = jnp.where(is_hist, v8 * vs[:, None], vc)
    k_fp = jnp.where(is_hist, kt, kc)                 # fp-ring view
    v_fp = jnp.where(is_hist, vt, vc)

    CG = q.shape[0]
    c0 = c0_ref[b]
    qp = c0 + jax.lax.broadcasted_iota(jnp.int32, (CG, 1), 0) // G
    gate = is_hist & (ti > qp // bs - rtail)          # (CG, 1) per query
    gf = gate.astype(jnp.float32)

    s = jnp.where(gate, q @ k_fp.T * scale, q @ k_int.T * scale)
    s = jnp.where(_verify_mask(ti, c0, CG, bs, G, nbt), s, NEG_INF)
    _accumulate(ti, nbt + cb, s,
                lambda p: (p * gf) @ v_fp + (p * (1.0 - gf)) @ v_int,
                o_ref, m_scr, l_scr, acc_scr)


def paged_verify_attention_quant(q, k_chunk, v_chunk, k_pool, v_pool,
                                 k_scale, v_scale, k_tails, v_tails,
                                 block_tables, c0s, *, scale=None,
                                 interpret=True):
    """Fused-dequant batched verify: q / bundle K/V (B, Cv, H|Hkv, D);
    int8 pools (NB, bs, Hkv, D) with f32 scales (NB, bs, Hkv);
    k_tails/v_tails (B, R*bs, Hkv, D) — every row's PRE-ROUND fp ring
    snapshot (taken for the exact rollback restore; drafts read it too);
    block_tables (B, NBt), c0s (B,).  The table gather matches the fp
    kernel; only the per-query recency select differs.  Returns
    (B, Cv, H, D)."""
    B, Cv, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = block_tables.shape[1]
    CB = Cv // bs
    R = k_tails.shape[1] // bs
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr, kcr, vcr = _verify_layouts(q, k_chunk, v_chunk, bs)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D) int8
    vr = v_pool.transpose(2, 0, 1, 3)
    ksr = k_scale.transpose(2, 0, 1)                  # (Hkv, NB, bs) f32
    vsr = v_scale.transpose(2, 0, 1)
    ktr = (k_tails.reshape(B, R, bs, Hkv, D)          # (B, Hkv, R, bs, D)
           .transpose(0, 3, 1, 2, 4))
    vtr = (v_tails.reshape(B, R, bs, Hkv, D)
           .transpose(0, 3, 1, 2, 4))

    def q_ix(b, h, ti, tbl, c0):
        return (b, h, 0, 0)

    def hist_ix(b, h, ti, tbl, c0, n=NBt):
        return (h, tbl[b, jnp.minimum(ti, n - 1)], 0, 0)

    def hist_ix_s(b, h, ti, tbl, c0, n=NBt):
        return (h, tbl[b, jnp.minimum(ti, n - 1)], 0)

    def ring_ix(b, h, ti, tbl, c0, r=R):
        return (b, h, ti % r, 0, 0)

    def chunk_ix(b, h, ti, tbl, c0, n=NBt, c=CB):
        return (b, h, jnp.clip(ti - n, 0, c - 1), 0, 0)

    kernel = functools.partial(_verify_kernel_quant, scale=scale, bs=bs,
                               nbt=NBt, G=G, cb=CB, rtail=R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block tables + per-row c0
        grid=(B, Hkv, NBt + CB),
        in_specs=[
            pl.BlockSpec((1, 1, Cv * G, D), q_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs), hist_ix_s),
            pl.BlockSpec((1, 1, bs), hist_ix_s),
            pl.BlockSpec((1, 1, 1, bs, D), ring_ix),
            pl.BlockSpec((1, 1, 1, bs, D), ring_ix),
            pl.BlockSpec((1, 1, 1, bs, D), chunk_ix),
            pl.BlockSpec((1, 1, 1, bs, D), chunk_ix),
        ],
        out_specs=pl.BlockSpec((1, 1, Cv * G, D), q_ix),
        scratch_shapes=[
            pltpu.VMEM((Cv * G,), jnp.float32),
            pltpu.VMEM((Cv * G,), jnp.float32),
            pltpu.VMEM((Cv * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Cv * G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), c0s.astype(jnp.int32), qr, kr, vr,
      ksr, vsr, ktr, vtr, kcr, vcr)
    return (out.reshape(B, Hkv, Cv, G, D).transpose(0, 2, 1, 3, 4)
            .reshape(B, Cv, H, D))
