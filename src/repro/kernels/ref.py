"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are deliberately naive — full score materialization, step-by-step
scans — so they are obviously correct; kernel tests sweep shapes/dtypes and
assert_allclose against them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_flash_attention(q, k, v, *, causal=True, window=0, q_start=0,
                        scale=None):
    """q: (B,Sq,H,D); k,v: (B,Skv,Hkv,D).  Naive full-softmax GQA attention.
    q positions are q_start..q_start+Sq-1; kv positions 0..Skv-1."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale or D ** -0.5
    qp = q_start + jnp.arange(Sq)
    kp = jnp.arange(Skv)
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kp[None, :] <= qp[:, None]
    if window:
        ok &= kp[None, :] > qp[:, None] - window
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def ref_decode_attention(q, k_cache, v_cache, slot_pos, pos, *, window=0,
                         scale=None):
    """q: (B,1,H,D); caches (B,C,Hkv,D); slot_pos (C,) abs positions or -1;
    pos: scalar query position."""
    B, _, H, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = scale or D ** -0.5
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32)) * scale
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window:
        ok &= slot_pos > pos - window
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


def ref_rwkv6_wkv(r, k, v, w, u, s0):
    """Step-by-step WKV recurrence.  r,k,v,w: (B,S,H,D) f32; u: (H,D);
    s0: (B,H,D,D).  Returns (y (B,S,H,D), s_final)."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32)
               for a in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3), s_fin


def ref_rglru_scan(a, b, h0):
    """Step-by-step linear recurrence h_t = a_t h_{t-1} + b_t.
    a, b: (B,S,W); h0: (B,W).  Returns (h (B,S,W), h_final)."""
    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    xs = (a.transpose(1, 0, 2), b.transpose(1, 0, 2))
    h_fin, hs = jax.lax.scan(step, h0, xs)
    return hs.transpose(1, 0, 2), h_fin
