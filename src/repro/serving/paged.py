"""Paged continuous-batching engine: block-table KV pool with ref-counted
prefix sharing (the device-resident recycling tier).

The dense slot pool (``BatchedEngine``) gives every in-flight request a
private ``[capacity]`` KV row and materializes every recycled hit from the
host store — two requests sharing a 500-token prefix hold two device
copies and both pay a host→device transfer.  This engine replaces the row
with a *block table*: all K/V lives in ONE shared pool of ``block_size``-
token blocks (``models.cache.init_paged_pool``), request r's cache is the
ordered list of pool block ids in its table, and a block may appear in any
number of tables at once.

Two cache tiers serve admissions:

  L1 — ``core.radix.BlockTrie``: token-block keys → live device blocks.
       A warm-prefix admission composes its table from the resident chain
       with **zero host round-trip**: shared full blocks are referenced in
       place (refcount++), and only the divergent boundary block is
       materialized fresh (copy-on-write by recomputation — a shared
       block is never written in place, and the donor is never even
       read).
  L2 — the existing ``Recycler``/``HostKVStore`` path: on an L1 miss the
       host entry is promoted back to device in block-granular chunks and
       indexed in L1 for the next admission.

Admission itself is **paged-native chunked prefill** (PR 5, the
default): the prompt's fresh region is processed as a sequence of
fixed-size, block-aligned chunks, each writing K/V straight into freshly
allocated pool blocks and attending history through the block table
(``kernels.paged_prefill_attention``) — no staging cache, no
gather/scatter round-trip — and ``decode_batch`` advances ONE chunk per
pending admission per engine step, interleaved with the batched decode,
so long prompts never stall the in-flight batch.  The original staged
path (full-capacity staging cache + dense prefill + scatter) survives
behind ``prefill_mode="staged"`` as the reference baseline.

Static shapes still rule: the pool is one fixed ``[num_blocks, bs, ...]``
allocation per layer, tables are fixed-width (sentinel-0 padded), ONE
compiled decode executable (`decode_step` over the paged cache) advances
every in-flight request per step regardless of occupancy or sharing, and
one compiled chunk-prefill executable per fixed chunk shape serves every
admission regardless of suffix length (the staged path compiled one per
DISTINCT length).

``kv_quant=True`` stores the L1 pool in **int8** (``repro.core.quant``
scheme, shared with the host tier): ~2-4x more resident blocks per HBM
byte, dequant fused into the block-table gather
(``kernels.paged_decode_attention_quant``), a per-row fp ring tail over
the most recent blocks, and int8-verbatim block movement between the
tiers — see the ``PagedEngine`` docstring for the one-quantization
invariant.

Correctness contract (tests/test_paged_pool.py,
tests/test_chunked_prefill.py): paged decode is token-for-token identical
to the dense slot pool — and therefore to serial ``generate`` — for every
admission mode (and the int8 pool to the fp pool, and the chunked
admission route to the staged one); blocks shared between requests have
refcount > 1 and are never written by either sharer, including by any
chunk step of a sharer's admission.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import BlockAllocator, BlockPoolExhausted, BlockTrie
from repro.core.blockpool import SENTINEL, PoolSaturated
from repro.core.faults import InjectedFault
from repro.core.kvstore import to_host, tree_bytes
from repro.core import quant as kvq
from repro.core.quant import dequantize_vectors_jnp, quantize_vectors_jnp
from repro.core.recycler import GraftPlan, grow_capacity
from repro.data.tokenizer import EOS
from repro.models import (decode_step, draft_refine, draft_view,
                          init_cache, init_paged_pool, paged_block_bytes,
                          prefill_paged, prefill_paged_packed, verify_paged)
from repro.serving import engine as engine_mod
from repro.serving.engine import Engine, GenResult, _Slot
from repro.serving.sampling import sample_batched, sample_logits


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pack_admission_segments(segs, *, block_size: int, buckets,
                            max_segments: int, table_width: int):
    """Build the ragged packed-prefill descriptor batch from per-admission
    chunk segments — the pure (numpy in, numpy out) heart of
    ``prefill_mode="packed"``, property-tested standalone.

    ``segs`` is a list of per-admission tuples ``(row, table_row, c0,
    w_floor, n_valid, C, tokens)``: pool row, host table mirror (NBt,),
    block-aligned chunk start, write floor, valid token count, chunk
    width (a multiple of ``block_size``), and the ``n_valid`` prompt ids.
    Segments are laid out back to back in the packed token buffer, each
    occupying exactly ``C`` positions (tokens past ``n_valid`` are
    padding), and the buffer is padded up to the smallest bucket in
    ``buckets`` that fits — so the dispatch shape depends only on the
    bucket ladder, never on the segment count or any suffix length.

    Descriptor arrays are fixed at ``max_segments + 1`` entries: index
    ``len(segs)`` is the dedicated PADDING segment (row 0, all-sentinel
    table, c0 = w_floor = valid = 0) whose ``q_off`` is the pack end, so
    pad tokens get small in-range positions, attend only the pad region,
    and write only the sentinel scratch block.

    Returns ``{"tokens" (1, T), "rows", "tables", "c0s", "w_floors",
    "valids", "q_offs" (all (S,)), "seg_ids" (T,)}`` with S =
    max_segments + 1."""
    assert 0 < len(segs) <= max_segments, (len(segs), max_segments)
    S = max_segments + 1
    total = sum(C for (_, _, _, _, _, C, _) in segs)
    T = next((b for b in sorted(buckets) if b >= total), None)
    if T is None:
        raise ValueError(f"packed total {total} exceeds largest bucket "
                         f"{max(buckets)}")
    tokens = np.zeros((1, T), np.int32)
    rows = np.zeros((S,), np.int32)
    tables = np.full((S, table_width), SENTINEL, np.int32)
    c0s = np.zeros((S,), np.int32)
    w_floors = np.zeros((S,), np.int32)
    valids = np.zeros((S,), np.int32)
    # unused / padding segments: q_off at the pack end so every pad
    # token's position (t - q_off) stays small and in range
    q_offs = np.full((S,), total, np.int32)
    seg_ids = np.full((T,), len(segs), np.int32)
    off = 0
    for i, (row, table_row, c0, w_floor, n_valid, C, toks) in \
            enumerate(segs):
        assert C % block_size == 0 and 0 < n_valid <= C, (C, n_valid)
        assert c0 % block_size == 0, c0
        rows[i] = row
        tables[i] = table_row
        c0s[i] = c0
        w_floors[i] = w_floor
        valids[i] = n_valid
        q_offs[i] = off
        seg_ids[off:off + C] = i
        tokens[0, off:off + n_valid] = toks
        off += C
    return {"tokens": tokens, "rows": rows, "tables": tables, "c0s": c0s,
            "w_floors": w_floors, "valids": valids, "q_offs": q_offs,
            "seg_ids": seg_ids}


# ---------------------------------------------------------------------------
# jitted pool <-> staging composition (device-to-device; no host traffic)
# ---------------------------------------------------------------------------
def _stage_from_pool(pool, chain_ids, depth: int, cap: int):
    """Compose a dense single-request staging cache holding positions
    [0, depth) gathered from pool blocks ``chain_ids`` — the layout the
    existing (compiled) prefill consumes.  Pure device gather.  Staging
    is always full precision: int8 pools dequantize in the gather (the
    prefill needs fp operands anyway; the int8 bytes in the pool are
    untouched)."""
    stage = {}
    for seg, c in pool.items():
        sub = {}
        for name in ("k", "v"):
            a = c[name][:, chain_ids]              # (L, ncb, bs, H, D)
            if name + "_scale" in c:
                a = dequantize_vectors_jnp(
                    a, c[name + "_scale"][:, chain_ids], c["k_tail"].dtype)
            L = a.shape[0]
            a = a.reshape(L, -1, *a.shape[3:])[:, :depth]
            a = jnp.pad(a, ((0, 0), (0, cap - depth), (0, 0), (0, 0)))
            sub[name] = a[:, None]                 # (L, 1, cap, H, D)
        pos = jnp.arange(cap, dtype=jnp.int32)
        sp = jnp.where(pos < depth, pos, -1)
        sub["slot_pos"] = jnp.broadcast_to(sp, (c["k"].shape[0], cap))
        stage[seg] = sub
    return stage


def _gather_quant(pool, chain_ids, depth: int, cap: int):
    """Harvest gather for int8 pools: like ``_stage_from_pool`` but the
    int8 codes and f32 scales are copied VERBATIM (no dequant) — the host
    entry built from this keeps the pool's exact bits, so a later
    promotion can put them back without a requant round-trip."""
    stage = {}
    for seg, c in pool.items():
        sub = {}
        for name in ("k", "v", "k_scale", "v_scale"):
            a = c[name][:, chain_ids]              # (L, ncb, bs, H[, D])
            L = a.shape[0]
            a = a.reshape(L, -1, *a.shape[3:])[:, :depth]
            pad = [(0, 0), (0, cap - depth)] + [(0, 0)] * (a.ndim - 2)
            sub[name] = jnp.pad(a, pad)[:, None]   # (L, 1, cap, H[, D])
        pos = jnp.arange(cap, dtype=jnp.int32)
        sp = jnp.where(pos < depth, pos, -1)
        sub["slot_pos"] = jnp.broadcast_to(sp, (c["k"].shape[0], cap))
        stage[seg] = sub
    return stage


def _scatter_to_pool(pool, stage, dst_ids, start: int, n: int, bs: int):
    """Write staging positions [start, start + n) into pool blocks
    ``dst_ids`` (dst_ids[i] holds positions [start + i*bs, ...)).  The
    copy-on-write boundary block is materialized here: staging already
    holds the donor prefix for [start, depth), so the divergent block's
    private copy costs no extra pass.  int8 pools quantize the scattered
    region here — for fresh tokens this is their first (and only)
    quantization."""
    ps = start + jnp.arange(n, dtype=jnp.int32)
    blk = dst_ids[(ps - start) // bs]
    off = ps % bs
    out = {}
    for seg, c in pool.items():
        upd = {}
        for name in ("k", "v"):
            vals = stage[seg][name][:, 0, start:start + n]
            if name + "_scale" in c:
                q, s = quantize_vectors_jnp(vals)
                upd[name] = c[name].at[:, blk, off].set(q)
                upd[name + "_scale"] = \
                    c[name + "_scale"].at[:, blk, off].set(s)
            else:
                upd[name] = c[name].at[:, blk, off].set(vals)
        out[seg] = {**c, **upd}
    return out


def _upload_q8(pool, ent, dst_ids, bs: int):
    """L2 -> L1 promotion of a quantized host entry's int8 region: copy
    the stored int8 codes + f32 scales straight into pool blocks
    ``dst_ids`` (positions [0, n), block-aligned).  No dequant/requant —
    the bits that were quantized once at the vectors' first write are the
    bits that land back in the pool."""
    out = {}
    for seg, c in pool.items():
        e = ent[seg]
        n = e["k"].shape[2]
        ps = jnp.arange(n, dtype=jnp.int32)
        blk = dst_ids[ps // bs]
        off = ps % bs
        upd = {name: c[name].at[:, blk, off].set(e[name][:, 0])
               for name in e}
        out[seg] = {**c, **upd}
    return out


def _fill_tail(pool, stage, row, m):
    """Populate pool row ``row``'s fp ring tail from a staging cache:
    ring slot r receives the fp values of block ``ti(r)`` — the unique
    block in the row's initial recency window (m//bs - R, m//bs] with
    ti % R == r — so the first decode step already attends its most
    recent R blocks at full precision.  Slots whose ti falls before the
    prompt (or holds no data yet) are zeroed; the kernel's recency gate
    never selects them.  int8 staging is dequantized here — tail fidelity
    is best-effort for admitted positions (exact for fp misses' freshly
    prefilled tokens, dequant for promoted/resident ones) and exact for
    every token decode later dual-writes."""
    out = {}
    for seg, c in pool.items():
        bs = c["k"].shape[2]                       # (L, NB, bs, H, D)
        R = c["k_tail"].shape[2] // bs             # (L, B, R*bs, H, D)
        cap = stage[seg]["k"].shape[2]             # (L, 1, cap, H[, D])
        open_b = m // bs
        r = jnp.arange(R, dtype=jnp.int32)
        ti = open_b - ((open_b - r) % R)           # block held by ring slot r
        j = jnp.arange(bs, dtype=jnp.int32)
        posm = ti[:, None] * bs + j[None]          # (R, bs) abs positions
        valid = ((posm >= 0) & (posm < cap)).reshape(-1)
        idx = jnp.clip(posm, 0, cap - 1).reshape(-1)
        upd = {}
        for name in ("k", "v"):
            vals = stage[seg][name][:, 0, idx]
            if name + "_scale" in stage[seg]:
                vals = dequantize_vectors_jnp(
                    vals, stage[seg][name + "_scale"][:, 0, idx],
                    c[name + "_tail"].dtype)
            vals = vals * valid[None, :, None, None]
            upd[name + "_tail"] = c[name + "_tail"].at[:, row].set(vals)
        out[seg] = {**c, **upd}
    return out


def _set_table_entries(pool, rows, idxs, blks):
    """Batched block-table update: entry (rows[j], idxs[j]) <- blks[j] in
    every layer, ONE dispatch for however many rows crossed a block
    boundary (or had one speculatively reserved) this step.  Padding
    entries carry idx == table width: out of bounds, dropped — so one
    fixed-width executable serves every update count."""
    out = {}
    for seg, c in pool.items():
        out[seg] = {**c, "block_tables":
                    c["block_tables"].at[:, rows, idxs].set(blks,
                                                            mode="drop")}
    return out


def _upload_fp_block(pool, blkdata, dst):
    """L2 -> L1 promotion of ONE block of a full-precision host entry:
    ``blkdata[seg]`` holds (L, bs, H, D) fp K/V for the block's positions.
    int8 pools quantize here — for entries that carried no sealed codes
    (fp entries, residual tails, converted legacy layouts) this is the
    vectors' first and only quantization.  Fixed shapes: one compiled
    executable regardless of how many blocks a promotion moves."""
    out = {}
    for seg, c in pool.items():
        upd = {}
        for name in ("k", "v"):
            vals = blkdata[seg][name]
            if name + "_scale" in c:
                q, s = quantize_vectors_jnp(vals)
                upd[name] = c[name].at[:, dst].set(q)
                upd[name + "_scale"] = c[name + "_scale"].at[:, dst].set(s)
            else:
                upd[name] = c[name].at[:, dst].set(vals)
        out[seg] = {**c, **upd}
    return out


def _upload_q8_block(pool, entblk, dst):
    """Verbatim int8 promotion of ONE sealed host-entry block: codes +
    scales land bit-exactly in pool block ``dst`` (the one-quantization
    invariant), with the same fixed per-block shape as the fp upload."""
    out = {}
    for seg, c in pool.items():
        upd = {name: c[name].at[:, dst].set(entblk[seg][name])
               for name in entblk[seg]}
        out[seg] = {**c, **upd}
    return out


def _set_row_tail(pool, row, tails):
    """Install a precomputed fp ring tail for row ``row`` (host-promotion
    seeding: the entry's fp residual provides exact values for the blocks
    preceding the first chunk)."""
    out = {}
    for seg, c in pool.items():
        upd = {n + "_tail": c[n + "_tail"].at[:, row].set(tails[seg][n])
               for n in ("k", "v")}
        out[seg] = {**c, **upd}
    return out


def _seed_tail_from_pool(pool, row, table_row, aligned):
    """Seed row ``row``'s fp ring tail for a RESIDENT-prefix chunked
    admission: ring slot r receives the dequantized pool content of the
    unique block ti in the window (aligned/bs - R, aligned/bs) with
    ti % R == r, so the first chunk's queries read their recent history at
    ring (not int8) fidelity — the same dequant values the staged path's
    staging gather would have produced.  Slots whose ti falls before the
    prompt are zeroed; the recency gates never select them.  The table is
    an explicit operand: the row's device table is still all-sentinel
    mid-admission."""
    out = {}
    for seg, c in pool.items():
        bs = c["k"].shape[2]                   # (L, NB, bs, H, D)
        R = c["k_tail"].shape[2] // bs
        ab = aligned // bs
        r = jnp.arange(R, dtype=jnp.int32)
        ti = (ab - 1) - ((ab - 1 - r) % R)     # block held by ring slot r
        valid = ti >= 0
        tbl = table_row
        blk = jnp.where(valid, tbl[jnp.clip(ti, 0, tbl.shape[0] - 1)], 0)
        upd = {}
        for name in ("k", "v"):
            a = c[name][:, blk]                # (L, R, bs, H, D)
            if name + "_scale" in c:
                a = dequantize_vectors_jnp(a, c[name + "_scale"][:, blk],
                                           c[name + "_tail"].dtype)
            a = a * valid[None, :, None, None, None]
            upd[name + "_tail"] = c[name + "_tail"].at[:, row].set(
                a.reshape(a.shape[0], -1, *a.shape[3:]))
        out[seg] = {**c, **upd}
    return out


def _ring_restore(pool, snap, ps, fbs):
    """Exact fp-ring rollback after a speculative round (int8 pools).

    Element (r, o) of row b's ring should end the round holding position
    q_correct = ti(r) * bs + o with ti(r) = fb - ((fb - r) % R) — the
    newest block <= the accept frontier ``fbs[b]`` congruent to r mod R,
    exactly what token-by-token decoding through the frontier would have
    left there.  Elements with q_correct >= ``ps[b]`` (the round's first
    written position) were rewritten by the verify pass with their exact
    values and are KEPT; every other element either was never touched
    (the snapshot equals the live ring) or was clobbered by a rejected
    write that wrapped onto an older slot, and is restored from the
    pre-round snapshot — which is exact there, because a slot's correct
    holder only changes when its position enters [ps, fb*bs + bs), i.e.
    when q_correct >= ps.  Rows not in the round pass ps = -2**30 (keep
    everything; their rings were only scribbled at stale positions the
    recency gates never select, same as every plain decode step)."""
    out = {}
    for seg, c in pool.items():
        bs = c["k"].shape[2]                   # (L, NB, bs, H, D)
        n = c["k_tail"].shape[2]               # (L, B, R*bs, H, D)
        R = n // bs
        idx = jnp.arange(n, dtype=jnp.int32)
        r, o = idx // bs, idx % bs
        ti = fbs[:, None] - ((fbs[:, None] - r[None]) % R)
        qc = ti * bs + o[None]                 # (B, R*bs)
        keep = (qc >= ps[:, None])[None, :, :, None, None]
        out[seg] = {**c,
                    "k_tail": jnp.where(keep, c["k_tail"],
                                        snap[seg]["k_tail"]),
                    "v_tail": jnp.where(keep, c["v_tail"],
                                        snap[seg]["v_tail"])}
    return out


def _set_row(pool, tokens, pos, row, table_row, tok0, m):
    out = {}
    for seg, c in pool.items():
        out[seg] = {**c,
                    "block_tables": c["block_tables"].at[:, row].set(table_row)}
    return out, tokens.at[row].set(tok0), pos.at[row].set(m)


def _clear_row(pool, row):
    out = {}
    for seg, c in pool.items():
        out[seg] = {**c, "block_tables":
                    c["block_tables"].at[:, row].set(SENTINEL)}
    return out


@dataclass
class _PendingAdmission:
    """A chunked admission in flight: the row is occupied but not yet
    decoding.  One chunk step runs per engine step, interleaved with the
    batched decode dispatch, so a long prompt never stalls the batch.
    The tier lookup is deferred to the FIRST chunk step (``started``),
    which lets an admission share blocks that a neighbor admitted in the
    same scheduler step has already sealed and registered."""
    st: _Slot
    next_c0: int = 0              # next chunk's (block-aligned) start
    w_floor: int = 0              # first pool position the chunks may write
    started: bool = False         # tier lookup + prefix setup done?
    # semantic block-donor graft state (None/-1 when no graft in flight).
    # ``segs`` splits the prompt into recompute segments around the
    # grafted interior: [(0, seg1_end), (graft_end, m)] — the chunks skip
    # [seg1_end, graft_end) entirely.  ``gate_at`` is the position where
    # the fidelity gate runs (end of segment 1); ``reg_cap`` caps the L1
    # trie registration frontier so approximate (grafted / post-graft)
    # blocks are NEVER indexed under the new prompt's token keys.
    segs: Optional[List[Tuple[int, int]]] = None
    seg_i: int = 0
    gate_at: int = -1
    reg_cap: int = 1 << 30
    graft: Optional[GraftPlan] = None
    graft_ref: Optional[dict] = None  # donor fp boundary K/V (gate ref)


class PagedEngine(Engine):
    """Continuous batching over a paged, prefix-shared device KV pool.

    Drop-in replacement for ``BatchedEngine`` behind the scheduler surface
    (``free_slots`` / ``admit_slot`` / ``decode_batch``); the dense slot
    pool stays as the equivalence reference.  Trunk attention only, no
    sliding window (paged blocks have no ring semantics).

    ``kv_quant=True`` switches the pool to the **int8 tier layout**
    (``core.quant`` scheme): pool K/V are int8 with per-vector f32 scales
    — ~2-4x more resident blocks per HBM byte, i.e. deeper batches and
    longer shareable prefixes on the same hardware.  Decode fuses the
    dequant into the block-table gather, and each row's most recent
    ``fp_tail_blocks`` blocks are attended from a full-precision ring
    tail (the device analogue of the host residual tail, which keeps
    greedy decoding token-identical to the fp pool).  The host (L2) tier
    holds quantized-tree entries with an fp residual tail; both tiers
    share one scheme, so:

      * a token's K/V is quantized ONCE — at the scatter/seal of its
        block (prefill scatter or decode dual-write);
      * harvest copies pool int8 verbatim into the host entry, and
        promotion copies the entry's full int8 blocks verbatim back into
        pool blocks — no dequant/requant round-trip (only the sub-block
        remainder of a partial boundary block re-quantizes, a
        value-preserving <= half-step event bounded to < block_size
        tokens per promotion);
      * the entry's fp residual tail feeds the pool's fp ring tail — the
        recent window is exact for entries admitted from an fp staging
        cache (precache, instant finish) and dequant-precision for
        pool-harvested ones, whose tails are rebuilt from the sealed int8
        codes (see ROADMAP "Int8 two-tier quantization", Known limits).
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 capacity: int = 256, num_blocks: Optional[int] = None,
                 fp_tail_blocks: int = 2, prefill_mode: str = "chunked",
                 prefill_chunk: Optional[int] = None,
                 prealloc_watermark: int = 1,
                 graft_max_div: float = 0.35,
                 speculative: bool = False, gamma: int = 4,
                 sink_blocks: int = 1, recent_blocks: int = 3,
                 spec_iters: int = 2,
                 preempt_policy: str = "least_progress",
                 overcommit: bool = False,
                 fault_plan=None, **kw):
        if kw.get("kv_quant"):
            # the int8 tier compresses its host tier by default, with a
            # residual deep enough that a promoted prefix can fill the
            # whole device fp ring tail with exact values
            kw.setdefault("compress_host_cache", True)
            kw.setdefault("compress_residual",
                          (fp_tail_blocks + 1) * kw.get("block_size", 64))
        super().__init__(cfg, params, **kw)
        if self.window:
            raise NotImplementedError("paged pool does not support "
                                      "sliding-window rings")
        bs = self.block                      # page size == radix block size
        if capacity % bs:
            capacity = _ceil_div(capacity, bs) * bs
        self.max_batch = max_batch
        self.capacity = capacity
        self.fp_tail_blocks = fp_tail_blocks
        self.nbt = capacity // bs            # fixed table width
        if num_blocks is None:
            # worst case every row full + one row's worth of retained
            # prefixes in the L1 trie + the sentinel
            num_blocks = max_batch * self.nbt + self.nbt + 1
        self.allocator = BlockAllocator(num_blocks, bs)
        self.trie = BlockTrie(bs)
        # ---- pressure-safe serving (PR 10) ---------------------------
        # preemption: when a step's alloc cannot be covered even by trie
        # eviction, demote a victim row's sealed KV to the host L2 and
        # requeue it (exact resume through warm admission).  Victims are
        # chosen least-progress first, latest-deadline tiebreak.
        if preempt_policy != "least_progress":
            raise ValueError(f"unknown preempt_policy {preempt_policy!r}")
        self.preempt_policy = preempt_policy
        # overcommit=True relaxes the chunked admission guarantee to the
        # PROMPT's blocks only: decode-time growth is served by
        # preemption instead of an up-front whole-lifetime reservation,
        # so an undersized pool oversubscribes and preempts rather than
        # rejecting at admission.
        self.overcommit = bool(overcommit)
        # deterministic fault injection (core.faults): threads the
        # "alloc" site into the allocator and the kvstore sites into the
        # recycler's store; "replica_step" fires in decode_batch.
        self.fault_plan = fault_plan
        if fault_plan is not None:
            self.allocator.fault_plan = fault_plan
            self.recycler.store.fault_plan = fault_plan
        # typed lifecycle events ("preempted" / "errored") for the
        # scheduler, drained once per step via drain_events()
        self._events: List[Tuple[str, dict]] = []
        self._preempted_now: set = set()
        # pending slots whose packed-segment descriptors are already
        # built this step: their blocks are about to be dispatched, so
        # they are not preemption victims until the dispatch lands
        self._pending_planned: set = set()
        # Under a mesh-carrying Runtime the pool is placed TP-sharded
        # (KV-head axis on 'model' when heads divide, replication fallback
        # otherwise — sharding.paged_pool_shardings); the attention
        # dispatches then run under shard_map (attention.paged_tp_axis).
        # Everything host-side (allocator, trie, table mirrors) is
        # replica-local numpy and never sees the mesh.
        self.pool = init_paged_pool(cfg, num_blocks, bs, max_batch,
                                    self.nbt, dtype=jnp.dtype(cfg.dtype),
                                    quant=self.kv_quant,
                                    fp_tail_blocks=fp_tail_blocks,
                                    mesh=self.rt.mesh)
        if prefill_mode not in ("chunked", "staged", "packed"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.prefill_mode = prefill_mode
        # semantic block-donor recycling (beyond paper; SemShareKV +
        # KVLink at block granularity): on a prefix miss, graft matching
        # interior donor blocks and recompute only the boundary.  The
        # mode rides the chunked admission's segment machinery — the
        # staged path has no segments, so the combination is rejected
        # rather than silently ignored.
        self.semantic = bool(getattr(self.recycler, "semantic", False))
        self.graft_max_div = graft_max_div
        self.semantic_gate_divs: List[float] = []
        if self.semantic and prefill_mode != "chunked":
            # packed admissions advance every pending chunk in one fused
            # dispatch and have no per-admission segment walk to ride
            raise ValueError("semantic grafting requires "
                             "prefill_mode='chunked'")
        if prefill_chunk is None:
            # default: 8 blocks per chunk.  Big enough that typical
            # admissions seal in one or two steps (and — for int8 pools —
            # that the fresh suffix usually lands in ONE chunk, whose
            # in-chunk attention is exact; history older than the fp ring
            # is read at int8 fidelity, see ROADMAP known limits), small
            # enough that a long prompt still yields the decode loop
            # between chunks.
            prefill_chunk = min(8 * bs, capacity)
        if prefill_chunk % bs or prefill_chunk <= 0:
            raise ValueError(
                f"prefill_chunk must be a positive multiple of the block "
                f"size {bs}, got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        # the FIXED ladder of chunk shapes: a step uses the smallest shape
        # covering its remaining suffix, so a 10-token warm-hit tail costs
        # a 2-block dispatch, not a full-width one.  The compile budget is
        # one executable per (shape, quant mode) — still independent of
        # how many distinct suffix lengths arrive.
        self.chunk_shapes = sorted({s for s in (bs, 2 * bs, prefill_chunk)
                                    if s <= prefill_chunk})
        # packed-admission buffer buckets: every pending admission's chunk
        # lands in ONE ragged buffer per engine step, padded to the
        # smallest bucket that fits.  One bucket per chunk shape (its
        # width times the worst-case segment count) keeps the compile
        # budget at <= len(chunk_shapes) executables per quant mode —
        # independent of suffix length AND concurrent-admission count.
        self.packed_buckets = sorted({c * self.max_batch
                                      for c in self.chunk_shapes})
        self.prealloc_watermark = prealloc_watermark
        # self-speculative decoding (PR 7): the same weights draft gamma
        # tokens against a sparse sink+recent block view — refined by
        # ``spec_iters`` fixed-point sweeps, each ONE multi-token
        # dispatch (see ``_draft_loop``) — then ONE batched multi-token
        # dispatch verifies the bundle against the full table.  Greedy
        # rows only — acceptance is longest-prefix match against the
        # greedy target, which keeps the output token-identical to the
        # plain step-by-step path.
        self.speculative = bool(speculative)
        self.gamma = int(gamma)
        self.sink_blocks = int(sink_blocks)
        self.recent_blocks = int(recent_blocks)
        self.spec_iters = int(spec_iters)
        if self.speculative:
            if self.gamma < 1:
                raise ValueError(f"gamma must be >= 1, got {gamma}")
            if self.spec_iters < 1:
                raise ValueError(
                    f"spec_iters must be >= 1, got {spec_iters}")
            if self.sink_blocks < 0 or self.recent_blocks < 1:
                raise ValueError("speculative drafting needs "
                                 "sink_blocks >= 0 and recent_blocks >= 1")
            if self.kv_quant and self.gamma > (fp_tail_blocks - 1) * bs:
                raise ValueError(
                    f"int8 speculative rollback requires gamma <= "
                    f"(fp_tail_blocks - 1) * block_size = "
                    f"{(fp_tail_blocks - 1) * bs}: a round writes ring "
                    f"positions [p, p + gamma], and the exact restore "
                    f"needs the pre-round snapshot to still cover every "
                    f"older block a wrapped write clobbered")
        # verify bundle width: gamma drafts + the pending token, padded
        # up to a block multiple (the verify kernel tiles by block)
        self.spec_cv = _ceil_div(self.gamma + 1, bs) * bs
        self.spec_ndt = self.sink_blocks + self.recent_blocks
        self._tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self._pos = jnp.zeros((max_batch,), jnp.int32)
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._pending: Dict[int, _PendingAdmission] = {}
        self._tables = np.zeros((max_batch, self.nbt), np.int32)  # host mirror
        self._row_blocks: List[List[int]] = [[] for _ in range(max_batch)]
        self._committed: List[int] = [0] * max_batch  # future allocs owed
        self._temp = np.zeros((max_batch,), np.float32)
        self._topk = np.zeros((max_batch,), np.int32)
        self._step_rng = self._sample_key

        self._stage_fn = jax.jit(_stage_from_pool, static_argnums=(2, 3))
        self._gather_q_fn = jax.jit(_gather_quant, static_argnums=(2, 3))
        self._scatter_fn = jax.jit(_scatter_to_pool,
                                   static_argnums=(3, 4, 5),
                                   donate_argnums=(0,))
        self._upload_fn = jax.jit(_upload_q8, static_argnums=(3,),
                                  donate_argnums=(0,))
        self._tail_fn = jax.jit(_fill_tail, donate_argnums=(0,))
        self._setrow_fn = jax.jit(_set_row, donate_argnums=(0, 1, 2))
        self._clear_fn = jax.jit(_clear_row, donate_argnums=(0,))
        self._pstep_fn = jax.jit(self._paged_step, donate_argnums=(1, 2, 3))
        self._pstep_sampled_fn = jax.jit(self._paged_step_sampled,
                                         donate_argnums=(1, 2, 3),
                                         static_argnums=(7,))
        # chunked-admission executables: ONE compiled prefill shape
        # (prefill_chunk is fixed; row / start / valid are traced scalars)
        self._chunk_fn = jax.jit(self._chunk_prefill, donate_argnums=(2,))
        # packed-admission executable: ONE dispatch advances EVERY pending
        # admission's chunk (ragged buffer + per-segment descriptors; one
        # compile per packed bucket)
        self._packed_fn = jax.jit(self._packed_prefill, donate_argnums=(2,))
        self._setents_batch_fn = jax.jit(_set_table_entries,
                                         donate_argnums=(0,))
        self._upload_blk_fn = jax.jit(_upload_fp_block, donate_argnums=(0,))
        self._upload_q8_blk_fn = jax.jit(_upload_q8_block,
                                         donate_argnums=(0,))
        self._settail_fn = jax.jit(_set_row_tail, donate_argnums=(0,))
        self._seedtail_fn = jax.jit(_seed_tail_from_pool,
                                    donate_argnums=(0,))
        # speculative executables: the whole draft loop is ONE dispatch
        # (spec_iters unrolled fixed-point sweeps over all gamma
        # positions), verification another — a round costs two
        # dispatches regardless of batch size or gamma.  The draft
        # only READS the pool (one view gather), so nothing is donated
        self._draft_fn = jax.jit(self._draft_loop)
        self._verify_fn = jax.jit(self._verify_step, donate_argnums=(2,))
        # a REAL device copy: the verify dispatch donates the pool, so a
        # mere reference to the live tails would be invalidated
        self._snap_fn = jax.jit(lambda t: jax.tree.map(jnp.copy, t))
        self._ringfix_fn = jax.jit(_ring_restore, donate_argnums=(0,))
        self.stats.update({
            "batched_decode_steps": 0, "admissions": 0, "sampled_steps": 0,
            "resident_hits": 0, "host_promotions": 0, "cow_copies": 0,
            "h2d_copies": 0, "h2d_bytes": 0, "trie_evictions": 0,
            "layout_conversions": 0,
            "q8_block_promotions": 0, "prefill_chunks": 0,
            "prefill_packed_steps": 0, "prefill_dispatches": 0,
            "staging_prefills": 0, "spec_preallocs": 0,
            "spec_rounds": 0, "spec_draft_tokens": 0,
            "spec_accepted_tokens": 0, "spec_emitted_tokens": 0,
            "spec_fallback_steps": 0,
            "semantic_grafts": 0, "semantic_refusals": 0,
            "semantic_resident_grafts": 0, "semantic_host_grafts": 0,
            "tokens_grafted": 0,
            "preemptions": 0, "preempted_tokens_recomputed": 0,
            "step_rollbacks": 0, "preempt_errors": 0,
        })

    # ------------------------------------------------------------------
    def _make_cache(self, capacity: int):
        """Admission staging is always full precision — the int8 pool
        quantizes at the scatter boundary (once per token), never inside
        the prefill — so one staging layout serves both pool tiers."""
        return init_cache(self.cfg, 1, capacity, window=self.window,
                          dtype=jnp.dtype(self.cfg.dtype), kv_quant=False)

    def _host_layout_ok(self, cache) -> bool:
        """A host entry is promotable iff it materializes to the plain fp
        staging layout.  Entries admitted by the dense ``kv_quant``
        engines carry native int8 + k_scale leaves the staged prefill
        can't consume — honest miss instead of corrupting the pool."""
        return not any(isinstance(c, dict) and "k_scale" in c
                       for c in cache.values())

    def _q8_blocks(self, raw, depth: int) -> int:
        """How many FULL blocks of a quantized host entry's int8 region
        cover [0, depth) — the part of a promotion that moves verbatim."""
        if raw is None or not kvq.is_quantized(raw):
            return 0
        for c in raw.values():
            leaf = c.get("k") if isinstance(c, dict) else None
            if isinstance(leaf, dict) and kvq._QKEY in leaf:
                if "ax" not in leaf:
                    # legacy quantized entry (pre-residual format): no
                    # verbatim upload — fall back to dequant + scatter
                    return 0
                ax = int(np.asarray(leaf["ax"]))
                split = leaf[kvq._QKEY].shape[ax]
                return min(split, depth) // self.block
        return 0

    def _slice_q8(self, raw, n8: int):
        """Host-side view of a quantized entry's first ``n8`` positions in
        the pool's upload layout: int8 codes + f32 scales (keepdim
        dropped), per segment.  Pure slicing — no arithmetic touches the
        stored bits."""
        ent = {}
        for seg, c in raw.items():
            sub = {}
            for name in ("k", "v"):
                leaf = c[name]
                ax = int(np.asarray(leaf["ax"]))
                sl = [slice(None)] * leaf[kvq._QKEY].ndim
                sl[ax] = slice(0, n8)
                sub[name] = jnp.asarray(leaf[kvq._QKEY][tuple(sl)])
                sub[name + "_scale"] = jnp.asarray(
                    np.asarray(leaf["scale"])[tuple(sl)][..., 0])
            ent[seg] = sub
        return ent

    def _harvest(self, chain_ids, depth: int, cap: int):
        """Gather pool blocks [0, depth) into a host-store entry.  fp
        pools return the dense staging layout; int8 pools return the
        quantized-tree host format built from the pool's VERBATIM int8
        codes (plus a dequantized fp residual tail), so the quantization
        the blocks received at their seal is the only one they ever get."""
        if not self.kv_quant:
            return to_host(self._stage_fn(self.pool, chain_ids, depth, cap))
        g = to_host(self._gather_q_fn(self.pool, chain_ids, depth, cap))
        residual = self.recycler.compress_residual
        split = max(0, depth - residual)
        dt = jnp.dtype(self.cfg.dtype)
        entry = {}
        for seg, c in g.items():
            sub = {"slot_pos": c["slot_pos"]}
            for name in ("k", "v"):
                q = c[name]                        # (L, 1, cap, H, D) int8
                s = c[name + "_scale"]             # (L, 1, cap, H) f32
                tail = (q[:, :, split:depth].astype(np.float32)
                        * s[:, :, split:depth, :, None]).astype(dt)
                sub[name] = {
                    kvq._QKEY: q[:, :, :split],
                    "scale": s[:, :, :split, :, None],
                    "dtype": np.dtype(dt).str,
                    "tail": tail,
                    "cap": np.int64(cap),
                    "ax": np.int64(2),
                }
            entry[seg] = sub
        return entry

    # ------------------------------------------------------------------
    def _paged_step(self, params, tokens, pool, pos):
        # greedy via the engine module so tests can substitute it (early
        # EOS) in the serial, dense-pool and paged paths at once
        logits, pool = decode_step(self.cfg, params, tokens, pool, pos,
                                   window=0, rt=self.rt)
        nxt = engine_mod.greedy(logits)
        return nxt, nxt[:, None], pool, pos + 1

    def _paged_step_sampled(self, params, tokens, pool, pos, temp, topk,
                            rng, topk_cap):
        logits, pool = decode_step(self.cfg, params, tokens, pool, pos,
                                   window=0, rt=self.rt)
        nxt = sample_batched(logits, rng, temperature=temp, top_k=topk,
                             top_k_cap=topk_cap)
        return nxt, nxt[:, None], pool, pos + 1

    def _chunk_prefill(self, params, tokens, pool, row, table_row, c0,
                       w_floor, n_valid):
        return prefill_paged(self.cfg, params, tokens, pool, row,
                             table_row, c0, w_floor, n_valid, rt=self.rt)

    def _packed_prefill(self, params, tokens, pool, rows, tables, c0s,
                        w_floors, valids, q_offs, seg_ids):
        return prefill_paged_packed(self.cfg, params, tokens, pool, rows,
                                    tables, c0s, w_floors, valids, q_offs,
                                    seg_ids, rt=self.rt)

    # ------------------------------------------------------------------
    # self-speculative decoding (drafter == target; sparse-view draft)
    # ------------------------------------------------------------------
    def _draft_loop(self, params, tok0, pool, pos, dtab, dbase):
        """``gamma`` greedy sparse-view drafts in ONE dispatch: the same
        weights decode attending only the sink + recent pool blocks
        named by ``dtab``/``dbase`` (positions stay truthful through the
        base indices).  The pool is READ-ONLY here — its sparse view is
        gathered ONCE up front — and the guesses are refined by
        ``spec_iters`` FIXED-POINT sweeps (``draft_refine``), each a
        single multi-token forward over all gamma positions, instead of
        gamma sequential one-token decodes: after k sweeps the first k
        drafts equal exact sequential greedy over the view, and
        predictable spans converge much faster, so a whole round costs
        spec_iters + 1 bundle-sized dispatches.  Drafts write nothing:
        the verify pass encodes every round position itself, so drafts
        only ever influence which tokens get PROPOSED, never what the
        pool ends up holding."""
        view, vpos = draft_view(self.cfg, pool, dtab, dbase, pos)
        guess = jnp.tile(tok0, (1, self.gamma))
        for _ in range(self.spec_iters):
            toks = jnp.concatenate([tok0, guess[:, :-1]], axis=1)
            logits = draft_refine(self.cfg, params, toks, view, vpos,
                                  pos, rt=self.rt)
            # greedy via the engine module: tests substitute it (early
            # EOS), and the drafter must propose with the same rule the
            # verifier accepts by
            guess = engine_mod.greedy(logits)
        return guess

    def _verify_step(self, params, tokens, pool, snap, c0s, act):
        """ONE batched dispatch verifying every armed row's bundle (the
        pending token + its gamma drafts) against the FULL table,
        returning the greedy target at every bundle position.  int8
        pools attend their fp recent window from the pre-round ring
        SNAPSHOT — taken anyway for the exact rollback restore, and
        identical to the live ring since drafts stopped touching the
        pool; the snapshot rides into the layer scan as extra cache
        leaves and is stripped before the pool comes back."""
        if snap is not None:
            pool = {seg: {**c, "k_tail_snap": snap[seg]["k_tail"],
                          "v_tail_snap": snap[seg]["v_tail"]}
                    for seg, c in pool.items()}
        logits, pool = verify_paged(self.cfg, params, tokens, pool, c0s,
                                    jnp.int32(self.gamma + 1), act,
                                    rt=self.rt)
        return engine_mod.greedy(logits), pool

    def _draft_tokens(self, draft):
        """The draft tokens fed to verification — a patchable seam: the
        rollback property test substitutes ARBITRARY tokens here, because
        acceptance must reproduce the non-speculative output whatever the
        drafter proposed (drafts only affect speed, never tokens)."""
        return draft

    def _spec_ready(self, active) -> bool:
        """A speculative round replaces this step iff every active row
        decodes greedily (acceptance is longest-prefix match against the
        greedy target), every row's gamma + 1 bundle positions fit its
        capacity, and the round's reserved blocks are obtainable."""
        if np.any(self._temp > 0.0):
            return False
        bs = self.block
        need = 0
        for i in active:
            st = self._slots[i]
            p = st.m + len(st.emitted) - 1
            if p + self.gamma > self.capacity - 1:
                return False
            need += sum(1 for idx in range(p // bs,
                                           (p + self.gamma) // bs + 1)
                        if self._tables[i, idx] == SENTINEL)
        return self.allocator.num_free() + self._evictable() >= need

    def _spec_round(self, active):
        """One speculative round over the armed rows: reserve the
        bundle's blocks, snapshot the fp ring (int8), draft gamma tokens
        against the sparse sink+recent view, verify the bundle in one
        batched full-table dispatch, emit the accepted prefix plus the
        free bonus token, and roll the rejected tail back (table
        truncation + refcount release + exact ring restore).  Token-for-
        token identical to plain greedy steps — the drafts only decide
        how many of those steps one round buys."""
        t_round = time.perf_counter()
        bs = self.block
        B, g = self.max_batch, self.gamma
        W = self.nbt + 2 * self.max_batch
        ps_h: Dict[int, int] = {}
        reserved: Dict[int, List[Tuple[int, int]]] = {}
        updates: List[Tuple[int, int, int]] = []
        for i in active:
            st = self._slots[i]
            p = st.m + len(st.emitted) - 1
            ps_h[i] = p
            rs = []
            for idx in range(p // bs, (p + g) // bs + 1):
                if self._tables[i, idx] == SENTINEL:
                    b = self._alloc_block()
                    self._tables[i, idx] = b
                    self._row_blocks[i].append(b)
                    self._committed[i] -= 1
                    updates.append((i, idx, b))
                    rs.append((idx, b))
            reserved[i] = rs
        while updates:
            self._apply_table_updates(updates[:W])
            updates = updates[W:]

        snap = None
        if self.kv_quant:
            snap = self._snap_fn({seg: {"k_tail": c["k_tail"],
                                        "v_tail": c["v_tail"]}
                                  for seg, c in self.pool.items()})

        # sparse draft view: first sink_blocks table entries + the
        # recent window ending at the round's last reserved block, with
        # the ORIGINAL table indices alongside so kv positions stay
        # truthful; -1 bases mark padding
        dtab = np.zeros((B, self.spec_ndt), np.int32)      # SENTINEL pad
        dbase = np.full((B, self.spec_ndt), -1, np.int32)
        pos_h = np.zeros((B,), np.int32)
        tok0 = np.zeros((B, 1), np.int32)
        act_h = np.zeros((B,), np.int32)
        for i in active:
            p = ps_h[i]
            hi = (p + g) // bs
            lo = max(0, hi - self.recent_blocks + 1)
            idxs = (list(range(min(self.sink_blocks, lo)))
                    + list(range(lo, hi + 1)))
            for j, idx in enumerate(idxs):
                dtab[i, j] = self._tables[i, idx]
                dbase[i, j] = idx
            pos_h[i] = p
            tok0[i, 0] = self._slots[i].emitted[-1]
            act_h[i] = 1

        dts = self._draft_fn(
            self.params, jnp.asarray(tok0), self.pool,
            jnp.asarray(pos_h), jnp.asarray(dtab), jnp.asarray(dbase))
        draft = self._draft_tokens(np.asarray(dts))

        vt = np.zeros((B, self.spec_cv), np.int32)
        for i in active:
            vt[i, 0] = self._slots[i].emitted[-1]
            vt[i, 1:g + 1] = draft[i]
        tg, self.pool = self._verify_fn(
            self.params, jnp.asarray(vt), self.pool, snap,
            jnp.asarray(pos_h), jnp.asarray(act_h))
        targets = np.asarray(tg)
        dt_round = time.perf_counter() - t_round

        done: List[Tuple[int, GenResult]] = []
        roll_updates: List[Tuple[int, int, int]] = []
        roll_free: List[int] = []
        fix_ps = np.full((B,), -(2 ** 30), np.int32)  # default: keep all
        fix_fbs = np.zeros((B,), np.int32)
        self.stats["spec_rounds"] += 1
        self.stats["spec_draft_tokens"] += g * len(active)
        for i in active:
            st = self._slots[i]
            p = ps_h[i]
            # greedy acceptance: longest prefix of drafts matching the
            # verifier's targets, then the target at the first mismatch
            # (or past the last draft) rides along free
            a = 0
            while a < g and draft[i, a] == targets[i, a]:
                a += 1
            self.stats["spec_accepted_tokens"] += a
            burst = [int(x) for x in draft[i, :a]] + [int(targets[i, a])]
            n_emit = 0
            finished = False
            for t in burst:
                st.emitted.append(t)
                n_emit += 1
                if ((st.stop_at_eos and t == EOS)
                        or len(st.emitted) >= st.max_new):
                    finished = True
                    break
            self.stats["spec_emitted_tokens"] += n_emit
            # honest per-token latency: the round emitted n_emit tokens
            # in one burst — each records an equal share of the round
            for _ in range(n_emit):
                st.step_times_s.append(dt_round / n_emit)
            if finished:
                done.append((i, self._result(st, row=i)))
                self._release_row(i)
                continue
            # rollback: reserved blocks past the accept frontier leave
            # the table and return to the free list; verify's writes at
            # the kept positions [p, p + a] stay (they are the exact
            # values plain decode would have written)
            fb = (p + a) // bs
            dropped = [(idx, b) for idx, b in reserved[i] if idx > fb]
            if dropped:
                for idx, b in dropped:
                    self._tables[i, idx] = SENTINEL
                    roll_updates.append((i, idx, SENTINEL))
                    roll_free.append(b)
                    self._committed[i] += 1
                self._row_blocks[i] = [int(x) for x in self._tables[i]
                                       if x != SENTINEL]
            fix_ps[i] = p
            fix_fbs[i] = fb
        while roll_updates:
            self._apply_table_updates(roll_updates[:W])
            roll_updates = roll_updates[W:]
        if roll_free:
            self.allocator.unref_many(roll_free)
        if self.kv_quant:
            # exact ring restore (see _ring_restore): finished rows keep
            # their stale rings — the next admission reseeds them before
            # any query can gate them in
            self.pool = self._ringfix_fn(self.pool, snap,
                                         jnp.asarray(fix_ps),
                                         jnp.asarray(fix_fbs))
        # rebuild the device token/pos mirrors wholesale: every
        # surviving row advanced a different number of tokens this round
        tok_h = np.zeros((B, 1), np.int32)
        npos_h = np.zeros((B,), np.int32)
        for i in self.active_slots():
            st = self._slots[i]
            tok_h[i, 0] = st.emitted[-1]
            npos_h[i] = st.m + len(st.emitted) - 1
        self._tokens = jnp.asarray(tok_h)
        self._pos = jnp.asarray(npos_h)
        return done

    def prefill_compiles(self) -> int:
        """How many prefill executables the admission path has compiled.
        The chunked path's whole point is that this is bounded by
        ``len(self.chunk_shapes)`` (one per fixed chunk shape) —
        independent of how many distinct suffix lengths were admitted —
        where the staged path compiles one per (suffix length, capacity
        bucket).  The packed path is bounded by ``len(self.packed_buckets)``
        — also independent of how many admissions ran CONCURRENTLY."""
        fn = {"chunked": self._chunk_fn,
              "packed": self._packed_fn}.get(self.prefill_mode,
                                             self._prefill_fn)
        try:
            return fn._cache_size()
        except AttributeError:  # pragma: no cover - older jax
            return -1

    # ------------------------------------------------------------------
    def _convert_dense_quant(self, cache):
        """A host entry admitted by the dense ``kv_quant`` engines carries
        native int8 K/V + per-vector scale leaves the staged/chunked
        admission layouts can't consume directly.  Dequantize it to the
        plain fp staging layout (value-preserving to within half a quant
        step) so the entry still promotes instead of being skipped — the
        honest fix for the old ``layout_skips`` gap."""
        dt = jnp.dtype(self.cfg.dtype)
        out = {}
        for seg, c in cache.items():
            if isinstance(c, dict) and "k_scale" in c:
                sub = {"slot_pos": c["slot_pos"]}
                for name in ("k", "v"):
                    q = np.asarray(c[name], np.float32)
                    s = np.asarray(c[name + "_scale"], np.float32)
                    sub[name] = (q * s[..., None]).astype(dt)
                out[seg] = sub
            else:
                out[seg] = c
        return out

    def _lookup_tiers(self, prompt: str, ids, m: int):
        """Serve an admission from the cache tiers: L1 (device-resident
        block trie) preferred, L2 (host store) behind it.  Returns
        (depth, hit, mode, sim, chain, res, host_cache) where host_cache
        is the promotable staging-layout view of the L2 entry (converted
        from the dense kv_quant layout when necessary)."""
        d1, chain = self.trie.lookup(ids)
        d1 = min(d1, m - 1)
        d2, res = 0, None
        sim = 0.0
        if d1 < m - 1:
            # L1 can still be beaten — consult the host (L2) tier.  At
            # maximal resident depth the lookup is skipped: no host hit
            # (d2 <= m-1) could win, and Recycler.lookup would materialize
            # the whole host cache just to be discarded.
            res = self.recycler.lookup(prompt, ids)
            if res.hit:
                d2 = res.reuse_depth
            sim = res.similarity
        # prefer the resident tier unless the host hit is deeper by MORE
        # than one block: re-prefilling a partial-block tail is far
        # cheaper than a host→device copy of the whole prefix
        if d1 > 0 and d1 >= d2 - self.block:
            # a resident hit is served by the trie, not retrieval — there
            # is no honest similarity to report
            self.stats["resident_hits"] += 1
            return d1, True, "resident_block", float("nan"), chain, res, None
        if d2 > 0:
            # lazy layout conversion — only once the host tier actually
            # WON the comparison, so a resident hit never pays (or counts)
            # a conversion it would discard
            host_cache = res.cache
            if not self._host_layout_ok(host_cache):
                host_cache = self._convert_dense_quant(host_cache)
                self.stats["layout_conversions"] += 1
            self.stats["host_promotions"] += 1
            return d2, True, res.mode, sim, [], res, host_cache
        return 0, False, "miss", sim, [], res, None

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots)
                if s is None and i not in self._pending]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    def pending_admissions(self) -> List[int]:
        return sorted(self._pending)

    # ------------------------------------------------------------------
    # block bookkeeping
    # ------------------------------------------------------------------
    def _evictable(self, exclude=()) -> int:
        ex = set(exclude)
        return self.trie.evictable(
            lambda b: b not in ex and self.allocator.refcount(b) == 1)

    def _alloc_block(self, protect=()) -> int:
        """A fresh private block, evicting cold L1 prefixes if needed.
        ``protect`` names blocks that must survive eviction (e.g. the
        boundary block an in-progress admission is about to gather)."""
        try:
            return self.allocator.alloc()
        except BlockPoolExhausted:
            ex = set(protect)
            dropped = self.trie.evict(
                1, lambda b: b not in ex and self.allocator.refcount(b) == 1)
            for b in dropped:
                self.allocator.unref(b)
                self.stats["trie_evictions"] += 1
            if not dropped:
                raise
            return self.allocator.alloc()

    # ------------------------------------------------------------------
    # preemption with exact resume (PR 10)
    # ------------------------------------------------------------------
    def drain_events(self) -> List[Tuple[str, dict]]:
        """Typed lifecycle events since the last drain.  ``("preempted",
        payload)`` carries everything ``admit_slot(resume=payload)``
        needs to resume the request token-identically; ``("errored",
        payload)`` reports a request the engine had to drop (payload has
        ``slot`` / ``error``)."""
        ev, self._events = self._events, []
        return ev

    def _alloc_pressure(self, protect=(), exclude_rows=(),
                        exclude_pending=()) -> int:
        """``_alloc_block`` that converts exhaustion into preemption:
        while no block is obtainable, demote one victim (least-progress
        first, latest-deadline tiebreak) and retry.  Raises
        BlockPoolExhausted only once no eligible victim remains — the
        caller then preempts/cancels ITSELF, so the exception never
        escapes an engine step."""
        while True:
            try:
                return self._alloc_block(protect=protect)
            except BlockPoolExhausted:
                if not self._try_preempt(exclude_rows=exclude_rows,
                                         exclude_pending=exclude_pending):
                    raise

    def _try_preempt(self, exclude_rows=(), exclude_pending=()) -> bool:
        """Pick and preempt ONE victim.  Candidates: decoding rows
        (demoted to L2 + requeued) and block-holding pending admissions
        (cancelled + requeued; their sealed chunks stay warm in the L1
        trie).  Grafted rows are never victims — their approximate
        blocks must not reach the host store (contamination rule), and a
        re-admission could gate differently."""
        cands = []
        for i, st in enumerate(self._slots):
            if st is None or i in exclude_rows or i in self._preempted_now:
                continue
            if st.mode == "semantic_block":
                continue
            dl = st.deadline_t if st.deadline_t is not None else float("inf")
            cands.append((len(st.emitted), -dl, 1, i))
        for s, adm in self._pending.items():
            if (s in exclude_pending or s in self._pending_planned
                    or s in self._preempted_now
                    or not self._row_blocks[s] or adm.graft is not None):
                continue
            st = adm.st
            dl = st.deadline_t if st.deadline_t is not None else float("inf")
            cands.append((0, -dl, 0, s))
        if not cands:
            return False
        cands.sort()
        _, _, kind, s = cands[0]
        if kind == 1:
            self._preempt_row(s)
        else:
            st = self._cancel_admission(s)
            payload = self._resume_payload(st, [])
            payload["slot"] = s
            self._events.append(("preempted", payload))
            self.stats["preemptions"] += 1
        self._preempted_now.add(s)
        return True

    def _resume_payload(self, st: _Slot, extra) -> dict:
        """Everything ``admit_slot(resume=...)`` needs to continue this
        request exactly where it stopped.  ``extra`` is the tokens this
        residency emitted (appended to the resume prompt so the warm
        re-admission re-derives the next token from the same state)."""
        extra = list(extra)
        ids = (np.concatenate([st.ids, np.asarray(extra, np.int32)])
               if extra else st.ids)
        return {
            "prompt": st.prompt, "ids": ids,
            "emitted": list(st.resume_emitted) + extra,
            "max_new_left": st.max_new - len(extra),
            "preemptions": st.preemptions + 1,
            "tokens_recomputed": st.tokens_recomputed,
            "t0": st.t0, "t_first": st.t_first,
            "temperature": st.temperature, "top_k": st.top_k,
            "tenant": st.tenant, "use_recycling": st.use_recycling,
            "admit": st.admit, "stop_at_eos": st.stop_at_eos,
        }

    def _mark_resumed(self, st: _Slot, resume: dict) -> None:
        """Carry a preempted request's cross-residency state onto its
        resumed slot: previously emitted tokens (prepended to the final
        result), preemption counters, and the ORIGINAL t0 / t_first so
        latency and TTFT describe the request, not its last residency."""
        st.resume_emitted = list(resume["emitted"])
        st.preemptions = int(resume["preemptions"])
        st.tokens_recomputed = int(resume.get("tokens_recomputed", 0))
        st.t0 = float(resume["t0"])
        if resume.get("t_first"):
            st.t_first = float(resume["t_first"])

    def _preempt_row(self, row: int) -> None:
        """Demote a decoding row and requeue it.  Sealed KV [0, p) —
        p = m + k - 1, since the last emitted token's KV is not written
        until the next step — is harvested to the host L2 (int8 pools
        keep their codes verbatim, with the live fp ring overlaid into
        the entry's residual tail so the resume reseeds an EXACT ring);
        trie-resident prompt blocks just drop the row's reference and
        stay warm.  The resume payload re-admits prompt + all emitted
        tokens through the ordinary warm admission machinery, which is
        what makes a preempted-then-resumed greedy run token-identical
        to an uninterrupted one."""
        st = self._slots[row]
        p = st.m + len(st.emitted) - 1
        if st.use_recycling and p > 0:
            cap = self._capacity(st.m + st.max_new)
            chain = [b for b in self._tables[row]
                     if b != SENTINEL][:_ceil_div(p, self.block)]
            entry = self._harvest(jnp.asarray(chain, jnp.int32), p, cap)
            if self.kv_quant:
                self._overlay_ring_tail(entry, row, p)
            ids_sealed = np.concatenate(
                [st.ids, np.asarray(st.emitted[:-1], np.int32)])
            self.recycler.admit(st.prompt, ids_sealed, entry, p, cap,
                                tenant=st.tenant)
        payload = self._resume_payload(st, st.emitted)
        payload["slot"] = row
        self._release_row(row)
        self._events.append(("preempted", payload))
        self.stats["preemptions"] += 1

    def _overlay_ring_tail(self, entry, row: int, depth: int) -> None:
        """Overlay the row's LIVE fp ring values into a harvest entry's
        residual tail.  ``_harvest`` rebuilds the tail by dequantizing
        the sealed int8 codes, but an uninterrupted run attends its last
        R blocks from the exact fp ring — without this overlay a resumed
        row would read dequant-precision recents where the uninterrupted
        run reads exact ones, breaking token identity.  Positions older
        than the ring keep the dequantized values (both runs read those
        through the int8 codes, so they agree by construction)."""
        bs = self.block
        R = self.fp_tail_blocks
        residual = self.recycler.compress_residual
        split = max(0, depth - residual)
        fb = (depth - 1) // bs
        lo = max(max(0, fb - R + 1) * bs, split)
        for seg, sub in entry.items():
            for name in ("k", "v"):
                ring = np.asarray(self.pool[seg][name + "_tail"][:, row])
                tail = sub[name]["tail"]          # (L, 1, depth-split, H, D)
                for q in range(lo, depth):
                    off = (q // bs % R) * bs + q % bs
                    tail[:, 0, q - split] = ring[:, off]

    def _cancel_admission(self, slot: int) -> _Slot:
        """Roll a pending admission back to a clean, invariant-true
        state: the row's blocks are dereferenced (chunks already sealed
        AND registered stay warm through the trie's own references), the
        mirrors are cleared, and the slot is free again.  Returns the
        slot record so the caller can requeue or error the request."""
        adm = self._pending.pop(slot)
        # unref from the TABLE mirror, not _row_blocks: a chunk's alloc
        # loop updates the table block-by-block and only syncs
        # _row_blocks after the loop — cancelling mid-loop must still
        # free the blocks the partial loop grabbed
        for x in self._tables[slot]:
            if x != SENTINEL:
                self.allocator.unref(int(x))
        self._row_blocks[slot] = []
        self._committed[slot] = 0
        self._tables[slot] = SENTINEL
        self.pool = self._clear_fn(self.pool, slot)
        return adm.st

    def _self_preempt(self, row: int) -> None:
        """Last resort when a row's own decode write cannot be covered
        even after preempting every eligible victim: demote THIS row
        (or, for a grafted row — which must never be demoted — error it
        out) so ``BlockPoolExhausted`` never escapes the step."""
        st = self._slots[row]
        if st.mode == "semantic_block":
            self._events.append(("errored", {
                "slot": row, "prompt": st.prompt, "tenant": st.tenant,
                "error": "pool exhausted: grafted row cannot be demoted"}))
            self.stats["preempt_errors"] += 1
            self._release_row(row)
        else:
            self._preempt_row(row)
        self._preempted_now.add(row)

    def device_kv_bytes_in_use(self) -> int:
        """Bytes of pool K/V actually referenced (live blocks, counted
        once however many tables share them).  In int8 mode a block costs
        int8 K/V + per-vector f32 scales — the ~2-4x reduction this
        returns vs an fp pool is the whole point of the tier; the per-row
        fp ring tails are a constant (max_batch-sized) overhead, not a
        per-block cost, and are excluded."""
        return self.allocator.num_live() * paged_block_bytes(
            self.cfg, self.block, dtype=jnp.dtype(self.cfg.dtype),
            quant=self.kv_quant)

    def kv_tp_degree(self) -> int:
        """How many 'model' shards the pool's KV-head axis is split over
        (1 when unsharded or when the replication fallback applied)."""
        from repro.models.attention import paged_tp_axis
        ax = paged_tp_axis(self.rt, {"k": self.pool["seg0"]["k"][0]})
        return 1 if ax is None else self.rt.mesh.shape[ax]

    def device_kv_bytes_per_device(self) -> int:
        """Live pool bytes each device holds: the TP shards split the
        KV-head axis, so per-device bytes are in_use / tp."""
        return self.device_kv_bytes_in_use() // self.kv_tp_degree()

    # ------------------------------------------------------------------
    def admit_slot(self, slot: int, prompt: str, *,
                   max_new_tokens: Optional[int] = None,
                   use_recycling: bool = True, admit: bool = False,
                   stop_at_eos: bool = True, temperature: float = 0.0,
                   top_k: int = 0,
                   tenant: Optional[str] = None,
                   deadline_t: Optional[float] = None,
                   resume: Optional[dict] = None) -> Optional[GenResult]:
        """Admit ``prompt`` into pool row ``slot``.

        ``prefill_mode="chunked"`` (default): the admission is queued as a
        sequence of fixed-size chunk steps that ``decode_batch`` advances
        one per engine step, interleaved with the batched decode dispatch.
        Each chunk writes its K/V straight into freshly allocated pool
        blocks and attends through the block table — the staging cache,
        the resident-prefix gather and the post-prefill scatter of the
        staged path do not exist on this route, and one compiled prefill
        executable PER FIXED CHUNK SHAPE serves every suffix length.

        ``prefill_mode="packed"`` queues the admission identically, but
        ``decode_batch`` advances ALL pending admissions' chunks in ONE
        ragged packed dispatch per step (``_admission_step_packed``).

        ``prefill_mode="staged"`` keeps the original path (one dense
        prefill over a full-capacity staging cache, gathered from /
        scattered back to the pool) as the reference baseline."""
        if self._slots[slot] is not None or slot in self._pending:
            raise ValueError(f"slot {slot} is occupied")
        t0 = time.perf_counter()
        if resume is not None:
            # resume of a preempted request: the "prompt" token stream is
            # the original prompt + every token already emitted, so the
            # warm admission re-derives the next token from exactly the
            # state the preemption froze
            ids = np.asarray(resume["ids"], np.int32)
            m = len(ids)
            max_new = int(resume["max_new_left"])
        else:
            max_new = max_new_tokens or self.max_new
            ids = self.tok.encode(prompt)
            m = len(ids)
        if m + max_new > self.capacity:
            raise ValueError(f"request needs {m + max_new} positions; pool "
                             f"capacity is {self.capacity}")
        if self.prefill_mode in ("chunked", "packed"):
            return self._admit_chunked(slot, prompt, ids, m, max_new,
                                       use_recycling, admit, stop_at_eos,
                                       temperature, top_k, t0, tenant,
                                       deadline_t=deadline_t, resume=resume)
        return self._admit_staged(slot, prompt, ids, m, max_new,
                                  use_recycling, admit, stop_at_eos,
                                  temperature, top_k, t0, tenant,
                                  deadline_t=deadline_t, resume=resume)

    def _admit_staged(self, slot: int, prompt: str, ids, m: int,
                      max_new: int, use_recycling: bool, admit: bool,
                      stop_at_eos: bool, temperature: float, top_k: int,
                      t0: float, tenant: Optional[str] = None,
                      deadline_t: Optional[float] = None,
                      resume: Optional[dict] = None) -> Optional[GenResult]:
        """The PR-2 admission path: L1 block-table reuse when the prefix
        is device-resident, else L2 host promotion, else a cold prefill —
        all through one staged dense prefill whose result is scattered
        into (copy-on-write) private blocks.  Kept as the equivalence
        reference for the chunked path."""
        bs = self.block
        nb_prompt = _ceil_div(m, bs)
        nb_total = _ceil_div(m + max_new, bs)

        depth, hit, mode, sim = 0, False, "baseline", 0.0
        chain: List[Tuple[int, int]] = []
        res, host_cache = None, None
        if use_recycling:
            depth, hit, mode, sim, chain, res, host_cache = \
                self._lookup_tiers(prompt, ids, m)

        nb_shared = depth // bs if chain else 0
        start = nb_shared * bs               # first position written fresh
        shared = [b for b, _ in chain[:nb_shared]]
        gather = [b for b, _ in chain[:_ceil_div(depth, bs)]] if chain else []

        # admission guarantee: every block this request will ever need —
        # now or at a later decode boundary — must be obtainable without
        # starving the futures other in-flight rows were promised
        need_now = nb_prompt - nb_shared
        need_later = nb_total - nb_prompt
        owed = sum(self._committed)
        avail = self.allocator.num_free() + self._evictable(exclude=gather)
        if avail < need_now + need_later + owed:
            msg = (
                f"paged pool exhausted: request needs {need_now + need_later}"
                f" blocks, {avail - owed} obtainable "
                f"(free={self.allocator.num_free()}, "
                f"in-flight reservations={owed})")
            if self.active_slots() or self._pending:
                # in-flight rows will free blocks — transient, retry later
                raise PoolSaturated(msg)
            raise ValueError(msg)

        for b in shared:                      # share the resident prefix
            self.allocator.ref(b)
        fresh: List[int] = []
        try:
            for _ in range(need_now):
                fresh.append(self._alloc_block(protect=gather))
        except (BlockPoolExhausted, InjectedFault) as e:
            # contained rollback: the guarantee above held, so this can
            # only be an injected/transient fault — undo the partial grab
            # and report saturation so the scheduler retries
            for b in fresh:
                self.allocator.unref(b)
            for b in shared:
                self.allocator.unref(b)
            self.stats["step_rollbacks"] += 1
            raise PoolSaturated(str(e)) from e
        if chain and depth % bs:
            # divergent boundary block: its private copy is written from
            # staging below instead of mutating the shared original
            self.stats["cow_copies"] += 1

        # ---- staged dense prefill (the compiled serial path) ----------
        cap = self._capacity(m)
        if mode == "resident_block":
            stage = self._stage_fn(self.pool, jnp.asarray(gather, jnp.int32),
                                   depth, cap)
        elif hit:
            self.stats["h2d_copies"] += 1
            self.stats["h2d_bytes"] += tree_bytes(host_cache)
            stage = jax.tree.map(jnp.asarray, grow_capacity(host_cache, cap))
        else:
            stage = self._make_cache(cap)
        suffix = jnp.asarray(ids[depth:])[None]
        logits, stage = self._prefill_fn(self.params, suffix, stage, depth)
        self.stats["staging_prefills"] += 1
        self.stats["prefill_dispatches"] += 1

        # ---- scatter the fresh region [start, m) into private blocks --
        # A quantized host entry's full int8 blocks are promoted verbatim
        # (_upload_q8, no requant); everything after them — the entry's fp
        # residual tail, the sub-block remainder, the fresh suffix — is
        # quantized here, at its one scatter.
        if fresh:
            up = (self._q8_blocks(res.entry.cache, depth)
                  if self.kv_quant and hit and mode != "resident_block"
                  and res is not None and res.entry is not None else 0)
            if up:
                self.pool = self._upload_fn(
                    self.pool, self._slice_q8(res.entry.cache, up * bs),
                    jnp.asarray(fresh[:up], jnp.int32), bs)
                self.stats["q8_block_promotions"] += up
            s0 = start + up * bs             # start == 0 on the host path
            self.pool = self._scatter_fn(
                self.pool, stage, jnp.asarray(fresh[up:], jnp.int32),
                s0, m - s0, bs)
        if self.kv_quant:
            # the row's fp ring tail must cover its last R blocks from the
            # very first decode step — even on a fully-shared resident hit
            # the previous occupant's tail is stale for this request
            self.pool = self._tail_fn(self.pool, stage, jnp.int32(slot),
                                      jnp.int32(m))

        # ---- index the now-resident prompt prefix in L1 ---------------
        table_blocks = shared + fresh        # covers [0, m)
        for b in self.trie.register(ids, m, table_blocks):
            self.allocator.ref(b)            # the trie's own reference

        if temperature > 0.0:
            self._step_rng, sub = jax.random.split(self._step_rng)
            tok0 = sample_logits(logits, sub, temperature=temperature,
                                 top_k=top_k)
        else:
            tok0 = engine_mod.greedy(logits)

        self.stats["requests"] += 0 if resume else 1
        self.stats["hits"] += int(hit)
        self.stats["tokens_reused"] += depth
        self.stats["tokens_prefilled"] += m - depth
        self.stats["admissions"] += 1

        st = _Slot(prompt, ids, m, max_new, use_recycling, admit,
                   stop_at_eos, depth, hit, mode, sim,
                   emitted=[int(tok0[0])], t0=t0,
                   t_first=time.perf_counter(),
                   temperature=temperature, top_k=top_k, tenant=tenant)
        st.deadline_t = deadline_t
        if resume is not None:
            self._mark_resumed(st, resume)
            rec = m - depth
            st.tokens_recomputed += rec
            self.stats["preempted_tokens_recomputed"] += rec
        if (st.stop_at_eos and st.emitted[0] == EOS) or max_new == 1:
            # finished at its first token: the prompt prefix stays warm in
            # L1, but the row is never occupied
            result = self._result(st, stage=stage, cap=cap)
            for b in table_blocks:
                self.allocator.unref(b)
            return result

        row = np.full((self.nbt,), SENTINEL, np.int32)
        row[:len(table_blocks)] = table_blocks
        self._tables[slot] = row
        self._row_blocks[slot] = list(table_blocks)
        self._committed[slot] = need_later
        self._temp[slot] = temperature
        self._topk[slot] = top_k
        self.pool, self._tokens, self._pos = self._setrow_fn(
            self.pool, self._tokens, self._pos, slot, jnp.asarray(row),
            tok0, jnp.int32(m))
        self._slots[slot] = st
        return None

    # ------------------------------------------------------------------
    # chunked admission (the paged-native default)
    # ------------------------------------------------------------------
    def _admit_chunked(self, slot: int, prompt: str, ids, m: int,
                      max_new: int, use_recycling: bool, admit: bool,
                      stop_at_eos: bool, temperature: float, top_k: int,
                      t0: float, tenant: Optional[str] = None,
                      deadline_t: Optional[float] = None,
                      resume: Optional[dict] = None) -> None:
        """Queue ``prompt`` as a pending chunked admission on row
        ``slot``.  Only the admission *guarantee* runs here (can the pool
        ever provide this request's blocks without starving in-flight
        reservations? — conservatively assuming zero reuse, since the
        tier lookup is deferred to the first chunk step); all device work
        happens chunk-by-chunk inside ``decode_batch``.

        ``overcommit=True`` weakens the guarantee to the PROMPT's blocks
        only: the request's decode-time growth is not reserved up front,
        and when the pool later cannot cover a write the preemption
        machinery demotes a victim instead — how an undersized pool
        oversubscribes rather than rejecting.  Saturation that in-flight
        work will relieve raises ``PoolSaturated`` (scheduler keeps the
        request queued); ``ValueError`` remains the permanent reject."""
        nb_total = _ceil_div(m + max_new, self.block)
        need = _ceil_div(m, self.block) if self.overcommit else nb_total
        owed = 0 if self.overcommit else sum(self._committed)
        avail = self.allocator.num_free() + self._evictable()
        if avail < need + owed:
            msg = (
                f"paged pool exhausted: request needs up to {need} "
                f"blocks, {avail - owed} obtainable "
                f"(free={self.allocator.num_free()}, "
                f"in-flight reservations={owed})")
            if self.active_slots() or self._pending:
                raise PoolSaturated(msg)
            raise ValueError(msg)
        self._committed[slot] = nb_total
        self._tables[slot] = SENTINEL
        self._row_blocks[slot] = []
        st = _Slot(prompt, ids, m, max_new, use_recycling, admit,
                   stop_at_eos, 0, False, "baseline", 0.0, emitted=[],
                   t0=t0, temperature=temperature, top_k=top_k,
                   tenant=tenant)
        st.deadline_t = deadline_t
        if resume is not None:
            self._mark_resumed(st, resume)
        self._pending[slot] = _PendingAdmission(st=st)
        return None

    def _begin_admission(self, slot: int, adm: _PendingAdmission) -> None:
        """First chunk step of a pending admission: tier lookup, shared-
        prefix composition (refcount++, zero copies), host promotion
        (block-granular direct upload — no staging cache), and fp ring
        seeding for int8 pools.  Running this lazily — at the first chunk,
        not at admit time — lets the lookup see blocks that admissions
        queued in the same scheduler step have already sealed."""
        st = adm.st
        bs = self.block
        ids, m = st.ids, st.m
        depth, hit, mode, sim = 0, False, "baseline", 0.0
        chain: List[Tuple[int, int]] = []
        res, host_cache = None, None
        if st.use_recycling:
            depth, hit, mode, sim, chain, res, host_cache = \
                self._lookup_tiers(st.prompt, ids, m)
        st.depth, st.hit, st.mode, st.sim = depth, hit, mode, sim
        aligned = (depth // bs) * bs

        # NB: only the HOST table mirror is updated during admission —
        # the device table row stays all-sentinel until the final chunk
        # installs it, so the batched decode (which writes through every
        # row's device table) can never scribble into a half-admitted
        # row's blocks at a stale position.  Chunk steps receive the host
        # mirror as an explicit operand instead.
        if mode == "resident_block":
            shared = [b for b, _ in chain[:aligned // bs]]
            for i, b in enumerate(shared):
                self.allocator.ref(b)
                self._tables[slot][i] = b
            self._row_blocks[slot] = list(shared)
            self._committed[slot] -= len(shared)
            if depth % bs:
                # divergent partial boundary block: the first chunk
                # REWRITES [aligned, depth) into a private block from the
                # prompt ids — the shared original is never gathered,
                # never mutated, and costs no staging pass (CoW by
                # recomputation)
                self.stats["cow_copies"] += 1
        elif hit and depth:
            # L2 promotion without the staging round-trip: the entry's
            # [0, depth) moves block-by-block into fresh private blocks —
            # sealed int8 blocks verbatim, everything else (fp entries,
            # residual tails, converted legacy layouts, the sub-block
            # remainder of the boundary block) through the fp upload that
            # quantizes exactly once.  The chunks then write only
            # [depth, m) (``w_floor``): uploaded positions keep the
            # staged-identical entry values instead of a recomputation.
            nb_up = _ceil_div(depth, bs)
            up = 0
            if self.kv_quant and res is not None and res.entry is not None:
                up = min(self._q8_blocks(res.entry.cache, depth), nb_up)
            try:
                fresh = self.allocator.alloc_many(nb_up)
            except BlockPoolExhausted:
                # free list alone can't cover the batch — fall back to
                # the per-block path, which evicts cold L1 chains and,
                # under pressure, preempts a victim; a partial grab is
                # rolled back before the failure propagates (the caller
                # then cancels this admission cleanly)
                fresh = []
                try:
                    for _ in range(nb_up):
                        fresh.append(self._alloc_pressure(
                            exclude_pending=(slot,)))
                except (BlockPoolExhausted, InjectedFault):
                    for b in fresh:
                        self.allocator.unref(b)
                    raise
            for j, b in enumerate(fresh):
                self._tables[slot][j] = b
            self._row_blocks[slot] = list(fresh)
            self._committed[slot] -= len(fresh)
            self.stats["h2d_copies"] += 1
            moved = 0
            for j in range(up):
                ent = self._q8_block(res.entry.cache, j)
                moved += sum(int(a.nbytes)
                             for s in ent.values() for a in s.values())
                self.pool = self._upload_q8_blk_fn(self.pool, ent,
                                                   jnp.int32(fresh[j]))
            self.stats["q8_block_promotions"] += up
            for j in range(up, nb_up):
                blk = self._host_block(host_cache, j)
                moved += sum(int(a.nbytes)
                             for s in blk.values() for a in s.values())
                self.pool = self._upload_blk_fn(self.pool, blk,
                                                jnp.int32(fresh[j]))
            self.stats["h2d_bytes"] += moved
            adm.w_floor = depth

        # int8 pools: the first chunk's queries read their last R blocks
        # of history from the row's fp ring tail; seed it like the staged
        # path's _fill_tail would have — exact fp from the entry's
        # residual on a host promotion (covering the uploaded partial
        # boundary block), dequantized pool content on a resident hit
        if self.kv_quant and mode == "resident_block" and aligned:
            self.pool = self._seedtail_fn(
                self.pool, jnp.int32(slot),
                jnp.asarray(self._tables[slot]), jnp.int32(aligned))
        elif self.kv_quant and hit and depth:
            self.pool = self._settail_fn(
                self.pool, jnp.int32(slot),
                self._host_ring_window(host_cache, depth))

        # semantic block-donor grafting: only on a prefix MISS (both
        # tiers), so the exact/partial paths are byte-identical to
        # semantic=False engines
        if self.semantic and st.use_recycling and not hit:
            plan = self.recycler.lookup_semantic(st.prompt, ids)
            if plan is not None:
                self._install_graft(slot, adm, plan)

        adm.next_c0 = aligned
        adm.started = True

    # ------------------------------------------------------------------
    # semantic block-donor grafting
    # ------------------------------------------------------------------
    def _install_graft(self, slot: int, adm: _PendingAdmission,
                       plan: GraftPlan) -> None:
        """Wire a nominated donor graft into the pending admission: put
        the donor's interior blocks [interior_lo, interior_hi) into the
        row's table (refcount++ when the donor chain is device-resident,
        block-granular fp promotion from the host entry otherwise) and
        split the prompt into recompute segments around them.  The graft
        is PROVISIONAL until ``_graft_gate`` accepts it after segment 1's
        boundary recompute."""
        st = adm.st
        bs = self.block
        e = plan.entry
        lo, hi = plan.interior_lo, plan.interior_hi
        # donor fp view: uploads on the host path + the gate's reference
        host = e.cache
        if kvq.is_quantized(host):
            host = kvq.dequantize_tree(host)
        if not self._host_layout_ok(host):
            host = self._convert_dense_quant(host)
            self.stats["layout_conversions"] += 1
        # resident fast path: the donor's own chain still holds the
        # interior on device — share it in place, zero copies.  peek, not
        # lookup: sizing up a donor must not stamp recency
        d_res, chain = self.trie.peek(e.token_ids[:e.length])
        if d_res >= plan.graft_end:
            blocks = [b for b, _ in chain[lo:hi]]
            for b in blocks:
                self.allocator.ref(b)
            self.stats["semantic_resident_grafts"] += 1
        else:
            blocks = []
            moved = 0
            try:
                for j in range(lo, hi):
                    b = self._alloc_block()
                    blk = self._host_block(host, j)
                    moved += sum(int(np.asarray(a).nbytes)
                                 for s in blk.values() for a in s.values())
                    self.pool = self._upload_blk_fn(self.pool, blk,
                                                    jnp.int32(b))
                    blocks.append(b)
            except (BlockPoolExhausted, InjectedFault):
                # a graft is opportunistic: under pressure, skip it and
                # recompute contiguously (token-identical to
                # semantic=False) instead of stealing blocks from
                # in-flight rows
                for b in blocks:
                    self.allocator.unref(b)
                return
            self.stats["h2d_copies"] += 1
            self.stats["h2d_bytes"] += moved
            self.stats["semantic_host_grafts"] += 1
        for k, b in enumerate(blocks):
            self._tables[slot][lo + k] = b
        self._row_blocks[slot] = [int(x) for x in self._tables[slot]
                                  if x != SENTINEL]
        self._committed[slot] -= len(blocks)
        # the gate's reference: the donor's OWN boundary blocks [b0, lo)
        # in fp — what the recompute would reproduce if the differing
        # head changed nothing
        ref = {}
        for seg, c in host.items():
            ref[seg] = {n: np.asarray(c[n][:, 0, plan.b0 * bs:lo * bs])
                        for n in ("k", "v")}
        adm.graft = plan
        adm.graft_ref = ref
        adm.segs = [(0, plan.seg1_end), (plan.graft_end, st.m)]
        adm.seg_i = 0
        adm.gate_at = plan.seg1_end
        adm.reg_cap = plan.seg1_end

    def _graft_gate(self, slot: int, adm: _PendingAdmission) -> None:
        """Fidelity gate, run when segment 1's chunks reach the graft.

        Measures how far the recomputed boundary block(s) [b0,
        interior_lo) — token-identical to the donor's but recomputed
        under the new prompt's real head — diverge from the donor's own
        K/V at those blocks (mean relative Frobenius error).  Boundary
        logits cannot see the un-attended interior under causal masking,
        so the boundary K/V delta is the observable proxy for how much
        the context difference would have perturbed the grafted region.
        Divergence <= ``graft_max_div`` accepts the graft (skip to the
        post-graft segment); otherwise every interior block is
        dereferenced and the admission falls back to a full contiguous
        recompute — token-identical to semantic=False."""
        plan = adm.graft
        st = adm.st
        bs = self.block
        lo, hi = plan.interior_lo, plan.interior_hi
        bids = [int(self._tables[slot][j]) for j in range(plan.b0, lo)]
        n = len(bids) * bs
        got = to_host(self._stage_fn(self.pool,
                                     jnp.asarray(bids, jnp.int32), n, n))
        rels = []
        for seg, ref in adm.graft_ref.items():
            for name in ("k", "v"):
                a = np.asarray(got[seg][name][:, 0, :n], np.float32)
                b = np.asarray(ref[name], np.float32)
                rels.append(np.linalg.norm(a - b)
                            / (np.linalg.norm(b) + 1e-9))
        div = float(np.mean(rels))
        self.semantic_gate_divs.append(div)
        if div <= self.graft_max_div:
            st.depth = plan.interior_tokens
            st.hit = True
            st.mode = "semantic_block"
            st.sim = plan.similarity
            self.stats["semantic_grafts"] += 1
            self.stats["tokens_grafted"] += plan.interior_tokens
            adm.seg_i = 1
            adm.next_c0 = plan.graft_end
            if self.kv_quant:
                # the post-graft chunk reads its last R history blocks
                # (the grafted interior) through the row's fp ring tail —
                # reseed it at the graft boundary like a resident hit
                self.pool = self._seedtail_fn(
                    self.pool, jnp.int32(slot),
                    jnp.asarray(self._tables[slot]),
                    jnp.int32(adm.next_c0))
        else:
            for j in range(lo, hi):
                b = int(self._tables[slot][j])
                self._tables[slot][j] = SENTINEL
                self.allocator.unref(b)
            self._row_blocks[slot] = [int(x) for x in self._tables[slot]
                                      if x != SENTINEL]
            self._committed[slot] += hi - lo
            self.stats["semantic_refusals"] += 1
            # a refused graft leaves NOTHING approximate in the row:
            # every remaining position recomputes contiguously, so the
            # registration cap is lifted and the prompt indexes like any
            # cold admission
            adm.graft = None
            adm.graft_ref = None
            adm.segs = None
            adm.seg_i = 0
            adm.gate_at = -1
            adm.reg_cap = 1 << 30

    def _admission_chunk(self, slot: int) -> None:
        """Advance one pending admission by ONE chunk: allocate the
        chunk's blocks (batched table update), run the single compiled
        chunk-prefill executable, extend the L1 registration frontier,
        and finish the admission when the chunk reaches the prompt end."""
        adm = self._pending[slot]
        st = adm.st
        bs = self.block
        if not adm.started:
            self._begin_admission(slot, adm)
        c0 = adm.next_c0
        # a grafted admission chunks per SEGMENT: [0, seg1_end) first,
        # then — if the gate accepts — [graft_end, m), skipping the
        # grafted interior entirely
        seg_end = adm.segs[adm.seg_i][1] if adm.segs else st.m
        remaining = seg_end - c0
        C = next((s for s in self.chunk_shapes if s >= remaining),
                 self.prefill_chunk)
        n_valid = min(C, remaining)
        for idx in range(c0 // bs, (c0 + n_valid - 1) // bs + 1):
            if self._tables[slot][idx] == SENTINEL:
                b = self._alloc_pressure(exclude_pending=(slot,))
                self._tables[slot][idx] = b
                self._committed[slot] -= 1
        # rebuild rather than append: a graft installs interior blocks at
        # HIGHER table indices than the segment being chunked, and the
        # row_blocks invariant is table order
        self._row_blocks[slot] = [int(x) for x in self._tables[slot]
                                  if x != SENTINEL]
        toks = np.zeros((1, C), np.int32)
        toks[0, :n_valid] = st.ids[c0:c0 + n_valid]
        logits, self.pool = self._chunk_fn(
            self.params, jnp.asarray(toks), self.pool, jnp.int32(slot),
            jnp.asarray(self._tables[slot]), jnp.int32(c0),
            jnp.int32(adm.w_floor), jnp.int32(n_valid))
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_dispatches"] += 1
        # progressive L1 registration: blocks this chunk sealed become
        # shareable immediately — a neighbor admitted this same step can
        # compose its table from them at ITS first chunk.  ``reg_cap``
        # stops the frontier at the graft: grafted interior and
        # post-graft blocks are approximate under THIS prompt's keys and
        # must never serve an exact-prefix lookup
        reg_len = min(c0 + n_valid, adm.reg_cap)
        if reg_len > 0:
            for b in self.trie.register(st.ids, reg_len,
                                        self._row_blocks[slot]):
                self.allocator.ref(b)
        adm.next_c0 = c0 + n_valid
        if adm.segs and adm.seg_i == 0 and adm.next_c0 >= adm.gate_at:
            # segment 1 reached the graft: run the fidelity gate.  On
            # accept it advances next_c0 past the interior; on refusal it
            # clears the segments and the next chunk continues
            # contiguously from here — either way the admission resumes
            # at the next engine step
            self._graft_gate(slot, adm)
            return
        if adm.next_c0 >= st.m:
            self._finish_admission(slot, logits)

    def _admission_step_packed(self) -> None:
        """Advance EVERY pending admission by one chunk in ONE ragged
        packed dispatch — the ``prefill_mode="packed"`` replacement for
        the per-admission ``_admission_chunk`` loop.  Host-side per-
        segment work (tier lookup on first step, chunk sizing, block
        allocation, table-mirror updates) runs exactly as the chunked
        path does it; then all segments' tokens are packed back to back
        into one bucket-shaped buffer with per-segment descriptors
        (``pack_admission_segments``) and ``_packed_fn`` runs the whole
        step's prefill in a single executable.  Per-segment bookkeeping
        (progressive L1 registration, ``next_c0`` advance, admission
        finish off the segment's own last-valid logits) follows.

        Equivalence to the chunked path is exact: each segment's queries
        attend only its own history + chunk (segment-masked kernel), and
        distinct segments write disjoint pool blocks, so tokens are
        identical — only the dispatch count changes (1 per step vs 1 per
        pending admission)."""
        slots = sorted(self._pending)
        if not slots:
            return
        bs = self.block
        segs = []
        metas = []
        self._pending_planned = set()
        for slot in slots:
            if slot not in self._pending:
                continue          # cancelled by a neighbor's pressure
            adm = self._pending[slot]
            st = adm.st
            # planned slots' segment descriptors reference their blocks
            # until the packed dispatch lands — from here on this slot
            # must not be a preemption victim
            self._pending_planned.add(slot)
            try:
                if not adm.started:
                    self._begin_admission(slot, adm)
                c0 = adm.next_c0
                remaining = st.m - c0
                C = next((s for s in self.chunk_shapes if s >= remaining),
                         self.prefill_chunk)
                n_valid = min(C, remaining)
                for idx in range(c0 // bs, (c0 + n_valid - 1) // bs + 1):
                    if self._tables[slot][idx] == SENTINEL:
                        b = self._alloc_pressure()
                        self._tables[slot][idx] = b
                        self._committed[slot] -= 1
            except (BlockPoolExhausted, InjectedFault):
                # contained: roll this admission back to a clean state
                # and requeue it; the other admissions' plans are intact
                self._pending_planned.discard(slot)
                if slot in self._pending:
                    stc = self._cancel_admission(slot)
                    payload = self._resume_payload(stc, [])
                    payload["slot"] = slot
                    self._events.append(("preempted", payload))
                    self.stats["preemptions"] += 1
                    self.stats["step_rollbacks"] += 1
                    self._preempted_now.add(slot)
                continue
            self._row_blocks[slot] = [int(x) for x in self._tables[slot]
                                      if x != SENTINEL]
            segs.append((slot, self._tables[slot].copy(), c0, adm.w_floor,
                         n_valid, C, st.ids[c0:c0 + n_valid]))
            metas.append((slot, adm, c0, n_valid))
        if not segs:
            self._pending_planned = set()
            return
        pk = pack_admission_segments(
            segs, block_size=bs, buckets=self.packed_buckets,
            max_segments=self.max_batch, table_width=self.nbt)
        logits, self.pool = self._packed_fn(
            self.params, jnp.asarray(pk["tokens"]), self.pool,
            jnp.asarray(pk["rows"]), jnp.asarray(pk["tables"]),
            jnp.asarray(pk["c0s"]), jnp.asarray(pk["w_floors"]),
            jnp.asarray(pk["valids"]), jnp.asarray(pk["q_offs"]),
            jnp.asarray(pk["seg_ids"]))
        self.stats["prefill_chunks"] += len(segs)
        self.stats["prefill_packed_steps"] += 1
        self.stats["prefill_dispatches"] += 1
        for i, (slot, adm, c0, n_valid) in enumerate(metas):
            st = adm.st
            reg_len = min(c0 + n_valid, adm.reg_cap)
            if reg_len > 0:
                for b in self.trie.register(st.ids, reg_len,
                                            self._row_blocks[slot]):
                    self.allocator.ref(b)
            adm.next_c0 = c0 + n_valid
            if adm.next_c0 >= st.m:
                self._finish_admission(slot, logits[i:i + 1])
        self._pending_planned = set()

    def _finish_admission(self, slot: int, logits) -> None:
        """Final chunk done: sample the first token, install the row's
        DEVICE block table (the first moment decode may write through it),
        arm the row for the batched decode loop, and account the
        admission."""
        adm = self._pending.pop(slot)
        st = adm.st
        if st.temperature > 0.0:
            self._step_rng, sub = jax.random.split(self._step_rng)
            tok0 = sample_logits(logits, sub, temperature=st.temperature,
                                 top_k=st.top_k)
        else:
            tok0 = engine_mod.greedy(logits)
        st.emitted = [int(tok0[0])]
        st.t_first = st.t_first or time.perf_counter()
        self.stats["requests"] += 0 if st.resume_emitted else 1
        if st.resume_emitted:
            rec = st.m - st.depth
            st.tokens_recomputed += rec
            self.stats["preempted_tokens_recomputed"] += rec
        self.stats["hits"] += int(st.hit)
        self.stats["tokens_reused"] += st.depth
        self.stats["tokens_prefilled"] += st.m - st.depth
        self.stats["admissions"] += 1
        self._temp[slot] = st.temperature
        self._topk[slot] = st.top_k
        self.pool, self._tokens, self._pos = self._setrow_fn(
            self.pool, self._tokens, self._pos, slot,
            jnp.asarray(self._tables[slot]), tok0, jnp.int32(st.m))
        self._slots[slot] = st

    # ------------------------------------------------------------------
    def _apply_table_updates(self,
                             updates: List[Tuple[int, int, int]]) -> None:
        """Apply (row, entry, block) table updates in ONE fixed-width
        dispatch; padding entries point past the table and are dropped."""
        W = self.nbt + 2 * self.max_batch
        assert len(updates) <= W, (len(updates), W)
        rows = np.zeros((W,), np.int32)
        idxs = np.full((W,), self.nbt, np.int32)
        blks = np.zeros((W,), np.int32)
        for j, (r, i, b) in enumerate(updates):
            rows[j], idxs[j], blks[j] = r, i, b
        self.pool = self._setents_batch_fn(
            self.pool, jnp.asarray(rows), jnp.asarray(idxs),
            jnp.asarray(blks))

    def _host_block(self, cache, j: int):
        """Block ``j`` of a promotable host entry (staging layout), as the
        per-block fp upload payload {seg: {k, v: (L, bs, H, D)}} —
        zero-padded when the entry's capacity axis ends mid-block (the
        pad positions sit beyond the promoted depth and are never
        attended)."""
        bs = self.block
        dt = jnp.dtype(self.cfg.dtype)
        out = {}
        for seg, c in cache.items():
            sub = {}
            for name in ("k", "v"):
                a = np.asarray(c[name][:, 0, j * bs:(j + 1) * bs])
                if a.shape[1] < bs:
                    pad = [(0, 0)] * a.ndim
                    pad[1] = (0, bs - a.shape[1])
                    a = np.pad(a, pad)
                sub[name] = jnp.asarray(a.astype(dt))
            out[seg] = sub
        return out

    def _q8_block(self, raw, j: int):
        """Block ``j`` of a quantized host entry's sealed int8 region, in
        the verbatim per-block upload layout (codes + scales, keepdim
        dropped).  Pure slicing — no arithmetic touches the stored bits."""
        bs = self.block
        ent = {}
        for seg, c in raw.items():
            sub = {}
            for name in ("k", "v"):
                leaf = c[name]
                ax = int(np.asarray(leaf["ax"]))
                sl = [slice(None)] * leaf[kvq._QKEY].ndim
                sl[ax] = slice(j * bs, (j + 1) * bs)
                sub[name] = jnp.asarray(leaf[kvq._QKEY][tuple(sl)][:, 0])
                sub[name + "_scale"] = jnp.asarray(
                    np.asarray(leaf["scale"])[tuple(sl)][:, 0, ..., 0])
            ent[seg] = sub
        return ent

    def _host_ring_window(self, cache, depth: int):
        """fp ring-tail payload for a host promotion: ring slot r holds
        the entry's (exact, residual-covered) values of the unique block
        ti in the last-R-blocks window of the promoted region [0, depth)
        with ti % R == r — computed host-side so only R blocks cross to
        the device.  Positions >= depth are zeroed; the chunk's own
        dual-writes fill them as the fresh suffix seals."""
        bs = self.block
        R = self.fp_tail_blocks
        lb = (depth - 1) // bs                 # last promoted block
        dt = jnp.dtype(self.cfg.dtype)
        r = np.arange(R)
        ti = lb - ((lb - r) % R)
        posm = ti[:, None] * bs + np.arange(bs)[None]          # (R, bs)
        out = {}
        for seg, c in cache.items():
            cap = np.asarray(c["k"]).shape[2]
            valid = ((posm >= 0) & (posm < min(depth, cap))).reshape(-1)
            idx = np.clip(posm, 0, cap - 1).reshape(-1)
            sub = {}
            for name in ("k", "v"):
                a = np.asarray(c[name])[:, 0, idx].astype(np.float32)
                a = a * valid[None, :, None, None]
                sub[name] = jnp.asarray(a.astype(dt))
            out[seg] = sub
        return out

    # ------------------------------------------------------------------
    def decode_batch(self) -> List[Tuple[int, GenResult]]:
        """One engine step: advance every pending chunked admission by ONE
        chunk, then one masked decode step over the paged pool (single
        dispatch) for the armed rows — a long admission never stalls the
        in-flight batch, it shares the step cadence with it.

        Before decoding, rows whose next write position crosses into an
        unallocated table entry get a fresh private block (on demand —
        device bytes track actual lengths, not capacity), and rows within
        ``prealloc_watermark`` positions of their block boundary have the
        NEXT block speculatively reserved, so table updates arrive in one
        batched dispatch instead of firing per row per boundary."""
        if self.fault_plan is not None:
            self.fault_plan.maybe_fire("replica_step", "injected: step fault")
        self._preempted_now = set()
        if self.prefill_mode == "packed":
            # ALL pending admissions advance in ONE ragged packed dispatch
            self._admission_step_packed()
        else:
            for slot in sorted(self._pending):
                if slot not in self._pending:
                    continue      # cancelled by a neighbor's pressure
                try:
                    self._admission_chunk(slot)
                except (BlockPoolExhausted, InjectedFault):
                    # contained: roll this admission back and requeue it
                    if slot in self._pending:
                        stc = self._cancel_admission(slot)
                        payload = self._resume_payload(stc, [])
                        payload["slot"] = slot
                        self._events.append(("preempted", payload))
                        self.stats["preemptions"] += 1
                        self.stats["step_rollbacks"] += 1
                        self._preempted_now.add(slot)
        done: List[Tuple[int, GenResult]] = []
        for i in self.active_slots():
            st = self._slots[i]
            # a row whose admission just completed may already be done
            # (EOS at its first token, or a 1-token budget)
            if len(st.emitted) == 1 and (
                    (st.stop_at_eos and st.emitted[0] == EOS)
                    or st.max_new == 1):
                done.append((i, self._result(st, row=i)))
                self._release_row(i)
        active = self.active_slots()
        if not active:
            return done
        spec_faultable = (self.fault_plan is not None
                          and "alloc" in self.fault_plan.sites)
        if self.speculative and not spec_faultable:
            if self._spec_ready(active):
                done.extend(self._spec_round(active))
                return done
            # sampled rows in the batch, bundle past capacity, or blocks
            # unobtainable: fall back to the plain step for this round
            self.stats["spec_fallback_steps"] += 1
        bs = self.block
        updates: List[Tuple[int, int, int]] = []
        for i in active:
            if i in self._preempted_now:
                continue          # victim of an earlier row's pressure
            st = self._slots[i]
            p = st.m + len(st.emitted) - 1   # position this step writes
            idx = p // bs
            if self._tables[i, idx] == SENTINEL:
                try:
                    b = self._alloc_pressure(exclude_rows=(i,))
                except (BlockPoolExhausted, InjectedFault):
                    # no victim left but this row itself: it yields its
                    # slot and comes back through the resume path
                    self._self_preempt(i)
                    continue
                self._tables[i, idx] = b
                self._row_blocks[i].append(b)
                self._committed[i] -= 1
                updates.append((i, idx, b))
            if (self.prealloc_watermark and idx + 1 < self.nbt
                    and p % bs >= bs - self.prealloc_watermark
                    and (idx + 1) * bs < st.m + st.max_new
                    and self._tables[i, idx + 1] == SENTINEL):
                try:
                    b = self._alloc_block()
                except (BlockPoolExhausted, InjectedFault):
                    # the watermark reservation is an optimisation, not a
                    # requirement — under pressure it just doesn't happen
                    continue
                self._tables[i, idx + 1] = b
                self._row_blocks[i].append(b)
                self._committed[i] -= 1
                updates.append((i, idx + 1, b))
                self.stats["spec_preallocs"] += 1
        if self._preempted_now:
            updates = [(r, i, b) for (r, i, b) in updates
                       if r not in self._preempted_now]
            active = [i for i in active if i not in self._preempted_now]
        if updates:
            self._apply_table_updates(updates)
        if not active:
            return done

        t_step = time.perf_counter()
        if np.any(self._temp > 0.0):
            self._step_rng, sub = jax.random.split(self._step_rng)
            self.stats["sampled_steps"] += 1
            nxt, self._tokens, self.pool, self._pos = self._pstep_sampled_fn(
                self.params, self._tokens, self.pool, self._pos,
                jnp.asarray(self._temp), jnp.asarray(self._topk), sub,
                max(int(self._topk.max()), 1))
        else:
            nxt, self._tokens, self.pool, self._pos = self._pstep_fn(
                self.params, self._tokens, self.pool, self._pos)
        toks = np.asarray(nxt)
        dt_step = time.perf_counter() - t_step
        self.stats["batched_decode_steps"] += 1
        for i in active:
            st = self._slots[i]
            st.emitted.append(int(toks[i]))
            st.step_times_s.append(dt_step)
            if ((st.stop_at_eos and st.emitted[-1] == EOS)
                    or len(st.emitted) >= st.max_new):
                done.append((i, self._result(st, row=i)))
                self._release_row(i)
        return done

    # ------------------------------------------------------------------
    def _release_row(self, row: int) -> None:
        """Free the row: drop its table references (prefix blocks indexed
        in L1 survive as the device cache tier; generation-only blocks
        fall to refcount 0 and return to the free list)."""
        for b in self._row_blocks[row]:
            self.allocator.unref(b)
        self._row_blocks[row] = []
        self._committed[row] = 0
        self._tables[row] = SENTINEL
        self._temp[row] = 0.0
        self._topk[row] = 0
        self.pool = self._clear_fn(self.pool, row)
        self._slots[row] = None

    # ------------------------------------------------------------------
    def _result(self, st: _Slot, *, row: Optional[int] = None, stage=None,
                cap: Optional[int] = None) -> GenResult:
        # contamination rule: a grafted row's K/V is approximate for THIS
        # prompt's tokens (interior from a different context, suffix
        # computed attending it) — admitting it to the host store would
        # let future exact-prefix lookups serve approximate caches
        if st.admit and st.mode != "semantic_block":
            cap = cap or self._capacity(st.m + st.max_new)
            if stage is None:
                # harvest from the pool: gather the row's prompt blocks
                # back into the host-store layout, valid [0, m) — int8
                # pools keep their codes verbatim (_harvest)
                ids = [b for b in self._tables[row]
                       if b != SENTINEL][:_ceil_div(st.m, self.block)]
                host = self._harvest(jnp.asarray(ids, jnp.int32),
                                     st.m, cap)
            else:
                # instant finish: the staging cache already holds exactly
                # [0, m) — generated positions were never written into it
                host = to_host(stage)
            self.recycler.admit(st.prompt, st.ids, host, st.m, cap,
                                tenant=st.tenant)
        # a resumed slot's "prompt" includes the tokens emitted before the
        # preemption; stitch them back so the result describes the whole
        # request, not just its last residency
        gen = st.resume_emitted + st.emitted
        all_ids = np.concatenate([st.ids, np.asarray(st.emitted, np.int32)])
        return GenResult(
            text=self.tok.decode(gen),
            token_ids=all_ids,
            latency_s=time.perf_counter() - st.t0,
            prompt_tokens=st.m - len(st.resume_emitted),
            gen_tokens=len(gen),
            reuse_depth=st.depth,
            cache_hit=st.hit,
            mode=st.mode if st.use_recycling else "baseline",
            prompt_similarity=st.sim,
            ttft_s=max(st.t_first - st.t0, 0.0),
            step_times_s=list(st.step_times_s),
            preemptions=st.preemptions,
            tokens_recomputed=st.tokens_recomputed,
        )

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Paged-pool global invariants (fuzzed in tests):
          * allocator free/live accounting is consistent
          * every block's refcount equals (#tables naming it) + (1 if the
            L1 trie indexes it) — so a block in two tables is provably
            shared, and no freed block is reachable
          * table entries beyond a row's blocks are sentinel
          * an armed (decoding) row's table names a CONTIGUOUS prefix
            whose frontier never runs past the row's write position by
            more than the one-block watermark prealloc — after a
            speculative round this is exactly the post-rollback bound
            (the accept frontier's block, plus at most the prealloc)"""
        self.allocator.check()
        expected: Dict[int, int] = {}
        for i in range(self.max_batch):
            for b in self._row_blocks[i]:
                expected[b] = expected.get(b, 0) + 1
            named = [b for b in self._tables[i] if b != SENTINEL]
            assert named == self._row_blocks[i], \
                (i, named, self._row_blocks[i])
        for b in self.trie.blocks():
            expected[b] = expected.get(b, 0) + 1
        for b in range(1, self.allocator.num_blocks):
            assert self.allocator.refcount(b) == expected.get(b, 0), \
                (b, self.allocator.refcount(b), expected.get(b, 0))
        for i in range(self.max_batch):
            st = self._slots[i]
            if st is None:
                continue    # pending admissions may hold grafted
                            # interior blocks at non-contiguous indices
            named_idx = [j for j in range(self.nbt)
                         if self._tables[i, j] != SENTINEL]
            assert named_idx == list(range(len(named_idx))), \
                (i, named_idx)
            if named_idx:
                p = st.m + len(st.emitted) - 1
                assert named_idx[-1] <= p // self.block + 1, \
                    (i, named_idx[-1], p, self.block)
