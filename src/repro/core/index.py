"""Nearest-neighbour retrieval over cached-prompt embeddings.

Paper §2.5: ``i* = argmax_i <e_i, e_t>`` over L2-normalized embeddings
(dot product == cosine).  The paper uses faiss-cpu; at our scale a blocked
numpy matmul is exact and dependency-free, and supports incremental add /
remove (needed by cache eviction).

Invariants (property-tested in tests/test_property.py):

  * one row per entry_id — ``add`` of an existing id REPLACES its vector
    (it used to append a duplicate row: ``remove`` then deleted only the
    first and ``similarity`` read the first, so stale vectors served
    retrieval forever);
  * ``_row[id]`` is the exact row of ``_vecs`` holding id's vector (an
    id→row map instead of the old O(n) ``list.index`` scan on every
    remove/similarity);
  * ``len(index) == len(_row) == _vecs.shape[0]``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


class EmbeddingIndex:
    def __init__(self, dim: int):
        self.dim = dim
        self._vecs = np.zeros((0, dim), np.float32)
        self._ids: List[int] = []
        self._row: Dict[int, int] = {}

    def __len__(self) -> int:
        return len(self._ids)

    def __contains__(self, entry_id: int) -> bool:
        return entry_id in self._row

    def ids(self) -> List[int]:
        return list(self._ids)

    def add(self, entry_id: int, vec: np.ndarray) -> None:
        """Index (or re-index) ``entry_id``.  A duplicate id replaces the
        existing row in place — the index never holds stale vectors."""
        assert vec.shape == (self.dim,)
        i = self._row.get(entry_id)
        if i is not None:
            self._vecs[i] = vec.astype(np.float32)
            return
        self._row[entry_id] = len(self._ids)
        self._ids.append(entry_id)
        self._vecs = np.concatenate(
            [self._vecs, vec.astype(np.float32)[None]], axis=0)

    def remove(self, entry_id: int) -> None:
        i = self._row.pop(entry_id, None)
        if i is None:
            return
        last = len(self._ids) - 1
        if i != last:
            # swap-with-last: O(1) array surgery instead of an O(n) delete
            self._vecs[i] = self._vecs[last]
            self._ids[i] = self._ids[last]
            self._row[self._ids[i]] = i
        self._vecs = self._vecs[:last]
        del self._ids[last]

    def search(self, vec: np.ndarray, k: int = 1
               ) -> List[Tuple[int, float]]:
        """Top-k (entry_id, similarity), best first."""
        if not self._ids:
            return []
        sims = self._vecs @ vec.astype(np.float32)
        k = min(k, len(self._ids))
        top = np.argpartition(-sims, k - 1)[:k]
        top = top[np.argsort(-sims[top])]
        return [(self._ids[i], float(sims[i])) for i in top]

    def similarity(self, entry_id: int, vec: np.ndarray) -> float:
        """Cosine similarity of the query against ONE entry's embedding
        (nan when the entry is not indexed).  Lets callers report the
        similarity of the entry actually serving a hit, rather than the
        best similarity seen during retrieval."""
        i = self._row.get(entry_id)
        if i is None:
            return float("nan")
        return float(self._vecs[i] @ vec.astype(np.float32))
