"""Request scheduling: serial FIFO baseline + continuous batching.

Two schedulers share one Request surface:

``FIFOScheduler`` is the honest serial baseline — ``step()`` pops requests
off the queue and runs ``engine.generate`` one at a time.  It exists as the
reference the batched path is benchmarked (and tested token-for-token)
against.

``ContinuousBatchingScheduler`` drives a pool engine — the dense
``BatchedEngine`` slot pool or the paged ``PagedEngine`` block-table pool,
both behind the same ``free_slots``/``admit_slot``/``decode_batch``
surface: it owns the admission policy (FIFO order, admit-before-decode),
the slot allocator (free list over pool rows), and the in-flight set.  Every
``step()`` first fills free slots from the queue head, then advances ALL
in-flight requests with one ``decode_batch`` call.  What an admission costs
inside that call is the ENGINE's choice: the dense pool (and the paged pool
in ``prefill_mode="staged"``) runs the whole single-row prefill inside
``admit_slot``; the paged pool's chunked default instead queues the
admission and ``decode_batch`` advances it ONE fixed-size chunk per step,
interleaved with the batched decode dispatch — a long prompt admits over
several steps while the resident batch keeps emitting tokens, and its slot
counts as in-flight the whole time (``admit_slot`` returned None).  Rows
that hit EOS or their token budget are freed at the step boundary and the
next ``step()`` refills them mid-flight: the batch never drains to refill,
which is what "continuous" means and where the throughput over the serial
loop comes from (one dispatch per token-step instead of one per request).

Static shapes still rule everything: the pool is a fixed ``[max_batch,
capacity, ...]`` allocation, so the decode executable compiles exactly once
per pool shape regardless of arrival order, mix of hit/miss requests, or
occupancy.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.serving.engine import BatchedEngine, Engine, GenResult


@dataclass
class Request:
    request_id: int
    prompt: str
    max_new_tokens: Optional[int] = None
    use_recycling: bool = True
    admit: bool = False
    # sampling controls; 0 temperature = greedy (the paper's
    # do_sample=False default).  Rows at different temperatures mix
    # freely in one pool dispatch (engine `sample_batched`).
    temperature: float = 0.0
    top_k: int = 0
    # owner for per-tenant L2 byte quotas (threaded engine -> recycler ->
    # HostKVStore; the scheduler's quota check reads the same accounting)
    tenant: Optional[str] = None
    submitted_at: float = field(default_factory=time.perf_counter)
    # SLO clock: enqueue_t stamps at submit (== submitted_at, kept under
    # both names for back-compat), admit_t when a slot is taken,
    # first_token_t when the first token lands.  core.metrics.slo_summary
    # consumes these.
    enqueue_t: float = field(default_factory=time.perf_counter)
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    result: Optional[GenResult] = None
    error: Optional[str] = None          # set when admission rejects it
    _ids: Optional[object] = field(default=None, repr=False)  # encode memo

    @property
    def done(self) -> bool:
        return self.result is not None or self.error is not None

    @property
    def queue_delay_s(self) -> Optional[float]:
        """Seconds spent queued before admission (None until admitted)."""
        if self.admit_t is None:
            return None
        return self.admit_t - self.enqueue_t


class FIFOScheduler:
    """Serial reference scheduler: one ``engine.generate`` per request."""

    def __init__(self, engine: Engine, *, max_batch: int = 8):
        self.engine = engine
        self.max_batch = max_batch
        self._queue: Deque[Request] = deque()
        self._next_id = 0
        self.completed: List[Request] = []

    def submit(self, prompt: str, **kw) -> Request:
        req = Request(self._next_id, prompt, **kw)
        self._next_id += 1
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    def step(self) -> List[Request]:
        """Serve up to max_batch requests from the queue head, sequentially
        (the engine's jit cache makes same-shape requests share one
        executable, but each still pays its own dispatch per token)."""
        served = []
        while self._queue and len(served) < self.max_batch:
            req = self._queue.popleft()
            req.admit_t = time.perf_counter()
            req.result = self.engine.generate(
                req.prompt, max_new_tokens=req.max_new_tokens,
                use_recycling=req.use_recycling, admit=req.admit,
                temperature=req.temperature, top_k=req.top_k,
                tenant=req.tenant)
            req.first_token_t = req.admit_t + req.result.ttft_s
            served.append(req)
            self.completed.append(req)
        return served

    def run(self) -> List[Request]:
        while self._queue:
            self.step()
        return self.completed


class ContinuousBatchingScheduler:
    """Admission policy + slot allocator over a ``BatchedEngine`` pool."""

    def __init__(self, engine: BatchedEngine, *,
                 max_admissions_per_step: Optional[int] = None,
                 admission_policy: str = "fifo",
                 tenant_quotas: Optional[Dict[str, int]] = None):
        self.engine = engine
        # at most this many single-row prefills per step before decoding;
        # None = fill every free slot (prefill-heavy but maximal occupancy)
        if max_admissions_per_step is not None and max_admissions_per_step < 1:
            raise ValueError("max_admissions_per_step must be >= 1 (0 would "
                             "make run() spin forever admitting nothing)")
        if admission_policy not in ("fifo", "cache_aware"):
            raise ValueError(f"unknown admission_policy {admission_policy!r}")
        self.max_admissions = max_admissions_per_step
        # "fifo" refills strictly in arrival order; "cache_aware" prefers
        # the queued request with the DEEPEST resident prefix in the
        # engine's block trie (``trie.peek`` — recency untouched), so warm
        # requests admit with near-zero prefill work while the batch is
        # hot.  FIFO breaks ties (strict > comparison), so a queue of
        # all-cold requests degenerates to exact FIFO — no starvation of
        # equally-cold requests, though a steady warm stream can delay a
        # cold one (that's the policy's documented trade).
        self.admission_policy = admission_policy
        # tenant -> max L2 (HostKVStore) bytes.  Enforced at ADMIT time:
        # an over-quota tenant's request still decodes, but its
        # ``admit=True`` is downgraded so it cannot grow the store
        # further.  Serving is never rejected on quota.
        self.tenant_quotas = tenant_quotas
        self._queue: Deque[Request] = deque()
        self._next_id = 0
        self._free: List[int] = engine.free_slots()
        self.in_flight: Dict[int, Request] = {}       # slot -> request
        self.completed: List[Request] = []
        self.stats = {"decode_steps": 0, "admissions": 0,
                      "instant_finishes": 0, "slot_reuses": 0,
                      "rejected": 0, "occupancy_sum": 0,
                      "quota_denied_admits": 0, "cache_aware_picks": 0}

    # ------------------------------------------------------------------
    def submit(self, prompt: str, **kw) -> Request:
        req = Request(self._next_id, prompt, **kw)
        self._next_id += 1
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    def _pop_next(self) -> Request:
        """Select and remove the next request to admit.  FIFO pops the
        queue head; cache_aware scans the queue for the deepest resident
        prefix in the engine's block trie (peek — no recency stamp) and
        falls back to FIFO when the engine has no trie/tokenizer or
        nothing queued is warm (strict > keeps arrival order on ties)."""
        if self.admission_policy == "cache_aware" and len(self._queue) > 1:
            trie = getattr(self.engine, "trie", None)
            tok = getattr(self.engine, "tok", None)
            if trie is not None and tok is not None:
                best_i, best_d = 0, -1
                for i, req in enumerate(self._queue):
                    if req._ids is None:
                        req._ids = tok.encode(req.prompt)
                    depth, _ = trie.peek(req._ids)
                    if depth > best_d:
                        best_i, best_d = i, depth
                if best_i > 0:
                    self.stats["cache_aware_picks"] += 1
                    req = self._queue[best_i]
                    del self._queue[best_i]
                    return req
        return self._queue.popleft()

    def _admit_allowed(self, req: Request) -> bool:
        """Admit-time quota gate: False when the request's tenant is at or
        over its L2 byte quota (serving still proceeds, admission to the
        host store is what gets denied)."""
        if not req.admit or not self.tenant_quotas or req.tenant is None:
            return req.admit
        quota = self.tenant_quotas.get(req.tenant)
        if quota is None:
            return True
        store = getattr(getattr(self.engine, "recycler", None), "store", None)
        if store is None or store.tenant_usage(req.tenant) < quota:
            return True
        self.stats["quota_denied_admits"] += 1
        return False

    def _admit(self) -> List[Request]:
        """Fill free slots from the queue; returns requests that
        completed during admission (rejections and instant finishes)."""
        done: List[Request] = []
        budget = (len(self._free) if self.max_admissions is None
                  else min(self.max_admissions, len(self._free)))
        while self._queue and budget > 0:
            slot = self._free.pop()
            req = self._pop_next()
            req.admit_t = time.perf_counter()
            try:
                res = self.engine.admit_slot(
                    slot, req.prompt, max_new_tokens=req.max_new_tokens,
                    use_recycling=req.use_recycling,
                    admit=self._admit_allowed(req),
                    temperature=req.temperature, top_k=req.top_k,
                    tenant=req.tenant)
            except ValueError as e:
                # reject THIS request (e.g. longer than the pool capacity)
                # without dropping the rest of the queue or the slot
                self._free.append(slot)
                req.error = str(e)
                self.completed.append(req)
                self.stats["rejected"] += 1
                done.append(req)
                continue
            except Exception:
                self._free.append(slot)      # don't leak the slot
                raise
            self.stats["admissions"] += 1
            budget -= 1    # admission work happened either way (a staged
            #                prefill ran, or chunk steps were queued)
            if res is not None:                       # finished at token 0
                req.result = res
                if res.ttft_s and res.ttft_s > 0.0:
                    req.first_token_t = req.admit_t + res.ttft_s
                self.completed.append(req)
                self.stats["instant_finishes"] += 1
                self._free.append(slot)
                done.append(req)
                continue
            self.in_flight[slot] = req
        return done

    def step(self) -> List[Request]:
        """Admit into free slots, then advance every in-flight request one
        token.  Returns the requests that completed this step (including
        admission-time completions: rejections and instant finishes)."""
        finished: List[Request] = list(self._admit())
        decoded = bool(self.in_flight)
        self.stats["occupancy_sum"] += len(self.in_flight)
        for slot, result in self.engine.decode_batch():
            req = self.in_flight.pop(slot)
            req.result = result
            # first-token wall time, reconstructed from the engine's TTFT
            # measurement relative to this request's admit stamp (the
            # engine measures TTFT from its own admission start, which is
            # within one step() of admit_t — documented approximation)
            if (req.admit_t is not None and result.ttft_s
                    and result.ttft_s > 0.0):
                req.first_token_t = req.admit_t + result.ttft_s
            self.completed.append(req)
            finished.append(req)
            if self._queue:
                self.stats["slot_reuses"] += 1
            self._free.append(slot)
        self.stats["decode_steps"] += int(decoded)
        return finished

    def run(self) -> List[Request]:
        while self._queue or self.in_flight:
            self.step()
        return self.completed

    # ------------------------------------------------------------------
    def mean_occupancy(self) -> float:
        steps = max(self.stats["decode_steps"], 1)
        return self.stats["occupancy_sum"] / steps
