"""AdamW + cosine schedule, dependency-free (optax is not installed here).

State and math follow Loshchilov & Hutter; moments are kept in f32 even for
bf16 params (mixed-precision training convention).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: any
    v: any


def cosine_lr(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: AdamWState, lr,
                 *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 clip_norm=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        if p.ndim >= 2:                       # decoupled decay on matrices
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * update).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr_t}
