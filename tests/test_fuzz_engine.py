"""Hypothesis fuzz of the full serving engine: for ANY workload of prompts
(random texts, random precache/query interleavings), the recycled greedy
output equals the baseline greedy output — the paper's correctness claim as
a universally-quantified property, covering exact hits, partial radix hits,
multi-turn admissions, and misses in one invariant.
"""
import jax
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.configs import get_config
from repro.models import init_params
from repro.serving import Engine

_WORDS = ["alpha", "beta", "gamma", "delta", "report", "summary", "rain",
          "paris", "machine", "learning", "cloud", "budget", "tokens"]

prompt_st = st.lists(st.sampled_from(_WORDS), min_size=2, max_size=12).map(
    " ".join)


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@given(cache_prompts=st.lists(prompt_st, min_size=1, max_size=3,
                              unique=True),
       queries=st.lists(prompt_st, min_size=1, max_size=3),
       partial=st.booleans(), compress=st.booleans())
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
def test_recycled_equals_baseline_any_workload(stack, cache_prompts,
                                               queries, partial, compress):
    cfg, params = stack
    eng = Engine(cfg, params, max_new_tokens=4, block_size=8,
                 enable_partial=partial, compress_host_cache=compress)
    eng.precache(cache_prompts)
    for q in queries:
        # queries often extend a cached prompt (the interesting case)
        probe = cache_prompts[0] + " " + q if len(q) % 2 else q
        base = eng.generate(probe, use_recycling=False)
        rec = eng.generate(probe, admit=True)
        assert rec.text == base.text, (probe, rec.mode, rec.reuse_depth)
        assert rec.reuse_depth < rec.prompt_tokens
