"""The paper's core correctness claim, as a test: prefilling a suffix on top
of a recycled prefix cache is equivalent to prefilling the whole prompt —
for every architecture family (attention KV, MLA latent, recurrent state),
and the subsequent decode trajectories agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill

FAMS = ["qwen3-1.7b", "qwen2.5-3b", "dialogpt-medium", "rwkv6-3b",
        "recurrentgemma-9b", "deepseek-v2-236b", "kimi-k2-1t-a32b"]


@pytest.mark.parametrize("arch", FAMS)
@pytest.mark.parametrize("k", [8, 20])
def test_split_prefill_equivalence(arch, k, rng):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rng)
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.fold_in(rng, 1), (B, S), 0,
                                cfg.vocab_size)
    c_full = init_cache(cfg, B, 64)
    lg_full, c_full = prefill(cfg, params, tokens, c_full)

    c_split = init_cache(cfg, B, 64)
    _, c_split = prefill(cfg, params, tokens[:, :k], c_split)
    lg_split, c_split = prefill(cfg, params, tokens[:, k:], c_split,
                                start_pos=k)
    np.testing.assert_allclose(np.asarray(lg_full, np.float32),
                               np.asarray(lg_split, np.float32),
                               rtol=2e-4, atol=2e-4)

    # decode trajectories from both caches stay identical (greedy, 4 steps)
    tok_f = tok_s = jnp.argmax(lg_full, -1)[:, None]
    for i in range(4):
        lf, c_full = decode_step(cfg, params, tok_f, c_full, S + i)
        ls, c_split = decode_step(cfg, params, tok_s, c_split, S + i)
        assert bool((jnp.argmax(lf, -1) == jnp.argmax(ls, -1)).all()), arch
        tok_f = jnp.argmax(lf, -1)[:, None]
        tok_s = jnp.argmax(ls, -1)[:, None]


def test_recycled_cache_matches_decode_chain(rng):
    """Prefill(k tokens) == k decode steps (cache-state equivalence)."""
    cfg = get_config("qwen3-1.7b").reduced()
    params = init_params(cfg, rng)
    B, S = 1, 10
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    c1 = init_cache(cfg, B, 32)
    lg1, c1 = prefill(cfg, params, tokens, c1)
    # token-by-token: prefill first token then decode the rest
    c2 = init_cache(cfg, B, 32)
    lg2, c2 = prefill(cfg, params, tokens[:, :1], c2)
    for t in range(1, S):
        lg2, c2 = decode_step(cfg, params, tokens[:, t:t + 1], c2, t)
    np.testing.assert_allclose(np.asarray(lg1, np.float32),
                               np.asarray(lg2, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_decode_equivalence(rng):
    """Ring-buffer (windowed) decode == full-cache decode restricted to the
    window — the long_500k mechanism."""
    cfg = get_config("qwen3-1.7b").reduced()
    params = init_params(cfg, rng)
    W = cfg.sliding_window  # 64 in reduced
    B, S = 1, 48
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    c_full = init_cache(cfg, B, 128)
    lg_f, c_full = prefill(cfg, params, tokens, c_full, window=W)
    c_ring = init_cache(cfg, B, 128, window=W)
    lg_r, c_ring = prefill(cfg, params, tokens, c_ring, window=W)
    np.testing.assert_allclose(np.asarray(lg_f, np.float32),
                               np.asarray(lg_r, np.float32),
                               rtol=2e-4, atol=2e-4)
    tok = jnp.argmax(lg_f, -1)[:, None]
    for i in range(6):
        lf, c_full = decode_step(cfg, params, tok, c_full, S + i, window=W)
        lr, c_ring = decode_step(cfg, params, tok, c_ring, S + i, window=W)
        np.testing.assert_allclose(np.asarray(lf, np.float32),
                                   np.asarray(lr, np.float32),
                                   rtol=2e-4, atol=2e-4)
        tok = jnp.argmax(lf, -1)[:, None]
