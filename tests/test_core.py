"""Unit tests for the recycling core: store, index, recycler policies."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import (EmbeddingIndex, HashEmbedder, HostKVStore,
                        RadixPrefixCache, Recycler)
from repro.core.recycler import (common_prefix_len, grow_capacity,
                                 is_trimmable, trim_to_depth)


def _attn_cache(n_slots=16, filled=8):
    sp = np.where(np.arange(n_slots) < filled, np.arange(n_slots), -1)
    return {"seg0": {"k": np.random.randn(2, 1, n_slots, 2, 4).astype(np.float32),
                     "v": np.random.randn(2, 1, n_slots, 2, 4).astype(np.float32),
                     "slot_pos": np.tile(sp, (2, 1)).astype(np.int32)}}


def _state_cache():
    return {"seg0": {"wkv": np.zeros((2, 1, 2, 4, 4), np.float32),
                     "shift_t": np.zeros((2, 1, 8), np.float32),
                     "shift_c": np.zeros((2, 1, 8), np.float32)}}


class TestEmbedder:
    def test_deterministic(self):
        e = HashEmbedder()
        a = e.encode("hello world")
        np.testing.assert_array_equal(a, e.encode("hello world"))
        assert abs(np.linalg.norm(a) - 1.0) < 1e-5

    def test_extended_prefix_similar(self):
        e = HashEmbedder()
        base = e.encode("Explain machine learning in simple terms.")
        ext = e.encode("Explain machine learning in simple terms. "
                       "Give an example application.")
        other = e.encode("How do airplanes fly?")
        assert float(base @ ext) > 0.6
        assert float(base @ other) < 0.3


class TestIndex:
    def test_search_and_remove(self):
        e = HashEmbedder(dim=64)
        idx = EmbeddingIndex(64)
        texts = ["alpha beta", "gamma delta", "alpha beta gamma"]
        for i, t in enumerate(texts):
            idx.add(i, e.encode(t))
        top = idx.search(e.encode("alpha beta"), k=2)
        assert top[0][0] == 0
        idx.remove(0)
        top = idx.search(e.encode("alpha beta"), k=1)
        assert top[0][0] == 2


class TestStore:
    def test_lru_eviction_budget(self):
        cache = _attn_cache()
        entry_bytes = sum(a.nbytes for seg in cache.values()
                          for a in seg.values())
        store = HostKVStore(max_bytes=int(entry_bytes * 2.5))
        for i in range(4):
            store.put(f"p{i}", np.arange(6), _attn_cache(), 6)
            store.evict_to_budget()
        assert len(store) == 2
        assert store.total_bytes <= store.max_bytes
        assert store.evictions == 2

    def test_disk_roundtrip(self):
        store = HostKVStore()
        e = store.put("prompt a", np.arange(8), _attn_cache(), 8, 16)
        with tempfile.TemporaryDirectory() as d:
            store.save_dir(d)
            loaded = HostKVStore.load_dir(d)
        e2 = loaded.get(e.entry_id)
        assert e2.text == "prompt a" and e2.length == 8
        np.testing.assert_array_equal(
            e2.cache["seg0"]["k"], e.cache["seg0"]["k"])


class TestCacheSurgery:
    def test_trimmable(self):
        assert is_trimmable(_attn_cache())
        assert not is_trimmable(_state_cache())

    def test_trim_masks_slots(self):
        t = trim_to_depth(_attn_cache(filled=8), 5)
        sp = t["seg0"]["slot_pos"]
        assert (sp[:, :5] >= 0).all() and (sp[:, 5:] == -1).all()

    def test_grow_capacity(self):
        g = grow_capacity(_attn_cache(n_slots=16), 32)
        assert g["seg0"]["k"].shape[2] == 32
        assert (g["seg0"]["slot_pos"][:, 16:] == -1).all()

    def test_grow_capacity_1d_slot_pos(self):
        # regression: a bare 1-D slot_pos leaf (no layer stack) must pad on
        # its only axis with -1, and a leaf with fewer dims than its named
        # capacity axis must be left alone instead of padding a wrong axis
        cache = {"slot_pos": np.full((4,), -1, np.int32),
                 "k": np.zeros((8, 2), np.float32)}  # ndim 2 < |axis -3|
        g = grow_capacity(cache, 8)
        assert g["slot_pos"].shape == (8,)
        assert (g["slot_pos"] == -1).all()
        assert g["k"].shape == (8, 2)                # untouched

    def test_grow_capacity_per_slot_pos(self):
        # batched-pool layout: slot_pos carries a batch axis (B, C)
        cache = {"slot_pos": np.where(np.arange(6) < 3, np.arange(6),
                                      -1).astype(np.int32)[None].repeat(2, 0)}
        g = grow_capacity(cache, 12)
        assert g["slot_pos"].shape == (2, 12)
        assert (g["slot_pos"][:, 6:] == -1).all()

    def test_common_prefix_len(self):
        assert common_prefix_len([1, 2, 3], [1, 2, 3, 4]) == 3
        assert common_prefix_len([1, 2, 9], [1, 2, 3, 4]) == 2
        assert common_prefix_len([], [1]) == 0


class TestRecycler:
    def test_exact_prefix_hit(self):
        r = Recycler()
        toks = np.arange(10)
        r.admit("what is the capital of france?", toks, _attn_cache(), 10)
        res = r.lookup("what is the capital of france? and italy?",
                       np.concatenate([toks, [11, 12, 13]]))
        assert res.hit and res.mode == "exact_prefix" and res.reuse_depth == 10

    def test_identical_prompt_leaves_one_token(self):
        r = Recycler()
        toks = np.arange(10)
        r.admit("same prompt", toks, _attn_cache(), 10)
        res = r.lookup("same prompt", toks)
        assert res.hit and res.reuse_depth == 9

    def test_recurrent_state_requires_full_prefix(self):
        r = Recycler()
        toks = np.arange(10)
        r.admit("state prompt xyz", toks, _state_cache(), 10)
        # identical prompt: state can't rewind to m-1 -> miss
        res = r.lookup("state prompt xyz", toks)
        assert not res.hit
        # strict extension: full state reuse OK
        res2 = r.lookup("state prompt xyz etc",
                        np.concatenate([toks, [11, 12]]))
        assert res2.hit and res2.reuse_depth == 10

    def test_partial_block_hit(self):
        r = Recycler(enable_partial=True, block_size=4)
        r.admit("p", np.arange(12), _attn_cache(filled=12), 12)
        res = r.lookup("q", np.asarray([0, 1, 2, 3, 4, 5, 99, 98, 97]))
        assert res.hit and res.mode == "partial_block" and res.reuse_depth == 4
        sp = res.cache["seg0"]["slot_pos"]
        assert (sp[:, 4:] == -1).all()      # trimmed beyond reuse depth

    def test_partial_hit_reports_entry_own_similarity(self):
        """The similarity on a partial_block result must describe the hit
        entry itself, not whatever sim_best the (rejected) exact-path
        retrieval loop happened to see."""
        r = Recycler(enable_partial=True, block_size=4)
        e = r.admit("shared tokens prompt", np.arange(12),
                    _attn_cache(filled=12), 12)
        # textually unrelated query whose TOKENS share a block-aligned
        # prefix: retrieval similarity to the entry is near zero, but the
        # radix still finds the 8-token overlap
        res = r.lookup("completely different words here",
                       np.asarray([0, 1, 2, 3, 4, 5, 6, 7, 55, 66]))
        assert res.hit and res.mode == "partial_block"
        own = r.index.similarity(e.entry_id,
                                 r.embedder.encode(
                                     "completely different words here"))
        assert res.similarity == pytest.approx(own)

    def test_radix_lookup_prefers_true_recency(self):
        """Two entries cover the same block prefix; lookup must prefer the
        most recently TOUCHED one (served hit), not the highest id."""
        rx = RadixPrefixCache(block_size=4)
        rx.insert(np.arange(8), entry_id=1, length=8)
        rx.insert(np.arange(8), entry_id=2, length=8)   # newer insert wins
        assert rx.lookup(np.arange(8))[1] == 2
        rx.touch(1)                                     # old entry re-hit
        assert rx.lookup(np.arange(8))[1] == 1          # id order would say 2
        rx.touch(2)
        assert rx.lookup(np.arange(8))[1] == 2

    def test_recycler_hit_refreshes_radix_recency(self):
        """Serving a hit must stamp the entry in the radix too, so lookup
        preference tracks the same LRU order the store eviction uses."""
        r = Recycler(enable_partial=True, block_size=4)
        toks = np.arange(8)
        r.admit("first", toks, _attn_cache(), 8)
        r.admit("second", toks.copy(), _attn_cache(), 8)
        # both cover the same tokens; the radix serves the newer (id 1)...
        assert r.radix.lookup(np.asarray([0, 1, 2, 3]))[1] == 1
        # ...but after entry 0 serves an exact hit, IT is the fresher one
        res = r.lookup("first", np.concatenate([toks, [20]]))
        assert res.hit and res.entry.entry_id == 0
        assert r.radix.lookup(np.asarray([0, 1, 2, 3]))[1] == 0

    def test_eviction_reaches_index_and_radix(self):
        cache = _attn_cache()
        entry_bytes = sum(a.nbytes for seg in cache.values()
                          for a in seg.values())
        r = Recycler(HostKVStore(max_bytes=int(entry_bytes * 1.5)),
                     enable_partial=True, block_size=4)
        e0 = r.admit("first prompt", np.arange(8), _attn_cache(), 8)
        e1 = r.admit("second prompt", np.arange(100, 108), _attn_cache(), 8)
        assert e0.entry_id not in r.store          # evicted
        assert e0.entry_id not in r.radix
        res = r.lookup("first prompt zz", np.arange(10))
        assert not res.hit
