"""Configuration system for the repro framework.

Every architecture (the paper's own DialoGPT-medium testbed plus the ten
assigned architectures) is described by a frozen ``ModelConfig``.  Configs are
pure data — model code in ``repro.models`` interprets them; sharding rules in
``repro.sharding`` map them onto the mesh.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (Switch/DeepSeek style)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # Layers at the bottom of the stack that use a dense FFN instead of MoE
    # (DeepSeek-V2 / Kimi-K2 use a dense first layer).
    first_dense_layers: int = 0
    dense_d_ff: int = 0           # d_ff for those dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2).  The KV cache stores only
    the compressed latent ``c_kv`` plus the shared RoPE key."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class HybridConfig:
    """Griffin/RecurrentGemma hybrid: repeating blocks of temporal-mixing
    layers, e.g. ``("rglru", "rglru", "local_attn")`` (the 1:2 pattern)."""

    pattern: Tuple[str, ...] = ("rglru", "rglru", "local_attn")
    lru_width: int = 0            # 0 -> d_model
    local_window: int = 2048
    conv1d_width: int = 4


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 "Finch" attention-free time mixing."""

    head_dim: int = 64


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB (per assignment: audio conv codec and ViT are
    not implemented — ``input_specs`` provides precomputed frame/patch
    embeddings of the right shape)."""

    kind: str                     # "audio" | "vision"
    num_tokens: int               # frames (audio) or patches (vision)
    embed_dim: int                # embedding dim delivered by the stub
    cross_attention: bool         # True: enc-dec (whisper); False: prefix-concat (VLM)
    # Encoder stack applied on top of the stub embeddings (whisper encoder).
    encoder_layers: int = 0
    encoder_heads: int = 0
    encoder_d_ff: int = 0


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ----------------------------------------------------------
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | enc_dec | vlm
    source: str                   # citation for the config numbers
    # -- trunk dimensions --------------------------------------------------
    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    num_kv_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 50257
    head_dim: int = 0             # 0 -> d_model // num_heads
    max_seq_len: int = 524_288
    # -- attention flavour -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    pos: str = "rope"             # "rope" | "learned"
    rope_theta: float = 10_000.0
    # sliding window used when an otherwise-full-attention arch runs the
    # long_500k shape (sub-quadratic requirement).  0 disables the variant.
    sliding_window: int = 8192
    # -- block flavour -----------------------------------------------------
    norm: str = "rmsnorm"         # "rmsnorm" | "layernorm"
    mlp: str = "swiglu"           # "swiglu" | "gelu_mlp"
    tie_embeddings: bool = False
    # -- family extensions (at most one of moe/hybrid/rwkv; mla may pair moe)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    hybrid: Optional[HybridConfig] = None
    rwkv: Optional[RWKVConfig] = None
    frontend: Optional[FrontendConfig] = None
    # -- numerics ----------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # ----------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.rwkv is None and self.mla is None:
            assert self.num_heads % self.num_kv_heads == 0, self.name

    # Derived helpers ------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.rwkv is not None

    @property
    def is_encdec(self) -> bool:
        return self.frontend is not None and self.frontend.cross_attention

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate total parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        for layer in range(L):
            total += self._layer_params(layer)
        if self.frontend is not None and self.frontend.encoder_layers:
            f = self.frontend
            enc_layer = 4 * f.embed_dim * f.embed_dim + 2 * f.embed_dim * f.encoder_d_ff
            total += f.encoder_layers * enc_layer
        return total

    def q_lora(self) -> int:
        return self.mla.q_lora_rank if self.mla else 0

    def _layer_params(self, layer_idx: int) -> int:
        d = self.d_model
        hd = self.head_dim
        # attention / mixer
        if self.rwkv is not None:
            mix = 6 * d * d          # r,k,v,g,o,w projections (approx)
        elif self.mla is not None:
            m = self.mla
            nh = self.num_heads
            mix = (d * m.q_lora_rank
                   + m.q_lora_rank * nh * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                   + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                   + m.kv_lora_rank * nh * (m.qk_nope_head_dim + m.v_head_dim)
                   + nh * m.v_head_dim * d)
        else:
            mix = (d * self.num_heads * hd          # Q
                   + 2 * d * self.num_kv_heads * hd  # K, V
                   + self.num_heads * hd * d)        # O
        if self.hybrid is not None and self.hybrid.pattern:
            kind = self.hybrid.pattern[layer_idx % len(self.hybrid.pattern)]
            if kind == "rglru":
                w = self.hybrid.lru_width or d
                mix = 2 * d * w + 2 * w * w // 1 + w * d  # gates + conv approx
        # ffn
        mult = 3 if self.mlp == "swiglu" else 2
        if self.moe is not None and layer_idx >= self.moe.first_dense_layers:
            moe = self.moe
            ffn = (moe.num_experts + moe.num_shared_experts) * mult * d * moe.d_ff_expert
            ffn += d * moe.num_experts  # router
        elif self.moe is not None:
            ffn = mult * d * self.moe.dense_d_ff
        else:
            ffn = mult * d * self.d_ff
        return mix + ffn

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        moe = self.moe
        mult = 3 if self.mlp == "swiglu" else 2
        for layer in range(L):
            full = self._layer_params(layer)
            if layer >= moe.first_dense_layers:
                routed_all = moe.num_experts * mult * d * moe.d_ff_expert
                routed_act = (moe.top_k + moe.num_shared_experts) * mult * d * moe.d_ff_expert
                full = full - routed_all - moe.num_shared_experts * mult * d * moe.d_ff_expert + routed_act
            total += full
        return total

    # Reduced variant for CPU smoke tests ----------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family, tiny dims: ≤2 layers (one full hybrid block),
        d_model ≤ 512, ≤4 experts — runs a forward/train step on CPU."""
        d = min(self.d_model, 256)
        heads = max(2, min(self.num_heads, 4))
        kvh = 1 if self.num_kv_heads < self.num_heads else heads
        layers = len(self.hybrid.pattern) if self.hybrid else 2
        kw = dict(
            name=self.name + "-reduced",
            num_layers=layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kvh,
            head_dim=d // heads,
            d_ff=d * 4,
            vocab_size=512,
            max_seq_len=2048,
            sliding_window=64 if self.sliding_window else 0,
            dtype="float32",
            param_dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_ff_expert=d * 2, dense_d_ff=d * 4,
                first_dense_layers=min(self.moe.first_dense_layers, 1))
        if self.mla is not None:
            kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
            kw["head_dim"] = 16
        if self.hybrid is not None:
            kw["hybrid"] = dataclasses.replace(
                self.hybrid, lru_width=d, local_window=32)
        if self.rwkv is not None:
            kw["rwkv"] = RWKVConfig(head_dim=32)
            kw["num_kv_heads"] = heads
        if self.frontend is not None:
            kw["frontend"] = dataclasses.replace(
                self.frontend, num_tokens=16, embed_dim=d,
                encoder_layers=min(self.frontend.encoder_layers, 2),
                encoder_heads=heads if self.frontend.encoder_layers else 0,
                encoder_d_ff=d * 4 if self.frontend.encoder_layers else 0)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"
    long_context: bool = False    # requires sub-quadratic attention


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode", long_context=True),
}


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
