# Continuous batching vs serial FIFO: tokens/s on a mixed workload.
"""Throughput benchmark for the slot-pool decode engine.

  PYTHONPATH=src python benchmarks/continuous_batching.py
  PYTHONPATH=src python benchmarks/continuous_batching.py --full --max-new 32

Workload: a fixed mix of recycled exact-prefix hits, partial-block hits and
cold misses (the three admission modes a production pool sees), served by

  * the serial FIFO scheduler (one generate per request — the seed's path),
  * the continuous-batching scheduler at batch sizes {1, 4, 8}.

Both paths see identical precached recycler contents.  Each configuration
runs the workload once untimed (jit warmup — per-suffix-length prefill
executables plus the one pool decode executable) and once timed.  Reported
tokens/s counts generated tokens only; the acceptance bar for this PR is
batch=8 >= 2x serial.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           Engine, FIFOScheduler)

CACHED = [
    "the quick brown fox jumps over the lazy dog today",
    "what is the capital of france and why",
    "explain machine learning in simple terms please",
]


def workload(n_requests: int):
    """Round-robin mix: exact hit / partial hit / cold miss."""
    reqs = []
    for i in range(n_requests):
        kind = i % 3
        if kind == 0:
            reqs.append(CACHED[i % len(CACHED)] + f" extended {i}")
        elif kind == 1:
            base = CACHED[i % len(CACHED)].rsplit(" ", 2)[0]
            reqs.append(base + f" divergent tail {i}")
        else:
            reqs.append(f"cold unseen prompt number {i} with no overlap")
    return reqs


def _run(sched, prompts, max_new):
    """(seconds, generated_tokens) for one workload pass.  Run twice on the
    SAME scheduler: the first pass compiles every per-suffix-length prefill
    executable plus the pool decode step; only the second pass is a fair
    timing (the paper's T4 runs have no compile step either)."""
    sched.completed = []
    for p in prompts:
        sched.submit(p, max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    rejected = [r for r in done if r.result is None]
    if rejected:
        print(f"# {len(rejected)} request(s) rejected: {rejected[0].error}")
    toks = sum(r.result.gen_tokens for r in done if r.result is not None)
    return dt, toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--capacity", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("dialogpt-medium")
    if not args.full:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = workload(args.requests)

    eng = Engine(cfg, params, max_new_tokens=args.max_new, block_size=8,
                 enable_partial=True)
    eng.precache(CACHED)
    serial_sched = FIFOScheduler(eng)

    def timed_best(sched):
        """Warmup pass, then best of two timed passes (this box is shared;
        a single pass can eat a CPU-contention spike)."""
        _run(sched, prompts, args.max_new)                 # warmup compile
        a = _run(sched, prompts, args.max_new)
        b = _run(sched, prompts, args.max_new)
        return min(a, b)

    rows = []
    dt, toks = timed_best(serial_sched)
    serial_tps = toks / dt
    rows.append(("serial_fifo", dt, toks, serial_tps, 1.0))

    for b in args.batches:
        beng = BatchedEngine(cfg, params, max_batch=b,
                             capacity=args.capacity,
                             max_new_tokens=args.max_new, block_size=8,
                             enable_partial=True)
        beng.precache(CACHED)
        sched = ContinuousBatchingScheduler(beng)
        dt, toks = timed_best(sched)
        rows.append((f"continuous_b{b}", dt, toks, toks / dt,
                     (toks / dt) / serial_tps))

    print(f"{'config':<16} {'wall_s':>8} {'gen_tok':>8} "
          f"{'tok/s':>10} {'speedup':>8}")
    for name, dt, toks, tps, sp in rows:
        print(f"{name:<16} {dt:>8.3f} {toks:>8d} {tps:>10.1f} {sp:>7.2f}x")
    best = max(r[4] for r in rows[1:])
    print(f"\nbest batched speedup over serial: {best:.2f}x")
    return rows


if __name__ == "__main__":
    main()
