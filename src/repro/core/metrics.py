"""Run metrics and the paper's Table-1 summary.

Paper §4.5 metrics: latency L = t_end - t_start, reused tokens R,
output similarity cos(E_base, E_rec), plus derived average speedup
S̄ = mean((L_base - L_rec) / L_base) * 100.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional

import numpy as np


@dataclass
class RunMetrics:
    prompt: str
    method: str                 # "baseline" | "recycled"
    latency_s: float
    prompt_tokens: int
    gen_tokens: int
    reuse_depth: int = 0
    cache_hit: bool = False
    prompt_similarity: float = 0.0
    mode: str = ""
    output_text: str = ""

    def row(self) -> Dict:
        return asdict(self)


def output_similarity(embedder, a: str, b: str) -> float:
    ea, eb = embedder.encode(a), embedder.encode(b)
    return float(np.dot(ea, eb))


def summarize_runs(baseline: List[RunMetrics], recycled: List[RunMetrics],
                   embedder=None) -> Dict:
    """Merge per-prompt rows on the text key and emit the paper's Table 1."""
    base = {r.prompt: r for r in baseline}
    rec = {r.prompt: r for r in recycled}
    keys = [k for k in base if k in rec]
    n = len(keys)
    hits = [k for k in keys if rec[k].cache_hit]
    speedups = {}
    for k in keys:
        lb, lr = base[k].latency_s, rec[k].latency_s
        speedups[k] = (lb - lr) / lb * 100.0 if lb > 0 else 0.0
    out_sims = []
    if embedder is not None:
        out_sims = [output_similarity(embedder, base[k].output_text,
                                      rec[k].output_text) for k in keys]

    def _avg(xs):
        # nan-aware: device-resident (L1) hits carry nan similarity — no
        # retrieval backs them — and must not poison the summary mean
        xs = [x for x in xs if not (isinstance(x, float) and math.isnan(x))]
        return float(np.mean(xs)) if xs else float("nan")

    return {
        "total_prompts": n,
        "cache_hits": len(hits),
        "hit_rate_pct": 100.0 * len(hits) / n if n else float("nan"),
        "total_tokens_reused": int(sum(rec[k].reuse_depth for k in keys)),
        "avg_speedup_pct": _avg(speedups.values()),
        "avg_speedup_with_cache_pct": _avg(speedups[k] for k in hits),
        "avg_speedup_no_cache_pct": _avg(
            speedups[k] for k in keys if k not in hits),
        "avg_output_similarity": _avg(out_sims),
        "avg_prompt_similarity": _avg(rec[k].prompt_similarity for k in keys),
        "high_similarity_prompts": sum(
            1 for k in keys if rec[k].prompt_similarity > 0.8),
        "latency_baseline_avg_s": _avg(base[k].latency_s for k in keys),
        "latency_recycled_avg_s": _avg(rec[k].latency_s for k in keys),
    }


def tpot_summary(results) -> Dict:
    """TPOT / TTFT summary over GenResults (anything carrying
    ``step_times_s`` / ``ttft_s``): p50/p95/mean time-per-output-token
    plus mean and p95 time-to-first-token — the serving latency pair
    (TTFT = admission cost, TPOT = decode cadence).  A speculative
    round's burst is recorded as equal per-token shares of the round's
    wall time, so accepted drafts show up as LOWER TPOT samples rather
    than as missing ones.

    Degenerate inputs are well-defined instead of raising or emitting
    NaN (``json.dump`` writes NaN as invalid JSON): results whose
    ``step_times_s`` / ``ttft_s`` is absent or None contribute no
    samples; with no samples the corresponding fields are None and
    ``tpot_samples`` is 0; with a single sample every percentile is
    that sample."""
    steps = [t for r in results
             for t in (getattr(r, "step_times_s", None) or [])]
    ttfts = [t for t in (getattr(r, "ttft_s", None) for r in results)
             if t is not None and t > 0.0]

    def pct(xs, q):
        return float(np.percentile(xs, q)) if xs else None

    return {
        "tpot_p50_s": pct(steps, 50),
        "tpot_p95_s": pct(steps, 95),
        "tpot_mean_s": float(np.mean(steps)) if steps else None,
        "tpot_samples": len(steps),
        "ttft_mean_s": float(np.mean(ttfts)) if ttfts else None,
        "ttft_p95_s": pct(ttfts, 95),
    }


def slo_summary(results, requests=None, *, ttft_slo_s: Optional[float] = None,
                tpot_slo_s: Optional[float] = None) -> Dict:
    """Serving-SLO summary: TTFT / TPOT / queue-delay percentiles plus the
    fraction of requests meeting every GIVEN target.

    ``results`` are GenResults (or anything carrying ``ttft_s`` /
    ``step_times_s``); ``requests`` are scheduler ``Request`` objects
    (anything carrying ``queue_delay_s``) for the admission-queue view —
    pass the same objects the ContinuousBatchingScheduler returned.

    SLO attainment is judged per REQUEST: a request attains when its TTFT
    meets ``ttft_slo_s`` (if given) AND its p95 per-token step time meets
    ``tpot_slo_s`` (if given).  With no targets given, or no measurable
    requests, ``slo_attainment`` is None — same None-not-NaN convention
    as ``tpot_summary`` (NaN is invalid JSON)."""
    def pct(xs, q):
        return float(np.percentile(xs, q)) if xs else None

    ttfts = [t for t in (getattr(r, "ttft_s", None) for r in results)
             if t is not None and t > 0.0]
    steps = [t for r in results
             for t in (getattr(r, "step_times_s", None) or [])]
    delays = [d for d in (getattr(r, "queue_delay_s", None)
                          for r in (requests or []))
              if d is not None]

    attained = None
    samples = 0
    if ttft_slo_s is not None or tpot_slo_s is not None:
        ok = 0
        for r in results:
            ttft = getattr(r, "ttft_s", None)
            rsteps = getattr(r, "step_times_s", None) or []
            meets = True
            measurable = False
            if ttft_slo_s is not None and ttft is not None and ttft > 0.0:
                measurable = True
                meets = meets and ttft <= ttft_slo_s
            if tpot_slo_s is not None and rsteps:
                measurable = True
                meets = meets and pct(rsteps, 95) <= tpot_slo_s
            if measurable:
                samples += 1
                ok += int(meets)
        attained = ok / samples if samples else None

    out = {
        "ttft_p50_s": pct(ttfts, 50),
        "ttft_p95_s": pct(ttfts, 95),
        "tpot_p50_s": pct(steps, 50),
        "tpot_p95_s": pct(steps, 95),
        "queue_delay_p50_s": pct(delays, 50),
        "queue_delay_p95_s": pct(delays, 95),
        "slo_attainment": attained,
        "slo_samples": samples,
    }
    out.update(_pressure_summary(results, requests))
    return out


def _pressure_summary(results, requests) -> Dict:
    """Overload-era additions to the SLO view: typed shed rates,
    preemption rates, and deadline attainment, computed from scheduler
    ``Request`` outcomes (``RequestOutcome``) and GenResult preemption
    counters.  All rates are fractions of SUBMITTED requests, so a
    server that sheds 30% cannot launder its p95 by only reporting the
    requests it chose to serve.  None (not NaN) when no requests were
    given — same JSON-safe convention as the rest of the summary."""
    reqs = list(requests or [])
    n = len(reqs)
    outcomes: Dict[str, int] = {}
    for r in reqs:
        o = getattr(r, "outcome", None)
        if o is not None:
            outcomes[o] = outcomes.get(o, 0) + 1
    shed = (outcomes.get("shed_queue_full", 0)
            + outcomes.get("shed_deadline", 0))
    preempted = [r for r in results
                 if getattr(r, "preemptions", 0) > 0]
    recomputed = sum(getattr(r, "tokens_recomputed", 0) for r in results)
    # deadline attainment: of requests that CARRIED a deadline, how many
    # produced their result before it (shed-on-deadline counts as missed;
    # requests without deadlines are excluded, not counted as attained)
    dl_total = dl_ok = 0
    for r in reqs:
        dl = getattr(r, "deadline_t", None)
        if dl is None:
            continue
        dl_total += 1
        ft = getattr(r, "first_token_t", None)
        if (getattr(r, "outcome", None) == "ok"
                and (ft is None or ft <= dl)):
            dl_ok += 1
    return {
        "requests_submitted": n if requests is not None else None,
        "outcome_counts": outcomes if requests is not None else None,
        "shed_rate": (shed / n) if n else None,
        "errored_rate": (outcomes.get("errored", 0) / n) if n else None,
        "preempted_results": len(preempted),
        "preemption_rate": (len(preempted) / len(results)
                            if results else None),
        "tokens_recomputed": int(recomputed),
        "deadline_attainment": (dl_ok / dl_total) if dl_total else None,
        "deadline_samples": dl_total,
    }


class Timer:
    """Wall-clock timer with block_until_ready semantics handled by caller
    (the paper's cuda.synchronize analogue is jax block_until_ready)."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
