"""dialogpt-medium — the paper's own testbed (GPT-2 medium architecture).

[arXiv:1911.00536] DialoGPT: 24L, d_model=1024, 16 heads (MHA), d_ff=4096,
vocab≈50k BPE, learned positions, 1024-token context, 345M params.  This is
the model the paper's Table 1 numbers come from; our paper-repro benchmarks
run this config (random-init weights — the paper's claims are about latency
and cache mechanics, not output quality).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="dialogpt-medium",
    arch_type="dense",
    source="arXiv:1911.00536 (DialoGPT-medium, GPT-2 arch)",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=50257,
    head_dim=64,
    pos="learned",
    norm="layernorm",
    mlp="gelu_mlp",
    qkv_bias=True,
    tie_embeddings=True,
    max_seq_len=1024,
    sliding_window=0,
)
