"""Paged-native chunked prefill attention (the admission hot path).

The staged admission path ran the dense prefill over a full-capacity
staging cache: gather the resident prefix out of the pool, prefill the
suffix, scatter the result back into pool blocks — a device round-trip
per admission, and one compiled executable per distinct suffix length.
This kernel removes the round-trip: a fixed-size chunk of C query tokens
attends its *history* directly against the request's pool blocks,
gathered through the scalar-prefetched block table exactly like
``paged_decode_attention``, and its *own* K/V from the chunk's fresh fp
operands (flash-attention style) — sealing to the pool happens after,
so in-chunk attention is always full precision.

Structure: flash-style online softmax (same recurrence as
``flash_attention``) with grid = (kv_heads, table_entries + chunk_tiles);
the kv dimension is sequential so the (C*G, d) softmax state stays in
VMEM.  Tiles ti < NBt are history: pool block ``table[ti]``, implicit
positions ti*bs + j, valid iff < ``w_eff`` (the history/chunk boundary —
normally the chunk start, or the promoted depth when a host promotion
pre-uploaded a partial boundary block) and causally <= the query's
position.  Tiles ti >= NBt are the chunk itself: fp operand slice at
positions c0 + (ti - NBt)*bs + j, valid iff >= ``w_eff``.  Sentinel
(block 0) table entries beyond the written region are harmless: their
positions exceed ``w_eff``.

Because C is FIXED (block-aligned ``prefill_chunk``), ONE compiled
executable serves every admission regardless of suffix length — c0 and
w_eff arrive as scalar-prefetch operands, never as shape.

``paged_prefill_attention_quant`` is the int8-pool variant: the history
gather fuses the per-vector dequant, and the last ``R`` history blocks
(ending at the newest history block, derived from w_eff) are read from
the row's fp ring tail instead — the same recency gate the int8 decode
kernel applies, so chunked prefill and decode see one consistent view of
where full precision lives.

``paged_prefill_attention_packed{,_quant}`` generalize the chunked pair
to MANY concurrent admissions in ONE dispatch: every pending admission's
current chunk is concatenated into a single ragged ``[total_tokens]``
buffer (each segment bs-aligned, the whole buffer padded to one of a few
bucket sizes), per-SEGMENT block tables arrive as a (S, NBt) scalar
prefetch, and a per-QUERY-TILE descriptor (4, QT) of
``[seg, c0, w_eff, qt0]`` rows drives both the gather index maps and the
segment-masked online softmax.  The grid gains a query-tile axis
(Hkv, QT, NBt + chunk_tiles) with the kv axis innermost, so each query
tile keeps its own (bs*G, d) softmax state in VMEM; because segments are
bs-aligned, every query tile belongs to exactly ONE segment and the
per-tile output blocks are disjoint.  Chunk kv tiles index the packed
buffer at ``qt0 + j`` — tiles past the query's own (j > qt - qt0) are
fully masked by causality (their minimum key position exceeds the tile's
maximum query position), so no cross-segment leakage is possible even
though neighbouring segments are adjacent in the buffer.  Buffer bucket
sizes and the fixed segment count keep the compile count independent of
both suffix length AND the number of concurrent admissions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import check_shard_view

NEG_INF = -1e30


def _accumulate(ti, ntiles, s, v, o_ref, m_scr, l_scr, acc_scr):
    """One online-softmax step over a pre-masked score tile ``s``
    (C*G, bs) against values ``v`` (bs, d), with the normalized write on
    the last tile.  Shared by the fp and int8 kernels so the
    normalization that must stay in lockstep for fp-vs-int8 token
    equivalence lives in one place."""
    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_new = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ti == ntiles - 1)
    def _write():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _tile_mask(ti, sc_ref, CG, bs, G, nbt):
    """(kv positions, validity) for tile ``ti``: history tiles hold
    implicit pool positions valid below w_eff; chunk tiles hold operand
    positions valid at/after it.  Causality against the query rows
    (query row r is token r // G at position c0 + r // G) applies to
    both."""
    c0, w_eff = sc_ref[0], sc_ref[1]
    j = jax.lax.broadcasted_iota(jnp.int32, (CG, bs), 1)
    qp = c0 + jax.lax.broadcasted_iota(jnp.int32, (CG, bs), 0) // G
    is_hist = ti < nbt
    kp = jnp.where(is_hist, ti * bs + j, c0 + (ti - nbt) * bs + j)
    ok = (kp <= qp) & jnp.where(is_hist, kp < w_eff, kp >= w_eff)
    return ok


def _paged_prefill_kernel(tbl_ref, sc_ref, q_ref, k_ref, v_ref, kc_ref,
                          vc_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
                          bs, nbt, G, cb):
    """One (kv_head, tile) program: for history tiles the BlockSpec index
    map already resolved table entry ``ti`` to a pool block; for chunk
    tiles it selected the matching slice of the chunk's fp K/V."""
    ti = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (C*G, d)
    is_hist = ti < nbt
    k = jnp.where(is_hist, k_ref[0, 0], kc_ref[0, 0]).astype(jnp.float32)
    v = jnp.where(is_hist, v_ref[0, 0], vc_ref[0, 0]).astype(jnp.float32)
    s = q @ k.T * scale                               # (C*G, bs)
    s = jnp.where(_tile_mask(ti, sc_ref, q.shape[0], bs, G, nbt),
                  s, NEG_INF)
    _accumulate(ti, nbt + cb, s, v, o_ref, m_scr, l_scr, acc_scr)


def _chunk_layouts(q, k_chunk, v_chunk, bs):
    """(1, C, H|Hkv, D) -> kernel layouts: q (Hkv, C*G, D) with query row
    r = (token r // G, group r % G); chunk K/V (Hkv, C/bs, bs, D)."""
    _, C, H, D = q.shape
    Hkv = k_chunk.shape[2]
    G = H // Hkv
    qr = (q.reshape(C, Hkv, G, D).transpose(1, 0, 2, 3)
          .reshape(Hkv, C * G, D))
    kcr = (k_chunk.reshape(C // bs, bs, Hkv, D).transpose(2, 0, 1, 3))
    vcr = (v_chunk.reshape(C // bs, bs, Hkv, D).transpose(2, 0, 1, 3))
    return qr, kcr, vcr


def paged_prefill_attention(q, k_chunk, v_chunk, k_pool, v_pool, table_row,
                            c0, w_eff, *, scale=None, interpret=True):
    """Chunked-prefill attention through a block table.

    q / k_chunk / v_chunk (1, C, H|Hkv, D): one admission chunk's roped
    projections at absolute positions [c0, c0 + C); pools
    (NB, bs, Hkv, D) shared by all requests; table_row (NBt,) int32 — the
    admitting request's block table (sentinel-0 padded); c0, w_eff scalar
    int32.  History (< w_eff) is read through the table; the chunk itself
    (>= w_eff) from the fp operands, so sealing K/V to the pool can
    happen AFTER attention.  Chunk padding queries produce garbage the
    caller discards.  Returns (1, C, H, D)."""
    _, C, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = table_row.shape[0]
    CB = C // bs
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr, kcr, vcr = _chunk_layouts(q, k_chunk, v_chunk, bs)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D)
    vr = v_pool.transpose(2, 0, 1, 3)
    sc = jnp.stack([jnp.asarray(c0, jnp.int32),
                    jnp.asarray(w_eff, jnp.int32)])

    def hist_ix(h, ti, tbl, sc, n=NBt):
        return (h, tbl[jnp.minimum(ti, n - 1)], 0, 0)

    def chunk_ix(h, ti, tbl, sc, n=NBt, c=CB):
        return (h, jnp.clip(ti - n, 0, c - 1), 0, 0)

    kernel = functools.partial(_paged_prefill_kernel, scale=scale, bs=bs,
                               nbt=NBt, G=G, cb=CB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block table + [c0, w_eff]
        grid=(Hkv, NBt + CB),
        in_specs=[
            pl.BlockSpec((1, C * G, D), lambda h, ti, tbl, sc: (h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
        ],
        out_specs=pl.BlockSpec((1, C * G, D),
                               lambda h, ti, tbl, sc: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, C * G, D), q.dtype),
        interpret=interpret,
    )(table_row.astype(jnp.int32), sc, qr, kr, vr, kcr, vcr)
    return (out.reshape(Hkv, C, G, D).transpose(1, 0, 2, 3)
            .reshape(1, C, H, D))


def _paged_prefill_kernel_quant(tbl_ref, sc_ref, q_ref, k_ref, v_ref,
                                ks_ref, vs_ref, kt_ref, vt_ref, kc_ref,
                                vc_ref, o_ref, m_scr, l_scr, acc_scr, *,
                                scale, bs, nbt, G, cb, rtail):
    """int8 variant: history K/V tiles arrive as int8 pool blocks plus
    their per-vector f32 scales (same table-lookup index map) with the
    dequant fused into the gather; the last ``rtail`` HISTORY blocks
    (ending at the newest history block hb, from w_eff) are read from the
    row's fp ring tail instead — attention runs before the chunk seals,
    so the ring still holds exactly those blocks.  Chunk tiles use the fp
    operands like the fp kernel."""
    ti = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (C*G, d)
    k8 = k_ref[0, 0].astype(jnp.float32)              # (bs, d) int8 tile
    v8 = v_ref[0, 0].astype(jnp.float32)
    ks = ks_ref[0, 0].astype(jnp.float32)             # (bs,) f32 scales
    vs = vs_ref[0, 0].astype(jnp.float32)
    kt = kt_ref[0, 0].astype(jnp.float32)             # (bs, d) fp ring tile
    vt = vt_ref[0, 0].astype(jnp.float32)
    kc = kc_ref[0, 0].astype(jnp.float32)             # (bs, d) fp chunk tile
    vc = vc_ref[0, 0].astype(jnp.float32)

    hb = (sc_ref[1] - 1) // bs                        # newest history block
    use_fp = (ti <= hb) & (ti > hb - rtail)           # scalar: ring block?
    is_hist = ti < nbt
    k = jnp.where(is_hist, jnp.where(use_fp, kt, k8 * ks[:, None]), kc)
    v = jnp.where(is_hist, jnp.where(use_fp, vt, v8 * vs[:, None]), vc)
    s = q @ k.T * scale
    s = jnp.where(_tile_mask(ti, sc_ref, q.shape[0], bs, G, nbt),
                  s, NEG_INF)
    _accumulate(ti, nbt + cb, s, v, o_ref, m_scr, l_scr, acc_scr)


def paged_prefill_attention_quant(q, k_chunk, v_chunk, k_pool, v_pool,
                                  k_scale, v_scale, k_tail_row, v_tail_row,
                                  table_row, c0, w_eff, *, scale=None,
                                  interpret=True):
    """Fused-dequant chunked prefill: q / chunk K/V (1, C, H|Hkv, D); int8
    pools (NB, bs, Hkv, D) with f32 scales (NB, bs, Hkv); the admitting
    row's fp ring tail (R*bs, Hkv, D); table_row (NBt,); c0, w_eff
    scalars.  The table gather is unchanged from the fp kernel — only
    history tile contents differ (int8 + scale, or the fp ring slot for
    the last R history blocks).  Returns (1, C, H, D)."""
    _, C, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = table_row.shape[0]
    CB = C // bs
    R = k_tail_row.shape[0] // bs
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr, kcr, vcr = _chunk_layouts(q, k_chunk, v_chunk, bs)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D) int8
    vr = v_pool.transpose(2, 0, 1, 3)
    ksr = k_scale.transpose(2, 0, 1)                  # (Hkv, NB, bs) f32
    vsr = v_scale.transpose(2, 0, 1)
    ktr = (k_tail_row.reshape(R, bs, Hkv, D)          # (Hkv, R, bs, D)
           .transpose(2, 0, 1, 3))
    vtr = (v_tail_row.reshape(R, bs, Hkv, D)
           .transpose(2, 0, 1, 3))
    sc = jnp.stack([jnp.asarray(c0, jnp.int32),
                    jnp.asarray(w_eff, jnp.int32)])

    def hist_ix(h, ti, tbl, sc, n=NBt):
        return (h, tbl[jnp.minimum(ti, n - 1)], 0, 0)

    def hist_ix_s(h, ti, tbl, sc, n=NBt):
        return (h, tbl[jnp.minimum(ti, n - 1)], 0)

    def ring_ix(h, ti, tbl, sc, r=R):
        return (h, ti % r, 0, 0)

    def chunk_ix(h, ti, tbl, sc, n=NBt, c=CB):
        return (h, jnp.clip(ti - n, 0, c - 1), 0, 0)

    kernel = functools.partial(_paged_prefill_kernel_quant, scale=scale,
                               bs=bs, nbt=NBt, G=G, cb=CB, rtail=R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block table + [c0, w_eff]
        grid=(Hkv, NBt + CB),
        in_specs=[
            pl.BlockSpec((1, C * G, D), lambda h, ti, tbl, sc: (h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs), hist_ix_s),
            pl.BlockSpec((1, 1, bs), hist_ix_s),
            pl.BlockSpec((1, 1, bs, D), ring_ix),
            pl.BlockSpec((1, 1, bs, D), ring_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
        ],
        out_specs=pl.BlockSpec((1, C * G, D),
                               lambda h, ti, tbl, sc: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, C * G, D), q.dtype),
        interpret=interpret,
    )(table_row.astype(jnp.int32), sc, qr, kr, vr, ksr, vsr, ktr, vtr,
      kcr, vcr)
    return (out.reshape(Hkv, C, G, D).transpose(1, 0, 2, 3)
            .reshape(1, C, H, D))


# ---------------------------------------------------------------------------
# ragged packed multi-admission prefill
# ---------------------------------------------------------------------------
def _packed_tile_mask(qt, ti, desc_ref, BG, bs, G, nbt):
    """Validity for query tile ``qt`` against kv tile ``ti``: history
    tiles (< nbt) hold the tile's SEGMENT's pool positions, valid below
    its w_eff; chunk tiles hold the segment's packed-buffer positions at
    or after it.  Causality uses the tile's absolute query positions
    ``c0 + (qt - qt0) * bs + r // G``."""
    c0 = desc_ref[1, qt]
    w_eff = desc_ref[2, qt]
    qt0 = desc_ref[3, qt]
    j = jax.lax.broadcasted_iota(jnp.int32, (BG, bs), 1)
    r = jax.lax.broadcasted_iota(jnp.int32, (BG, bs), 0)
    qp = c0 + (qt - qt0) * bs + r // G
    is_hist = ti < nbt
    kp = jnp.where(is_hist, ti * bs + j, c0 + (ti - nbt) * bs + j)
    ok = (kp <= qp) & jnp.where(is_hist, kp < w_eff, kp >= w_eff)
    return ok


def _paged_prefill_packed_kernel(tbl_ref, desc_ref, q_ref, k_ref, v_ref,
                                 kc_ref, vc_ref, o_ref, m_scr, l_scr,
                                 acc_scr, *, scale, bs, nbt, G, ntiles):
    """One (kv_head, query_tile, kv_tile) program: the BlockSpec index
    maps already resolved history tile ``ti`` through the tile's
    SEGMENT's table row, and chunk tile ``ti - nbt`` to packed-buffer
    tile ``qt0 + (ti - nbt)``; the mask keeps everything segment-local."""
    qt = pl.program_id(1)
    ti = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32)                  # (bs*G, d)
    is_hist = ti < nbt
    k = jnp.where(is_hist, k_ref[0, 0], kc_ref[0, 0]).astype(jnp.float32)
    v = jnp.where(is_hist, v_ref[0, 0], vc_ref[0, 0]).astype(jnp.float32)
    s = q @ k.T * scale                               # (bs*G, bs)
    s = jnp.where(_packed_tile_mask(qt, ti, desc_ref, q.shape[0], bs, G,
                                    nbt), s, NEG_INF)
    _accumulate(ti, ntiles, s, v, o_ref, m_scr, l_scr, acc_scr)


def paged_prefill_attention_packed(q, k_chunk, v_chunk, k_pool, v_pool,
                                   tables, desc, *, scale=None,
                                   chunk_tiles=None, interpret=True):
    """Ragged packed multi-admission prefill attention.

    q / k_chunk / v_chunk (1, T, H|Hkv, D): EVERY pending admission's
    current chunk concatenated (each segment bs-aligned, T padded to a
    bucket size); pools (NB, bs, Hkv, D); tables (S, NBt) int32 — one
    block-table row per segment (sentinel rows for padding segments);
    desc (4, QT) int32 — per query tile ``[seg, c0, w_eff, qt0]`` where
    qt0 is the segment's first packed tile.  History (< w_eff) is read
    through the tile's segment's table; the segment's own chunk
    (>= w_eff) from the fp operands.  ``chunk_tiles`` bounds how many
    chunk kv tiles any one segment spans (defaults to all of them).
    Padding queries produce garbage the caller discards.
    Returns (1, T, H, D)."""
    _, T, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = tables.shape[1]
    QT = T // bs
    CB = chunk_tiles or QT
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr, kcr, vcr = _chunk_layouts(q, k_chunk, v_chunk, bs)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D)
    vr = v_pool.transpose(2, 0, 1, 3)

    def hist_ix(h, qt, ti, tbl, dsc, n=NBt):
        return (h, tbl[dsc[0, qt], jnp.minimum(ti, n - 1)], 0, 0)

    def chunk_ix(h, qt, ti, tbl, dsc, n=NBt, qtt=QT):
        return (h, jnp.clip(dsc[3, qt] + ti - n, 0, qtt - 1), 0, 0)

    def q_ix(h, qt, ti, tbl, dsc):
        return (h, qt, 0)

    kernel = functools.partial(_paged_prefill_packed_kernel, scale=scale,
                               bs=bs, nbt=NBt, G=G, ntiles=NBt + CB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # segment tables + tile descriptors
        grid=(Hkv, QT, NBt + CB),
        in_specs=[
            pl.BlockSpec((1, bs * G, D), q_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
        ],
        out_specs=pl.BlockSpec((1, bs * G, D), q_ix),
        scratch_shapes=[
            pltpu.VMEM((bs * G,), jnp.float32),
            pltpu.VMEM((bs * G,), jnp.float32),
            pltpu.VMEM((bs * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, T * G, D), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), desc.astype(jnp.int32), qr, kr, vr,
      kcr, vcr)
    return (out.reshape(Hkv, T, G, D).transpose(1, 0, 2, 3)
            .reshape(1, T, H, D))


def _paged_prefill_packed_kernel_quant(tbl_ref, desc_ref, q_ref, k_ref,
                                       v_ref, ks_ref, vs_ref, kt_ref,
                                       vt_ref, kc_ref, vc_ref, o_ref,
                                       m_scr, l_scr, acc_scr, *, scale, bs,
                                       nbt, G, ntiles, rtail):
    """int8 packed variant: history tiles arrive as int8 pool blocks plus
    scales (dequant fused into the segment-table gather); the last
    ``rtail`` HISTORY blocks of each tile's SEGMENT (ending at its newest
    history block hb, from its w_eff) come from that segment's fp ring
    tail — per-tile w_eff makes the recency gate per-segment, otherwise
    identical to the chunked quant kernel."""
    qt = pl.program_id(1)
    ti = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32)                  # (bs*G, d)
    k8 = k_ref[0, 0].astype(jnp.float32)              # (bs, d) int8 tile
    v8 = v_ref[0, 0].astype(jnp.float32)
    ks = ks_ref[0, 0].astype(jnp.float32)             # (bs,) f32 scales
    vs = vs_ref[0, 0].astype(jnp.float32)
    kt = kt_ref[0, 0, 0].astype(jnp.float32)          # (bs, d) fp ring tile
    vt = vt_ref[0, 0, 0].astype(jnp.float32)
    kc = kc_ref[0, 0].astype(jnp.float32)             # (bs, d) fp chunk tile
    vc = vc_ref[0, 0].astype(jnp.float32)

    hb = (desc_ref[2, qt] - 1) // bs                  # seg's newest hist blk
    use_fp = (ti <= hb) & (ti > hb - rtail)
    is_hist = ti < nbt
    k = jnp.where(is_hist, jnp.where(use_fp, kt, k8 * ks[:, None]), kc)
    v = jnp.where(is_hist, jnp.where(use_fp, vt, v8 * vs[:, None]), vc)
    s = q @ k.T * scale
    s = jnp.where(_packed_tile_mask(qt, ti, desc_ref, q.shape[0], bs, G,
                                    nbt), s, NEG_INF)
    _accumulate(ti, ntiles, s, v, o_ref, m_scr, l_scr, acc_scr)


def paged_prefill_attention_packed_quant(q, k_chunk, v_chunk, k_pool,
                                         v_pool, k_scale, v_scale, k_tails,
                                         v_tails, tables, desc, *,
                                         scale=None, chunk_tiles=None,
                                         interpret=True):
    """Fused-dequant ragged packed prefill: int8 pools (NB, bs, Hkv, D)
    with f32 scales (NB, bs, Hkv); k_tails / v_tails (S, R*bs, Hkv, D) —
    each SEGMENT's row's fp ring tail, gathered by the caller; tables
    (S, NBt); desc (4, QT).  The gathers are unchanged from the fp packed
    kernel — only history tile contents differ (int8 + scale, or the
    segment's fp ring slot for its last R history blocks).
    Returns (1, T, H, D)."""
    _, T, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = tables.shape[1]
    QT = T // bs
    CB = chunk_tiles or QT
    S = k_tails.shape[0]
    R = k_tails.shape[1] // bs
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr, kcr, vcr = _chunk_layouts(q, k_chunk, v_chunk, bs)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D) int8
    vr = v_pool.transpose(2, 0, 1, 3)
    ksr = k_scale.transpose(2, 0, 1)                  # (Hkv, NB, bs) f32
    vsr = v_scale.transpose(2, 0, 1)
    ktr = (k_tails.reshape(S, R, bs, Hkv, D)          # (Hkv, S, R, bs, D)
           .transpose(3, 0, 1, 2, 4))
    vtr = (v_tails.reshape(S, R, bs, Hkv, D)
           .transpose(3, 0, 1, 2, 4))

    def hist_ix(h, qt, ti, tbl, dsc, n=NBt):
        return (h, tbl[dsc[0, qt], jnp.minimum(ti, n - 1)], 0, 0)

    def hist_ix_s(h, qt, ti, tbl, dsc, n=NBt):
        return (h, tbl[dsc[0, qt], jnp.minimum(ti, n - 1)], 0)

    def ring_ix(h, qt, ti, tbl, dsc, r=R):
        return (h, dsc[0, qt], ti % r, 0, 0)

    def chunk_ix(h, qt, ti, tbl, dsc, n=NBt, qtt=QT):
        return (h, jnp.clip(dsc[3, qt] + ti - n, 0, qtt - 1), 0, 0)

    def q_ix(h, qt, ti, tbl, dsc):
        return (h, qt, 0)

    kernel = functools.partial(_paged_prefill_packed_kernel_quant,
                               scale=scale, bs=bs, nbt=NBt, G=G,
                               ntiles=NBt + CB, rtail=R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # segment tables + tile descriptors
        grid=(Hkv, QT, NBt + CB),
        in_specs=[
            pl.BlockSpec((1, bs * G, D), q_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs), hist_ix_s),
            pl.BlockSpec((1, 1, bs), hist_ix_s),
            pl.BlockSpec((1, 1, 1, bs, D), ring_ix),
            pl.BlockSpec((1, 1, 1, bs, D), ring_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
        ],
        out_specs=pl.BlockSpec((1, bs * G, D), q_ix),
        scratch_shapes=[
            pltpu.VMEM((bs * G,), jnp.float32),
            pltpu.VMEM((bs * G,), jnp.float32),
            pltpu.VMEM((bs * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, T * G, D), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), desc.astype(jnp.int32), qr, kr, vr, ksr,
      vsr, ktr, vtr, kcr, vcr)
    return (out.reshape(Hkv, T, G, D).transpose(1, 0, 2, 3)
            .reshape(1, T, H, D))
