"""Training substrate: optimizer math, loss descent, checkpoints, data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import ByteTokenizer, TrainBatches
from repro.data.pipeline import paper_prompt_sets
from repro.models import init_params, train_loss
from repro.training import (adamw_init, adamw_update, cosine_lr,
                            load_checkpoint, save_checkpoint, train)


def test_cosine_lr_shape():
    lr = cosine_lr(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < float(lr(50)) < float(lr(10))
    assert float(lr(100)) >= 1e-4 - 1e-9          # min_frac floor


def test_adamw_first_step_is_lr_sized():
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 0.5)}
    st = adamw_init(p)
    p2, st2, m = adamw_update(p, g, st, 1e-2, weight_decay=0.0)
    # first Adam step with constant grad ~ lr * sign(g)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.ones((4, 4)) - 1e-2, rtol=1e-3)
    assert int(st2.step) == 1


def test_grad_clipping():
    p = {"w": jnp.ones((2,))}
    g = {"w": jnp.full((2,), 1e6)}
    st = adamw_init(p)
    _, _, m = adamw_update(p, g, st, 1e-3, clip_norm=1.0)
    assert float(m["grad_norm"]) > 1e5            # reported pre-clip


def test_loss_decreases_20_steps(rng):
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, rng)
    tok = ByteTokenizer(cfg.vocab_size)
    batches = TrainBatches(tok, batch=4, seq_len=64)
    params, opt, hist = train(cfg, params, batches, steps=20, lr=1e-3,
                              warmup=5, log_every=19)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5


def test_checkpoint_roundtrip(rng):
    cfg = get_config("qwen3-1.7b").reduced()
    params = init_params(cfg, rng)
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, opt, step=7, extra={"arch": cfg.name})
        p2, o2, meta = load_checkpoint(d, with_opt=True)
    assert meta["step"] == 7 and meta["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert int(o2.step) == 0


def test_train_batches_pack_shape():
    tok = ByteTokenizer(512)
    it = iter(TrainBatches(tok, batch=3, seq_len=32))
    b1, b2 = next(it), next(it)
    assert b1["tokens"].shape == (3, 32)
    assert (b1["tokens"] != b2["tokens"]).any()   # stream advances


def test_paper_prompt_sets_csv(tmp_path):
    cache, test = paper_prompt_sets(str(tmp_path))
    assert len(cache) == 10 and len(test) == 6    # paper §4.6 scale
    assert (tmp_path / "cache_prompts.csv").exists()
    assert (tmp_path / "test_prompts.csv").exists()
    # the paper's construction: test prompts extend cache prompts
    assert test[0].startswith(cache[0])
