"""Beyond-paper benchmark: strict exact-prefix (the paper's rule) vs
block-radix partial reuse on a workload of diverging prompts.

The paper's limitation (§6.1): "If a single token differs, reuse is
disabled."  This benchmark quantifies what the radix extension buys: a
workload where prompts share long prefixes but diverge before the end —
exact-full-prefix misses, block-radix recovers floor(LCP/block) tokens.
"""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving import Engine


_BASE = ("please summarize the following report about quarterly results "
         "for the engineering division including staffing and budget ")
_VARIANTS = [
    _BASE + "with emphasis on hiring trends",
    _BASE + "with emphasis on cloud spend",
    _BASE + "focusing only on headcount changes",
    _BASE + "and compare against last year",
]


def _run(enable_partial: bool):
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new_tokens=8, block_size=16,
                 enable_partial=enable_partial)
    # seed the cache with the FIRST variant's full run (admit)
    eng.warmup(_VARIANTS[0], use_recycling=False)
    eng.generate(_VARIANTS[0], admit=True)
    hits, reused, lat = 0, 0, []
    for p in _VARIANTS[1:]:
        eng.warmup(p)
        r = eng.generate(p)
        hits += int(r.cache_hit)
        reused += r.reuse_depth
        lat.append(r.latency_s)
    return hits, reused, sum(lat) / len(lat)


def exact_vs_partial():
    out = []
    h_e, t_e, l_e = _run(enable_partial=False)
    h_p, t_p, l_p = _run(enable_partial=True)
    n = len(_VARIANTS) - 1
    out.append(("recycle.exact_only.hits", l_e * 1e6,
                f"{h_e}/{n} hits;{t_e} tokens reused"))
    out.append(("recycle.partial_radix.hits", l_p * 1e6,
                f"{h_p}/{n} hits;{t_p} tokens reused"))
    out.append(("recycle.partial_gain", 0.0,
                f"+{t_p - t_e} tokens reused vs paper rule"))
    out.extend(host_compression())
    out.extend(block_size_ablation())
    return out


def host_compression():
    """int8 host-cache compression: bytes + fidelity (paper §6.1 remedy)."""
    import time
    from repro.configs import get_config as _gc
    cfg = _gc("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = []
    stores = {}
    for comp in (False, True):
        eng = Engine(cfg, params, max_new_tokens=8, block_size=16,
                     compress_host_cache=comp)
        eng.precache([_BASE])
        probe = _BASE + "with emphasis on hiring trends"
        eng.warmup(probe)
        r = eng.generate(probe)
        stores[comp] = (eng.recycler.store.total_bytes, r)
    b0, r0 = stores[False]
    b1, r1 = stores[True]
    rows.append(("recycle.host_bytes.raw", 0.0, f"{b0/1e6:.2f}MB"))
    rows.append(("recycle.host_bytes.int8", 0.0,
                 f"{b1/1e6:.2f}MB ({b0/b1:.1f}x smaller)"))
    rows.append(("recycle.int8_fidelity", 0.0,
                 f"hit={r1.cache_hit};same_output={r0.text == r1.text}"))
    return rows


def block_size_ablation():
    """Radix granularity tradeoff: smaller blocks recover more of the LCP
    but make more index nodes; reuse depth = floor(LCP/block)*block."""
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = []
    for bs in (4, 16, 64):
        eng = Engine(cfg, params, max_new_tokens=4, block_size=bs,
                     enable_partial=True)
        eng.warmup(_VARIANTS[0], use_recycling=False)
        eng.generate(_VARIANTS[0], admit=True)
        reused = 0
        for pmt in _VARIANTS[1:]:
            eng.warmup(pmt)
            reused += eng.generate(pmt).reuse_depth
        rows.append((f"recycle.block_ablation.bs{bs}", 0.0,
                     f"{reused} tokens reused over {len(_VARIANTS)-1} queries"))
    return rows
