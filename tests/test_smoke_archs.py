"""REQUIRED per-arch smoke tests: reduced variant of each assigned
architecture runs one forward/train step on CPU — shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import (decode_step, init_cache, init_params, prefill,
                          train_loss)

ALL = ASSIGNED_ARCHS + ["dialogpt-medium"]


def _batch(cfg, rng, B=2, S=24):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend is not None:
        batch["frontend"] = jax.random.normal(
            rng, (B, cfg.frontend.num_tokens, cfg.frontend.embed_dim),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_train_step_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rng)
    batch = _batch(cfg, rng)
    loss, metrics = train_loss(cfg, params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    # random init: loss should be near ln(vocab)
    assert 2.0 < float(loss) < 12.0, arch


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rng)
    B, S = 2, 24
    batch = _batch(cfg, rng, B, S)
    extra = (cfg.frontend.num_tokens
             if cfg.frontend and not cfg.frontend.cross_attention else 0)
    cache = init_cache(cfg, B, 64 + extra)
    logits, cache = prefill(cfg, params, batch["tokens"], cache,
                            frontend=batch.get("frontend"))
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), arch
    tok = jnp.argmax(logits, -1)[:, None]
    logits2, cache = decode_step(cfg, params, tok, cache, S + extra)
    assert logits2.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any()), arch


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-3b",
                                  "recurrentgemma-9b", "kimi-k2-1t-a32b"])
def test_grad_step_finite(arch, rng):
    """One value_and_grad step produces finite grads for every family."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rng)
    batch = _batch(cfg, rng)
    (loss, _), grads = jax.value_and_grad(
        lambda p: train_loss(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), arch
