# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only repro.launch.dryrun forces 512 host devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
