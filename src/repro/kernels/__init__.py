"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel module pairs with a pure-jnp oracle in ``ref.py``; ``ops.py``
exposes the jit'd wrappers the model layer dispatches to via
``Runtime.use_pallas``.  On this CPU container kernels execute with
``interpret=True``; on TPU the same ``pallas_call``s compile via Mosaic.
"""
