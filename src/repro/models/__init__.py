"""Model substrate: composable decoder/enc-dec stacks for all assigned archs."""
from repro.models.model import (
    init_params,
    train_loss,
    prefill,
    prefill_paged,
    prefill_paged_packed,
    verify_paged,
    draft_view,
    draft_refine,
    decode_step,
    embed_inputs,
)
from repro.models.cache import (init_cache, cache_struct, init_paged_pool,
                                paged_block_bytes)

__all__ = [
    "init_params",
    "train_loss",
    "prefill",
    "prefill_paged",
    "prefill_paged_packed",
    "verify_paged",
    "draft_view",
    "draft_refine",
    "decode_step",
    "embed_inputs",
    "init_cache",
    "cache_struct",
    "init_paged_pool",
    "paged_block_bytes",
]
