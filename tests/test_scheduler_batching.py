"""Batched-vs-serial equivalence: a continuous batch mixing {recycled exact
hit, partial block hit, cold miss, early-EOS} requests must produce
token-for-token identical outputs to serial ``engine.generate`` calls.

This is the correctness contract of the slot pool: per-row slot_pos masking
makes every pool row behave exactly like a dedicated single-request cache,
so batching is a pure throughput optimization with no output drift.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import EOS
from repro.models import init_params
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           Engine)

CACHED = [
    "the quick brown fox jumps over the lazy dog today",
    "what is the capital of france and why",
]
REQUESTS = [
    # (prompt, expected mode against the CACHED precache)
    (CACHED[0] + " and tomorrow", "exact_prefix"),
    ("the quick brown fox jumps over a red fence", "partial_block"),
    ("zzz qqq completely unrelated 12345", "miss"),
    (CACHED[1] + " is it paris", "exact_prefix"),
]


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engines(stack, *, max_new=6, max_batch=3):
    """Serial + batched engine over identical recycler contents."""
    cfg, params = stack
    ser = Engine(cfg, params, max_new_tokens=max_new, block_size=8,
                 enable_partial=True)
    ser.precache(CACHED)
    bat = BatchedEngine(cfg, params, max_batch=max_batch, capacity=128,
                        max_new_tokens=max_new, block_size=8,
                        enable_partial=True)
    bat.precache(CACHED)
    return ser, bat


def test_batched_equals_serial_all_modes(stack):
    ser, bat = _engines(stack)
    serial = {p: ser.generate(p) for p, _ in REQUESTS}

    sched = ContinuousBatchingScheduler(bat)
    reqs = [sched.submit(p) for p, _ in REQUESTS]
    sched.run()

    for (p, want_mode), req in zip(REQUESTS, reqs):
        s, b = serial[p], req.result
        assert b.mode == want_mode, (p, b.mode)
        assert b.mode == s.mode and b.reuse_depth == s.reuse_depth
        assert b.text == s.text, (p, b.mode)
        np.testing.assert_array_equal(b.token_ids, s.token_ids)
        assert b.gen_tokens == s.gen_tokens
        assert b.prompt_tokens == s.prompt_tokens


def test_batched_equals_serial_mixed_budgets(stack):
    """Different per-request token budgets finish rows at different steps;
    freed slots are refilled mid-flight and outputs stay identical."""
    ser, bat = _engines(stack, max_new=8, max_batch=2)
    prompts = [p for p, _ in REQUESTS] + ["one more cold prompt"]
    budgets = [8, 3, 5, 2, 8]
    serial = [ser.generate(p, max_new_tokens=n)
              for p, n in zip(prompts, budgets)]

    sched = ContinuousBatchingScheduler(bat)
    reqs = [sched.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, budgets)]
    sched.run()

    assert sched.stats["slot_reuses"] >= 1        # genuinely mid-flight
    for s, req in zip(serial, reqs):
        assert req.result.text == s.text
        np.testing.assert_array_equal(req.result.token_ids, s.token_ids)


def test_early_eos_equivalence(stack, monkeypatch):
    """Force EOS emission (deterministically, in BOTH paths) by remapping a
    band of argmax ids to EOS: rows that stop early free their slot and the
    remaining rows must keep decoding exactly like their serial runs."""
    import repro.serving.engine as engine_mod

    def eos_greedy(logits):
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(g % 5 == 1, jnp.int32(EOS), g)

    monkeypatch.setattr(engine_mod, "greedy", eos_greedy)
    ser, bat = _engines(stack, max_new=8)
    serial = {p: ser.generate(p) for p, _ in REQUESTS}
    assert any(r.gen_tokens < 8 and r.token_ids[-1] == EOS
               for r in serial.values()), "remap produced no early EOS"

    sched = ContinuousBatchingScheduler(bat)
    reqs = [sched.submit(p) for p, _ in REQUESTS]
    sched.run()
    for (p, _), req in zip(REQUESTS, reqs):
        s, b = serial[p], req.result
        assert b.text == s.text and b.gen_tokens == s.gen_tokens
        np.testing.assert_array_equal(b.token_ids, s.token_ids)


def test_sampled_rows_mix_with_greedy(stack):
    """Request-level temperature/top_k: sampled rows draw per-row without
    disturbing greedy rows sharing the same pool dispatch (greedy stays
    the default, matching the paper's do_sample=False)."""
    cfg, params = stack
    bat = BatchedEngine(cfg, params, max_batch=3, capacity=64,
                        max_new_tokens=8, block_size=8)
    sched = ContinuousBatchingScheduler(bat)
    g = sched.submit("a greedy request stays greedy")
    s1 = sched.submit("a sampled request", temperature=1.2, top_k=8)
    s2 = sched.submit("a sampled request", temperature=1.2, top_k=8)
    sched.run()
    assert bat.stats["sampled_steps"] > 0
    assert s1.result.text != s2.result.text     # independent per-row draws

    ref = BatchedEngine(cfg, params, max_batch=3, capacity=64,
                        max_new_tokens=8, block_size=8)
    rsched = ContinuousBatchingScheduler(ref)
    g2 = rsched.submit("a greedy request stays greedy")
    rsched.run()
    assert ref.stats["sampled_steps"] == 0      # pure-greedy fast path
    assert g.result.text == g2.result.text
    np.testing.assert_array_equal(g.result.token_ids, g2.result.token_ids)


def test_serial_sampling_deterministic_per_seed(stack):
    """Serial engine sampling: draws differ across calls (key folds per
    request), greedy calls stay bit-deterministic."""
    cfg, params = stack
    eng = Engine(cfg, params, max_new_tokens=8, block_size=8)
    a = eng.generate("serial sampling test", temperature=1.0, top_k=4)
    b = eng.generate("serial sampling test", temperature=1.0, top_k=4)
    assert a.text != b.text
    g1 = eng.generate("serial sampling test")
    g2 = eng.generate("serial sampling test")
    assert g1.text == g2.text


def test_batched_admission_feeds_recycler(stack):
    """admit=True requests harvested from the pool must land in the host
    store trimmed to prompt depth, exactly like the serial path."""
    cfg, params = stack
    bat = BatchedEngine(cfg, params, max_batch=2, capacity=128,
                        max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(bat)
    p = "tell me about rivers"
    sched.submit(p, admit=True)
    sched.run()
    assert len(bat.recycler.store) == 1
    follow = bat.recycler.lookup(p + " and lakes too",
                                 bat.tok.encode(p + " and lakes too"))
    assert follow.hit and follow.reuse_depth >= len(bat.tok.encode(p)) - 1
