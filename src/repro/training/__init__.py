from repro.training.optimizer import adamw_init, adamw_update, cosine_lr
from repro.training.train_loop import make_train_step, train
from repro.training.checkpoint import save_checkpoint, load_checkpoint

__all__ = ["adamw_init", "adamw_update", "cosine_lr", "make_train_step",
           "train", "save_checkpoint", "load_checkpoint"]
