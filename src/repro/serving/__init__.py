from repro.serving.engine import Engine, GenResult
from repro.serving.sampling import greedy, sample_logits
from repro.serving.scheduler import Request, FIFOScheduler

__all__ = ["Engine", "GenResult", "greedy", "sample_logits", "Request",
           "FIFOScheduler"]
