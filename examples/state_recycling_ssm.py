"""State-snapshot recycling for attention-free models (DESIGN.md §4).

RWKV-6's decode state is O(1) in sequence length, so "KV recycling" for an
SSM means restoring a (wkv, shift) snapshot — the usable-context win the
paper speculates about is structural here: a recycled 1M-token prefix costs
the same bytes as a 10-token one.

    PYTHONPATH=src python examples/state_recycling_ssm.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.kvstore import to_host, tree_bytes
from repro.models import init_params
from repro.serving import Engine

cfg = get_config("rwkv6-3b").reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
engine = Engine(cfg, params, max_new_tokens=10)

ctx = ("system: you are a helpful assistant that answers concisely. "
       "knowledge: the eiffel tower is 330 meters tall. ")
engine.precache([ctx])
e = engine.recycler.store.get(0, touch=False)
print(f"cached state snapshot: {e.nbytes/1024:.1f} KB for {e.length} tokens "
      f"({e.nbytes/e.length:.0f} B/token amortized — O(1) state, unlike "
      f"attention KV which grows linearly)")

q = ctx + "user: how tall is the eiffel tower?"
base = engine.generate(q, use_recycling=False)
rec = engine.generate(q)
print(f"reuse: {rec.reuse_depth}/{rec.prompt_tokens} tokens "
      f"[{rec.mode}]  identical output: {base.text == rec.text}")

# recurrent caches are NOT trimmable: a diverging prompt must miss
div = ctx[:-10] + "DIFFERENT suffix entirely"
r2 = engine.generate(div)
print(f"diverging prompt -> mode={r2.mode} (state cannot rewind; "
      f"the paper's strict full-prefix rule is REQUIRED here)")
