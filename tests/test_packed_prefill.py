"""Ragged packed multi-admission prefill + SLO-aware admission.

The packed admission route (``prefill_mode="packed"``) advances EVERY
pending admission's chunk step in ONE ragged packed-QKV dispatch per
engine step.  This file holds it to the same discipline the chunked route
was held to: greedy decode is token-for-token identical to the chunked,
staged and serial paths (fp and int8, exact/partial/miss admissions,
early EOS); the packed kernel matches the jnp reference; the packed
writer matches per-segment chunk writes; the prefill-compile count is
bounded by the fixed packed-bucket ladder — independent of the number of
CONCURRENT admissions, not just of suffix lengths; and the ragged segment
descriptor construction satisfies its invariants (no overlap, full
coverage, block alignment) for ANY workload (hypothesis).

The satellites ride along: SLO timestamps + ``slo_summary``, per-tenant
admission quotas, cache-aware refill, and per-row repetition/presence
penalties in ``sample_batched``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import EOS
from repro.models import init_params
from repro.serving import (ContinuousBatchingScheduler, Engine,
                           PagedEngine)
from repro.serving.paged import SENTINEL, pack_admission_segments

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CACHED = [
    "the quick brown fox jumps over the lazy dog today",
    "what is the capital of france and why",
]
REQUESTS = [
    (CACHED[0] + " and tomorrow", "exact_prefix"),
    ("the quick brown fox jumps over a red fence", "partial_block"),
    ("zzz qqq completely unrelated 12345", "miss"),
    (CACHED[1] + " is it paris", "exact_prefix"),
]


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _paged(stack, *, prefill_mode, quant=False, max_new=6, max_batch=3,
           capacity=128, precache=CACHED, **kw):
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=max_batch, capacity=capacity,
                      max_new_tokens=max_new, block_size=8,
                      enable_partial=True, kv_quant=quant,
                      prefill_mode=prefill_mode, **kw)
    if precache:
        eng.precache(precache)
    return eng


def _run(eng, prompts, sched_kw=None, **submit_kw):
    sched = ContinuousBatchingScheduler(eng, **(sched_kw or {}))
    reqs = [sched.submit(p, **submit_kw) for p in prompts]
    sched.run()
    eng.check_invariants()
    return reqs


# ---------------------------------------------------------------------------
# 4-way token identity: packed == chunked == staged == serial
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quant", [False, True], ids=["fp", "int8"])
def test_packed_equals_chunked_and_staged(stack, quant):
    """Acceptance: packed greedy decode is token-identical to the chunked
    and staged routes on the reduced DialoGPT workload, fp and int8,
    across exact/partial/miss admissions — and the packed engine issued
    ONE prefill dispatch per engine step, not one per admission."""
    outs = {}
    for mode in ("staged", "chunked", "packed"):
        eng = _paged(stack, prefill_mode=mode, quant=quant)
        outs[mode] = (_run(eng, [p for p, _ in REQUESTS]), eng)
    for (p, _), rs, rc, rp in zip(REQUESTS, outs["staged"][0],
                                  outs["chunked"][0], outs["packed"][0]):
        assert rp.result.text == rc.result.text == rs.result.text, p
        np.testing.assert_array_equal(rp.result.token_ids,
                                      rc.result.token_ids)
        np.testing.assert_array_equal(rp.result.token_ids,
                                      rs.result.token_ids)
    eng = outs["packed"][1]
    assert eng.stats["prefill_packed_steps"] > 0
    assert eng.stats["staging_prefills"] == 0
    # one dispatch per packed step, every admission advanced inside it
    assert (eng.stats["prefill_dispatches"]
            == eng.stats["prefill_packed_steps"])
    assert eng.stats["prefill_chunks"] >= len(REQUESTS)
    # the chunked engine paid one dispatch per admission chunk
    ceng = outs["chunked"][1]
    assert ceng.stats["prefill_dispatches"] == ceng.stats["prefill_chunks"]


def test_packed_equals_serial_multi_chunk(stack):
    """A small chunk size forces every admission through SEVERAL packed
    steps interleaved with decode; fp outputs stay identical to the
    serial engine."""
    cfg, params = stack
    ser = Engine(cfg, params, max_new_tokens=6, block_size=8,
                 enable_partial=True)
    ser.precache(CACHED)
    serial = {p: ser.generate(p) for p, _ in REQUESTS}
    eng = _paged(stack, prefill_mode="packed", prefill_chunk=16)
    reqs = _run(eng, [p for p, _ in REQUESTS])
    assert eng.stats["prefill_packed_steps"] > 1
    for (p, _), r in zip(REQUESTS, reqs):
        np.testing.assert_array_equal(r.result.token_ids,
                                      serial[p].token_ids)


def test_packed_early_eos_equivalence(stack, monkeypatch):
    """Early-EOS rows free their blocks while neighbors are still being
    packed into the same dispatch; survivors decode exactly like
    chunked."""
    import repro.serving.engine as engine_mod

    def eos_greedy(logits):
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(g % 5 == 1, jnp.int32(EOS), g)

    monkeypatch.setattr(engine_mod, "greedy", eos_greedy)
    chunked = _paged(stack, prefill_mode="chunked", max_new=8)
    packed = _paged(stack, prefill_mode="packed", max_new=8)
    creqs = _run(chunked, [p for p, _ in REQUESTS])
    preqs = _run(packed, [p for p, _ in REQUESTS])
    assert any(r.result.gen_tokens < 8 and r.result.token_ids[-1] == EOS
               for r in creqs), "remap produced no early EOS"
    for rc, rp in zip(creqs, preqs):
        assert rp.result.text == rc.result.text
        assert rp.result.gen_tokens == rc.result.gen_tokens
        np.testing.assert_array_equal(rp.result.token_ids,
                                      rc.result.token_ids)


# ---------------------------------------------------------------------------
# compile count: independent of CONCURRENT-admission count
# ---------------------------------------------------------------------------
def test_packed_compiles_independent_of_admission_count(stack):
    """Acceptance: admitting 1 request or max_batch requests at once
    reuses the SAME packed executables — the compile count is bounded by
    the fixed packed-bucket ladder, never by concurrency or lengths."""
    prompts = [f"prompt of a distinct length {'x' * i}" for i in
               (0, 3, 7, 11)]
    eng = _paged(stack, prefill_mode="packed", max_batch=4, precache=None)
    _run(eng, prompts[:1])                 # 1 concurrent admission
    assert eng.prefill_compiles() <= len(eng.packed_buckets)
    seen = eng.prefill_compiles()
    _run(eng, prompts)                     # max_batch concurrent, new
    assert eng.prefill_compiles() <= len(eng.packed_buckets)  # lengths
    extra = eng.prefill_compiles() - seen
    # new BUCKETS may compile (bigger packed totals), but concurrency
    # itself must not: repeat the burst -> zero new executables
    _run(eng, [p + " again" for p in prompts])
    assert eng.prefill_compiles() == seen + extra


# ---------------------------------------------------------------------------
# kernel == jnp reference (fp and int8) and writer == per-segment writes
# ---------------------------------------------------------------------------
def _two_segment_pack():
    """Two ragged segments + a pad segment in a T=32 packed buffer:
    seg 0 (row 0) at depth 16 with a 13-valid 16-token chunk, seg 1
    (row 1) at depth 8 with a 5-valid 8-token chunk, 8 pad tokens."""
    rows = jnp.asarray([0, 1, 0], jnp.int32)
    tables = jnp.asarray([[3, 5, 7, 9, 0, 0],
                          [4, 6, 0, 0, 0, 0],
                          [0, 0, 0, 0, 0, 0]], jnp.int32)
    c0s = jnp.asarray([16, 8, 0], jnp.int32)
    w_floors = jnp.asarray([0, 0, 0], jnp.int32)
    valids = jnp.asarray([13, 5, 0], jnp.int32)
    q_offs = jnp.asarray([0, 16, 24], jnp.int32)
    seg_ids = jnp.asarray([0] * 16 + [1] * 8 + [2] * 8, jnp.int32)
    return rows, tables, c0s, w_floors, valids, q_offs, seg_ids


def _desc(c0s, w_floors, q_offs, seg_ids, bs):
    tile_seg = seg_ids[::bs]
    w_effs = jnp.maximum(w_floors, c0s)
    return jnp.stack([tile_seg, c0s[tile_seg], w_effs[tile_seg],
                      q_offs[tile_seg] // bs])


def test_packed_kernel_matches_reference_fp():
    from repro.kernels import ops
    from repro.models.attention import attend_paged_prefill_packed
    rng = np.random.default_rng(21)
    NB, bs, H, hkv, dh, T = 16, 8, 4, 2, 16, 32
    rows, tables, c0s, w_floors, valids, q_offs, seg_ids = _two_segment_pack()
    kp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, T, H, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(1, T, hkv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(1, T, hkv, dh)), jnp.float32)
    cache = {"k": kp, "v": vp,
             "block_tables": jnp.zeros((1, 6), jnp.int32)}
    ref = attend_paged_prefill_packed(q, kc, vc, cache, rows, tables, c0s,
                                      w_floors, q_offs, seg_ids)
    out = ops.paged_prefill_attention_packed(
        q, kc, vc, kp, vp, tables, _desc(c0s, w_floors, q_offs, seg_ids, bs),
        interpret=True)
    # compare VALID tokens only (chunk padding past a segment's valids is
    # never read by the engine)
    for i in range(2):
        o, n = int(q_offs[i]), int(valids[i])
        np.testing.assert_allclose(np.asarray(out[0, o:o + n]),
                                   np.asarray(ref[0, o:o + n]), atol=1e-5)
    # and against the per-chunk reference segment by segment — packing
    # must not leak anything across segments
    from repro.models.attention import attend_paged_prefill
    for i in range(2):
        o = int(q_offs[i])
        C = [16, 8][i]                        # seg chunk sizes
        per = attend_paged_prefill(
            q[:, o:o + C], kc[:, o:o + C], vc[:, o:o + C],
            cache, int(rows[i]), tables[i], int(c0s[i]),
            max(int(w_floors[i]), int(c0s[i])))
        n = int(valids[i])
        np.testing.assert_allclose(np.asarray(out[0, o:o + n]),
                                   np.asarray(per[0, :n]), atol=1e-5)


def test_packed_kernel_matches_reference_quant():
    from repro.kernels import ops
    from repro.models.attention import attend_paged_prefill_packed
    rng = np.random.default_rng(22)
    NB, bs, H, hkv, dh, T, R = 16, 8, 4, 2, 16, 32, 2
    rows, tables, c0s, w_floors, valids, q_offs, seg_ids = _two_segment_pack()
    kp = jnp.asarray(rng.integers(-127, 128, size=(NB, bs, hkv, dh)),
                     jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, size=(NB, bs, hkv, dh)),
                     jnp.int8)
    ks = jnp.asarray(rng.uniform(0.001, 0.02, size=(NB, bs, hkv)),
                     jnp.float32)
    vs = jnp.asarray(rng.uniform(0.001, 0.02, size=(NB, bs, hkv)),
                     jnp.float32)
    kt = jnp.asarray(rng.normal(size=(2, R * bs, hkv, dh)), jnp.float32)
    vt = jnp.asarray(rng.normal(size=(2, R * bs, hkv, dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, T, H, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(1, T, hkv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(1, T, hkv, dh)), jnp.float32)
    cache = {"k": kp, "v": vp, "k_scale": ks, "v_scale": vs,
             "k_tail": kt, "v_tail": vt,
             "block_tables": jnp.zeros((2, 6), jnp.int32)}
    ref = attend_paged_prefill_packed(q, kc, vc, cache, rows, tables, c0s,
                                      w_floors, q_offs, seg_ids)
    out = ops.paged_prefill_attention_packed_quant(
        q, kc, vc, kp, vp, ks, vs, kt[rows], vt[rows], tables,
        _desc(c0s, w_floors, q_offs, seg_ids, bs), interpret=True)
    for i in range(2):
        o, n = int(q_offs[i]), int(valids[i])
        np.testing.assert_allclose(np.asarray(out[0, o:o + n]),
                                   np.asarray(ref[0, o:o + n]), atol=1e-5)


@pytest.mark.parametrize("quant", [False, True], ids=["fp", "int8"])
def test_packed_writer_matches_per_segment_writes(quant):
    """The one fused packed scatter leaves the pool bitwise identical to
    per-segment ``paged_prefill_write`` calls on every NON-SENTINEL
    block.  (Both paths scribble chunk padding into sentinel block 0 —
    with different values, by design — so block 0 is excluded.)"""
    from repro.models.attention import (init_paged_kv_cache,
                                        paged_prefill_write,
                                        paged_prefill_write_packed)
    rng = np.random.default_rng(23)
    NB, bs, hkv, dh, T = 16, 8, 2, 16, 32
    rows, tables, c0s, w_floors, valids, q_offs, seg_ids = _two_segment_pack()
    kc = jnp.asarray(rng.normal(size=(1, T, hkv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(1, T, hkv, dh)), jnp.float32)

    def fresh():
        return init_paged_kv_cache(NB, bs, hkv, dh, jnp.float32,
                                   max_batch=2, max_blocks_per_seq=6,
                                   quant=quant)

    packed = paged_prefill_write_packed(fresh(), kc, vc, rows, tables, c0s,
                                        w_floors, valids, q_offs, seg_ids)
    serial = fresh()
    for i in range(2):
        o = int(q_offs[i])
        C = [16, 8][i]
        serial = paged_prefill_write(serial, kc[:, o:o + C], vc[:, o:o + C],
                                     int(rows[i]), tables[i], int(c0s[i]),
                                     int(w_floors[i]), int(valids[i]))
    for key in packed:
        if key == "block_tables":
            continue
        a, b = np.asarray(packed[key]), np.asarray(serial[key])
        if key in ("k_tail", "v_tail"):
            np.testing.assert_array_equal(a, b, err_msg=key)
        else:
            np.testing.assert_array_equal(a[1:], b[1:], err_msg=key)


# ---------------------------------------------------------------------------
# hypothesis: ragged segment descriptor invariants
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    class TestPackDescriptorProperty:
        @given(data=st.data(), n_segs=st.integers(1, 4),
               bs=st.sampled_from([4, 8]))
        @settings(max_examples=200, deadline=None)
        def test_pack_covers_exactly_once_block_aligned(self, data, n_segs,
                                                        bs):
            """For ANY admission mix: segments tile the packed buffer
            with no overlap and full coverage, every segment start is
            block-aligned, valid tokens land verbatim, the bucket is the
            smallest that fits, and the trailing pad segment is inert
            (row 0, all-sentinel table, zero valids)."""
            NBt = 8
            segs = []
            for s in range(n_segs):
                blocks = data.draw(st.integers(1, 4), label=f"blocks{s}")
                C = blocks * bs
                n_valid = data.draw(st.integers(1, C), label=f"valid{s}")
                c0 = bs * data.draw(st.integers(0, NBt - blocks),
                                    label=f"c0b{s}")
                w_floor = data.draw(st.integers(0, c0), label=f"wf{s}")
                toks = np.arange(1000 * s, 1000 * s + n_valid, dtype=np.int32)
                tbl = np.arange(1 + s * NBt, 1 + (s + 1) * NBt,
                                dtype=np.int32)
                segs.append((s, tbl, c0, w_floor, n_valid, C, toks))
            max_bucket = 4 * 4 * bs * n_segs
            buckets = sorted({bs * (1 << i) for i in range(12)
                              if bs * (1 << i) <= max_bucket}
                             | {max_bucket})
            pk = pack_admission_segments(segs, block_size=bs,
                                         buckets=buckets,
                                         max_segments=4, table_width=NBt)
            total = sum(C for *_, C, _t in segs)
            T = pk["tokens"].shape[1]
            assert T in buckets and T >= total
            assert T == min(b for b in buckets if b >= total)  # smallest fit
            # no overlap + full coverage: seg i owns exactly
            # [q_offs[i], q_offs[i] + C_i), pad owns the rest
            off = 0
            for i, (_row, _tbl, _c0, _wf, n_valid, C, toks) in \
                    enumerate(segs):
                assert pk["q_offs"][i] == off
                assert off % bs == 0                       # block-aligned
                np.testing.assert_array_equal(
                    pk["seg_ids"][off:off + C], i)
                np.testing.assert_array_equal(
                    pk["tokens"][0, off:off + n_valid], toks)
                assert pk["valids"][i] == n_valid
                off += C
            assert off == total
            np.testing.assert_array_equal(pk["seg_ids"][total:],
                                          len(segs))
            # pad segment is inert
            pad = len(segs)
            assert pk["rows"][pad] == 0 and pk["valids"][pad] == 0
            assert (pk["tables"][pad] == SENTINEL).all()
            assert pk["q_offs"][pad] == total
            # unused segment slots (between pad and max_segments) too
            for i in range(len(segs), 5):
                assert pk["valids"][i] == 0

        @given(total_blocks=st.integers(17, 64))
        @settings(max_examples=50, deadline=None)
        def test_pack_rejects_oversize(self, total_blocks):
            bs = 8
            toks = np.zeros((total_blocks * bs,), np.int32)
            seg = (0, np.full((4,), 1, np.int32), 0, 0, total_blocks * bs,
                   total_blocks * bs, toks)
            with pytest.raises(ValueError):
                pack_admission_segments([seg], block_size=bs,
                                        buckets=[8 * 16],
                                        max_segments=1, table_width=4)
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_pack_properties():
        pass


# ---------------------------------------------------------------------------
# satellites: SLO clocks, tenant quotas, cache-aware refill, penalties
# ---------------------------------------------------------------------------
def test_slo_timestamps_and_summary(stack):
    from repro.core.metrics import slo_summary
    eng = _paged(stack, prefill_mode="packed")
    reqs = _run(eng, [p for p, _ in REQUESTS])
    for r in reqs:
        assert r.enqueue_t > 0 and r.admit_t is not None
        assert r.queue_delay_s is not None and r.queue_delay_s >= 0.0
        assert r.first_token_t is not None
        assert r.first_token_t >= r.admit_t
    s = slo_summary([r.result for r in reqs], reqs,
                    ttft_slo_s=1e9, tpot_slo_s=1e9)
    assert s["slo_attainment"] == 1.0 and s["slo_samples"] == len(reqs)
    assert s["queue_delay_p95_s"] is not None
    tight = slo_summary([r.result for r in reqs], reqs, ttft_slo_s=0.0)
    assert tight["slo_attainment"] == 0.0
    # degenerate inputs: percentile/rate fields None (never NaN), count
    # fields zero/empty — everything json-safe
    empty = slo_summary([], [])
    assert empty["slo_samples"] == 0
    for k, v in empty.items():
        assert v is None or v == 0 or v == {}, (k, v)


def test_tenant_quota_denies_admit_not_serving(stack):
    """An over-quota tenant's requests still DECODE; only their L2
    admission is downgraded.  Other tenants are unaffected."""
    eng = _paged(stack, prefill_mode="packed", precache=None)
    sched = ContinuousBatchingScheduler(eng, tenant_quotas={"small": 1})
    store = eng.recycler.store
    # quota reads LIVE usage at admit time, so serve sequentially: the
    # first request's entry must land before the second is admitted
    reqs = []
    for p, _ in REQUESTS[:2]:
        reqs.append(sched.submit(p, tenant="small", admit=True))
        sched.run()
    eng.check_invariants()
    for r in reqs:
        assert r.result is not None and r.error is None
    # first admit landed (usage was 0 < quota), second was denied
    assert store.tenant_usage("small") > 0
    assert len(store) == 1
    assert sched.stats["quota_denied_admits"] == 1
    other = sched.submit(REQUESTS[2][0], tenant="big", admit=True)
    sched.run()
    assert other.result is not None
    assert store.tenant_usage("big") > 0


def test_cache_aware_refill_prefers_resident_prefix(stack):
    """With a warm trie, cache_aware admission picks the queued request
    with the deepest resident prefix ahead of arrival order; an all-cold
    queue degenerates to exact FIFO."""
    eng = _paged(stack, prefill_mode="packed", max_batch=1, precache=None)
    warm = CACHED[0]
    sched = ContinuousBatchingScheduler(eng, admission_policy="cache_aware")
    sched.submit(warm)
    sched.run()                                     # warm the trie
    cold1 = sched.submit("zzz cold request number one")
    hot = sched.submit(warm + " plus a warm suffix")
    cold2 = sched.submit("another cold request entirely")
    sched.run()
    eng.check_invariants()
    assert sched.stats["cache_aware_picks"] >= 1
    assert hot.admit_t < cold1.admit_t              # warm jumped the queue
    assert cold1.admit_t < cold2.admit_t            # cold ties stay FIFO
    # identical outputs to plain FIFO ordering on a fresh engine
    eng2 = _paged(stack, prefill_mode="packed", max_batch=1, precache=None)
    reqs = _run(eng2, [warm, cold1.prompt, hot.prompt, cold2.prompt])
    for a, b in zip((cold1, hot, cold2), reqs[1:]):
        np.testing.assert_array_equal(a.result.token_ids,
                                      b.result.token_ids)


def test_sampling_penalties_rowwise():
    """Per-row repetition/presence penalties: one fused scatter over each
    row's generated set; zero-penalty rows are BIT-identical to the
    un-penalised path (greedy included); -1 padding is inert."""
    from repro.serving.sampling import (apply_penalties, greedy,
                                        sample_batched)
    rng = jax.random.PRNGKey(3)
    logits = jnp.asarray(np.random.default_rng(5).normal(size=(3, 24)),
                         jnp.float32)
    gen = jnp.asarray([[1, 2, -1, -1], [0, 23, 5, 5], [-1, -1, -1, -1]],
                      jnp.int32)
    # statically inert -> transform skipped, greedy bit-identical
    np.testing.assert_array_equal(
        np.asarray(sample_batched(logits, rng, temperature=0.0)),
        np.asarray(sample_batched(logits, rng, temperature=0.0,
                                  repetition_penalty=1.0,
                                  presence_penalty=0.0, gen_tokens=gen)))
    # per-row: row 1 penalised only; row 2 all-pad -> untouched
    rp = jnp.asarray([1.0, 3.0, 3.0], jnp.float32)
    out = apply_penalties(logits, gen, repetition_penalty=rp,
                          presence_penalty=jnp.asarray([0., .5, .5]))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(logits[0]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(logits[2]))
    for tok in (0, 5, 23):
        assert float(out[1, tok]) < float(logits[1, tok])
    untouched = [t for t in range(24) if t not in (0, 5, 23)]
    np.testing.assert_array_equal(np.asarray(out[1, untouched]),
                                  np.asarray(logits[1, untouched]))
    # a strong repetition penalty steers greedy off its repeated argmax
    g0 = greedy(logits)
    g1 = sample_batched(logits, rng, temperature=0.0,
                        repetition_penalty=100.0,
                        gen_tokens=jnp.tile(g0[:, None], (1, 4)))
    assert not np.array_equal(np.asarray(g0), np.asarray(g1))
