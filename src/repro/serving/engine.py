"""Serving engine: tokenizer -> recycler -> prefill(suffix) -> decode loop.

This is the paper's evaluation loop (§4.4) as a production surface:

  baseline run    engine.generate(p, use_recycling=False)
  cache build     engine.precache(prompts)          # §4.4 "Cache Construction"
  recycled run    engine.generate(p)                # retrieval + prefix test
                                                    # + past_key_values reuse

plus what the paper doesn't have: capacity-bucketed cache allocation (stable
jit signatures), automatic admission of finished generations (multi-turn
prefix reuse), block-radix partial hits, and byte-budget LRU eviction.

Latency accounting mirrors §4.5: wall time around the whole generate call
with ``block_until_ready`` as the synchronize analogue, reuse depth k, and
prompt similarity from the retrieval stage.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import HashEmbedder, Recycler
from repro.core import quant
from repro.core.kvstore import to_host
from repro.core.recycler import (grow_capacity, is_trimmable,
                                 shrink_capacity, trim_to_depth)
from repro.data.tokenizer import ByteTokenizer, EOS
from repro.models import decode_step, init_cache, prefill
from repro.runtime import Runtime, LOCAL
from repro.serving.sampling import greedy, sample_batched, sample_logits


@dataclass
class GenResult:
    text: str
    token_ids: np.ndarray
    latency_s: float
    prompt_tokens: int
    gen_tokens: int
    reuse_depth: int = 0
    cache_hit: bool = False
    mode: str = "baseline"
    prompt_similarity: float = 0.0
    # admission latency: seconds from admission start to the FIRST sampled
    # token (the paper's latency metric isolates prefill cost; this is its
    # per-request serving analogue, what the chunked-admission path exists
    # to shrink).  0.0 when the engine predates the measurement.
    ttft_s: float = 0.0
    # per-decode-step wall times (TPOT samples): one entry per emitted
    # token after the first.  A speculative round that emits a burst of
    # k tokens records k entries of round_time / k — honest per-token
    # latency, so accepted drafts show up as LOWER TPOT, not as gaps.
    step_times_s: list = field(default_factory=list)
    # pressure-safe serving (paged engine): how often this request was
    # preempted + resumed, and how many positions its resumes had to
    # re-prefill — 0/0 on engines without preemption
    preemptions: int = 0
    tokens_recomputed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, *,
                 tokenizer: Optional[ByteTokenizer] = None,
                 recycler: Optional[Recycler] = None,
                 enable_partial: bool = False,
                 block_size: int = 64,
                 max_new_tokens: int = 32,
                 window: int = 0,
                 compress_host_cache: bool = False,
                 compress_residual: Optional[int] = None,
                 kv_quant: bool = False,
                 semantic: bool = False,
                 graft_min_agree: float = 1.0,
                 graft_boundary_blocks: int = 1,
                 sample_seed: int = 0,
                 rt: Runtime = LOCAL):
        self.cfg = cfg
        self.params = params
        self._sample_key = jax.random.PRNGKey(sample_seed)
        self.tok = tokenizer or ByteTokenizer(cfg.vocab_size)
        if compress_residual is None:
            compress_residual = quant.DEFAULT_RESIDUAL
        self.recycler = recycler or Recycler(
            embedder=HashEmbedder(), enable_partial=enable_partial,
            block_size=block_size, compress=compress_host_cache,
            compress_residual=compress_residual, semantic=semantic,
            graft_min_agree=graft_min_agree,
            graft_boundary_blocks=graft_boundary_blocks)
        self.block = block_size
        self.max_new = max_new_tokens
        self.window = window
        self.kv_quant = kv_quant
        self.rt = rt
        self._prefill_fn = jax.jit(
            lambda p, t, c, sp: prefill(cfg, p, t, c, start_pos=sp,
                                        window=window, rt=rt))
        self._decode_fn = jax.jit(
            lambda p, t, c, pos: decode_step(cfg, p, t, c, pos,
                                             window=window, rt=rt))
        self.stats = {"requests": 0, "hits": 0, "tokens_reused": 0,
                      "tokens_prefilled": 0}

    # ------------------------------------------------------------------
    def _capacity(self, n: int) -> int:
        return ((n + self.block - 1) // self.block) * self.block

    def _make_cache(self, capacity: int):
        return init_cache(self.cfg, 1, capacity, window=self.window,
                          dtype=jnp.dtype(self.cfg.dtype),
                          kv_quant=self.kv_quant)

    # ------------------------------------------------------------------
    def precache(self, prompts, lengths: Optional[Dict[str, int]] = None):
        """Paper §4.4 cache construction: one forward pass per cache prompt
        with caching enabled; serialize to host and index by embedding."""
        for p in prompts:
            ids = self.tok.encode(p)
            cap = self._capacity(len(ids) + self.max_new)
            cache = self._make_cache(cap)
            _, cache = self._prefill_fn(self.params, jnp.asarray(ids)[None],
                                        cache, 0)
            self.recycler.admit(p, ids, to_host(cache), len(ids), cap)

    # ------------------------------------------------------------------
    def generate(self, prompt: str, *, max_new_tokens: Optional[int] = None,
                 use_recycling: bool = True, admit: bool = False,
                 stop_at_eos: bool = True, temperature: float = 0.0,
                 top_k: int = 0,
                 tenant: Optional[str] = None) -> GenResult:
        max_new = max_new_tokens or self.max_new
        t0 = time.perf_counter()
        ids = self.tok.encode(prompt)
        m = len(ids)
        cap = self._capacity(m + max_new)
        # greedy (the paper's do_sample=False) unless this request opted in;
        # the key is folded per request AND per position -> deterministic
        # replays without coupling requests to each other
        if temperature > 0.0:
            req_key = jax.random.fold_in(self._sample_key,
                                         self.stats["requests"])
            pick = lambda lg, p: sample_logits(
                lg, jax.random.fold_in(req_key, p),
                temperature=temperature, top_k=top_k)
        else:
            pick = lambda lg, p: greedy(lg)

        depth, hit, mode, sim = 0, False, "baseline", 0.0
        if use_recycling:
            res = self.recycler.lookup(prompt, ids)
            sim = res.similarity
            if res.hit:
                depth, hit, mode = res.reuse_depth, True, res.mode
                host_cache = grow_capacity(res.cache, cap)
                cache = jax.tree.map(jnp.asarray, host_cache)
            else:
                mode = "miss"
        if not hit:
            cache = self._make_cache(cap)

        suffix = jnp.asarray(ids[depth:])[None]
        logits, cache = self._prefill_fn(self.params, suffix,
                                         cache, depth)
        out_ids = []
        tok = pick(logits, m)[:, None]
        jax.block_until_ready(tok)
        ttft = time.perf_counter() - t0
        pos = m
        step_times: List[float] = []
        t_prev = time.perf_counter()
        for k in range(max_new):
            # int() forces the step's value: the wall time since t_prev
            # covers exactly one decode step + sample (a TPOT sample);
            # the first iteration is the prefill->token gap (TTFT), not
            # a decode step, and is excluded
            out_ids.append(int(tok[0, 0]))
            if k:
                step_times.append(time.perf_counter() - t_prev)
            t_prev = time.perf_counter()
            if stop_at_eos and out_ids[-1] == EOS:
                break
            logits, cache = self._decode_fn(self.params, tok, cache,
                                            jnp.int32(pos))
            tok = pick(logits, pos + 1)[:, None]
            pos += 1
        jax.block_until_ready(logits)
        latency = time.perf_counter() - t0

        all_ids = np.concatenate([ids, np.asarray(out_ids, np.int32)])
        if admit:
            host = to_host(cache)
            if is_trimmable(host):
                # admit at PROMPT depth: future prompts extending this one
                # (without the generated reply) still pass the exact-prefix
                # test; generated positions are masked out.
                self.recycler.admit(prompt, ids, trim_to_depth(host, m),
                                    m, cap, tenant=tenant)
            else:
                # recurrent state can't rewind: admit the full trajectory
                self.recycler.admit(prompt, all_ids, host, len(all_ids), cap,
                                    tenant=tenant)

        self.stats["requests"] += 1
        self.stats["hits"] += int(hit)
        self.stats["tokens_reused"] += depth
        self.stats["tokens_prefilled"] += m - depth
        return GenResult(
            text=self.tok.decode(out_ids),
            token_ids=all_ids,
            latency_s=latency,
            prompt_tokens=m,
            gen_tokens=len(out_ids),
            reuse_depth=depth,
            cache_hit=hit,
            mode=mode if use_recycling else "baseline",
            prompt_similarity=sim,
            ttft_s=ttft,
            step_times_s=step_times,
        )

    # ------------------------------------------------------------------
    def warmup(self, prompt: str, *, max_new_tokens: Optional[int] = None,
               use_recycling: bool = True) -> None:
        """Compile the shapes a subsequent timed call will use (the paper's
        T4 runs have no compile step; jit does — exclude it from latency)."""
        self.generate(prompt, max_new_tokens=max_new_tokens,
                      use_recycling=use_recycling, admit=False)


# ---------------------------------------------------------------------------
# continuous batching: slot-based KV pool
# ---------------------------------------------------------------------------
@dataclass
class _Slot:
    """Host-side record for one in-flight request occupying a pool row."""
    prompt: str
    ids: np.ndarray              # prompt token ids
    m: int                       # prompt length
    max_new: int
    use_recycling: bool
    admit: bool
    stop_at_eos: bool
    depth: int
    hit: bool
    mode: str
    sim: float
    emitted: list = field(default_factory=list)
    t0: float = 0.0
    t_first: float = 0.0         # when the first token was sampled (TTFT)
    temperature: float = 0.0     # 0 = greedy (the paper's do_sample=False)
    top_k: int = 0
    step_times_s: list = field(default_factory=list)  # TPOT samples
    tenant: Optional[str] = None  # labels admitted host entries (quotas)
    # preemption bookkeeping (paged engine): tokens already emitted before
    # this slot was demoted + resumed, re-derived through warm admission.
    # ``resume_emitted`` is prepended to the row's fresh output, and the
    # per-request counters below survive across preempt/resume cycles so
    # observability sees the whole request, not just its last residency.
    resume_emitted: list = field(default_factory=list)
    preemptions: int = 0
    tokens_recomputed: int = 0
    deadline_t: Optional[float] = None  # absolute deadline (victim tiebreak)


def _pool_load_row(pool, row, slot, tokens, pos, tok0, m):
    """Scatter a single-request cache (standard layout: slot_pos (L, C),
    k/v (L, 1, C, ...)) into pool row ``slot`` and prime its token/pos."""
    def walk(pl, rw, name=None):
        if isinstance(pl, dict):
            return {k: walk(pl[k], rw[k], k) for k in pl}
        if name == "slot_pos":
            return pl.at[:, slot].set(rw)
        return pl.at[:, slot].set(rw[:, 0])
    return (walk(pool, row), tokens.at[slot].set(tok0),
            pos.at[slot].set(m))


def _pool_read_row(pool, slot):
    """Gather pool row ``slot`` back into the single-request cache layout
    (what the recycler stores and ``prefill`` consumes)."""
    def walk(pl, name=None):
        if isinstance(pl, dict):
            return {k: walk(pl[k], k) for k in pl}
        if name == "slot_pos":
            return pl[:, slot]
        return pl[:, slot][:, None]
    return walk(pool)


def _donor_width(cache) -> int:
    """Widest attention slot axis in a host cache pytree (0 if none) —
    what has to fit a pool row, since buffers can grow but never shrink."""
    w = 0
    def walk(t, name=None):
        nonlocal w
        if isinstance(t, dict):
            for k, v in t.items():
                walk(v, k)
        elif name == "slot_pos":
            w = max(w, t.shape[-1])
    walk(cache)
    return w


class BatchedEngine(Engine):
    """Continuous-batching engine over a slot-based KV pool.

    The pool is one per-slot cache pytree ``init_cache(cfg, max_batch,
    capacity, per_slot=True)``: row b holds request b's KVs with its own
    ``slot_pos`` row, so one jitted ``decode_step`` call advances every
    in-flight request by a token regardless of their (different) depths.

    Admission is a single-row prefill — exactly the serial engine's path,
    including the recycler lookup, so a batch freely mixes exact-prefix
    hits, partial-block hits, and cold misses — followed by one scatter of
    that row into the pool.  Finished rows (EOS or token budget) are freed
    at the step boundary; the scheduler refills them from its queue, which
    is what makes the batch *continuous* rather than lockstep.

    Invariants (tested in tests/test_slot_pool.py):
      * rows never attend across slots — masking is per-row ``slot_pos``;
      * a row's decoded tokens are identical to a serial ``generate`` of
        the same request (greedy; tests/test_scheduler_batching.py);
      * freed rows need no scrubbing — admission overwrites the whole row,
        and stale slots stay masked because slot_pos is overwritten too.

    Trunk-attention architectures only (GQA/MHA; no MLA, recurrent state,
    or enc-dec rows — those can't be sliced per slot).
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 capacity: int = 256, **kw):
        super().__init__(cfg, params, **kw)
        self.max_batch = max_batch
        self.capacity = capacity
        # actual slot-axis width of a pool row (ring width when windowed)
        self._eff_cap = min(self.window, capacity) if self.window else capacity
        # validates the arch supports per-slot pooling (raises otherwise)
        self.pool = init_cache(cfg, max_batch, capacity, window=self.window,
                               dtype=jnp.dtype(cfg.dtype),
                               kv_quant=self.kv_quant, per_slot=True)
        self._tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self._pos = jnp.zeros((max_batch,), jnp.int32)
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        # per-row sampling controls (0 temperature = greedy row); kept on
        # host so the all-greedy fast path costs no rng or sort work
        self._temp = np.zeros((max_batch,), np.float32)
        self._topk = np.zeros((max_batch,), np.int32)
        self._step_rng = self._sample_key
        # donate pool/tokens/pos: the step rewrites a handful of slots, so
        # without donation every decode step memcpys the whole pool
        self._load_fn = jax.jit(_pool_load_row, donate_argnums=(0, 3, 4))
        self._read_fn = jax.jit(_pool_read_row)
        self._bstep_fn = jax.jit(self._batched_step, donate_argnums=(1, 2, 3))
        self._bstep_sampled_fn = jax.jit(self._batched_step_sampled,
                                         donate_argnums=(1, 2, 3),
                                         static_argnums=(7,))
        self.stats.update({"batched_decode_steps": 0, "oversize_skips": 0,
                           "admissions": 0, "sampled_steps": 0})

    def _batched_step(self, params, tokens, pool, pos):
        # greedy is looked up at trace time on purpose: tests substitute it
        # to force early EOS in both the serial and batched paths
        logits, pool = decode_step(self.cfg, params, tokens, pool, pos,
                                   window=self.window, rt=self.rt)
        nxt = greedy(logits)                      # (B,)
        return nxt, nxt[:, None], pool, pos + 1

    def _batched_step_sampled(self, params, tokens, pool, pos, temp, topk,
                              rng, topk_cap):
        """Mixed-policy step: rows with temperature > 0 draw from their
        per-row categorical (per-row dynamic top-k), rows at 0 stay
        greedy — one dispatch either way (ROADMAP open item).
        ``topk_cap`` (static) is the batch's max requested k, so no row's
        distribution is silently narrowed by a fixed cap."""
        logits, pool = decode_step(self.cfg, params, tokens, pool, pos,
                                   window=self.window, rt=self.rt)
        nxt = sample_batched(logits, rng, temperature=temp, top_k=topk,
                             top_k_cap=topk_cap)
        return nxt, nxt[:, None], pool, pos + 1

    def _advance(self):
        """One decode step over the pool, dispatching the greedy or the
        sampled executable depending on whether any row samples."""
        if np.any(self._temp > 0.0):
            self._step_rng, sub = jax.random.split(self._step_rng)
            self.stats["sampled_steps"] += 1
            return self._bstep_sampled_fn(
                self.params, self._tokens, self.pool, self._pos,
                jnp.asarray(self._temp), jnp.asarray(self._topk), sub,
                max(int(self._topk.max()), 1))
        return self._bstep_fn(self.params, self._tokens, self.pool,
                              self._pos)

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    # ------------------------------------------------------------------
    def admit_slot(self, slot: int, prompt: str, *,
                   max_new_tokens: Optional[int] = None,
                   use_recycling: bool = True, admit: bool = False,
                   stop_at_eos: bool = True, temperature: float = 0.0,
                   top_k: int = 0,
                   tenant: Optional[str] = None) -> Optional[GenResult]:
        """Prefill ``prompt`` into pool row ``slot`` (recycled prefix when
        available).  Returns a GenResult immediately — leaving the slot
        free — iff the request finishes at its very first token."""
        if self._slots[slot] is not None:
            raise ValueError(f"slot {slot} is occupied")
        max_new = max_new_tokens or self.max_new
        t0 = time.perf_counter()
        ids = self.tok.encode(prompt)
        m = len(ids)
        if m + max_new > self.capacity:
            raise ValueError(f"request needs {m + max_new} positions; pool "
                             f"capacity is {self.capacity}")

        depth, hit, mode, sim = 0, False, "baseline", 0.0
        if use_recycling:
            res = self.recycler.lookup(prompt, ids)
            sim = res.similarity
            if res.hit and _donor_width(res.cache) > self._eff_cap:
                # cached buffers can't shrink into a pool row; honest miss
                self.stats["oversize_skips"] += 1
                mode = "miss"
            elif res.hit:
                depth, hit, mode = res.reuse_depth, True, res.mode
                cache = jax.tree.map(
                    jnp.asarray, grow_capacity(res.cache, self._eff_cap))
            else:
                mode = "miss"
        if not hit:
            cache = self._make_cache(self.capacity)

        suffix = jnp.asarray(ids[depth:])[None]
        logits, cache = self._prefill_fn(self.params, suffix, cache, depth)
        if temperature > 0.0:
            self._step_rng, sub = jax.random.split(self._step_rng)
            tok0 = sample_logits(logits, sub, temperature=temperature,
                                 top_k=top_k)
        else:
            tok0 = greedy(logits)                 # (1,)

        self.stats["requests"] += 1
        self.stats["hits"] += int(hit)
        self.stats["tokens_reused"] += depth
        self.stats["tokens_prefilled"] += m - depth
        self.stats["admissions"] += 1

        st = _Slot(prompt, ids, m, max_new, use_recycling, admit,
                   stop_at_eos, depth, hit, mode, sim,
                   emitted=[int(tok0[0])], t0=t0,
                   t_first=time.perf_counter(),
                   temperature=temperature, top_k=top_k, tenant=tenant)
        if (st.stop_at_eos and st.emitted[0] == EOS) or max_new == 1:
            # finished at the first token: never occupies the pool
            return self._result(st, host_cache=lambda: to_host(cache))
        self.pool, self._tokens, self._pos = self._load_fn(
            self.pool, cache, jnp.int32(slot), self._tokens, self._pos,
            tok0, jnp.int32(m))
        self._slots[slot] = st
        self._temp[slot] = temperature
        self._topk[slot] = top_k
        return None

    # ------------------------------------------------------------------
    def decode_batch(self) -> List[Tuple[int, GenResult]]:
        """One masked decode step over the whole pool (single jit dispatch).
        Appends each active row's next token; returns the (slot, result)
        pairs of rows that finished — their slots are freed for the
        scheduler to refill before the next step."""
        active = self.active_slots()
        if not active:
            return []
        t_step = time.perf_counter()
        nxt, self._tokens, self.pool, self._pos = self._advance()
        toks = np.asarray(nxt)
        dt_step = time.perf_counter() - t_step
        self.stats["batched_decode_steps"] += 1
        done: List[Tuple[int, GenResult]] = []
        for i in active:
            st = self._slots[i]
            st.emitted.append(int(toks[i]))
            st.step_times_s.append(dt_step)
            if ((st.stop_at_eos and st.emitted[-1] == EOS)
                    or len(st.emitted) >= st.max_new):
                done.append((i, self._result(
                    st, host_cache=lambda i=i: to_host(
                        self._read_fn(self.pool, jnp.int32(i))))))
                self._slots[i] = None
                self._temp[i] = 0.0
                self._topk[i] = 0
        return done

    # ------------------------------------------------------------------
    def _result(self, st: _Slot, host_cache) -> GenResult:
        all_ids = np.concatenate([st.ids, np.asarray(st.emitted, np.int32)])
        if st.admit:
            host = trim_to_depth(host_cache(), st.m)
            # per-slot pools exist only for trunk attention, so the row is
            # always trimmable: admit at prompt depth like the serial path.
            # Shrink the row back to the serial path's bucketed width so the
            # host store doesn't pay pool-capacity bytes per entry (safe:
            # unwrapped slots hold slot == position; ring rows can't shrink)
            cap = self._capacity(st.m + st.max_new)
            if not self.window and cap < self._eff_cap:
                host = shrink_capacity(host, cap)
            else:
                cap = self.capacity
            self.recycler.admit(st.prompt, st.ids, host, st.m, cap,
                                tenant=st.tenant)
        return GenResult(
            text=self.tok.decode(st.emitted),
            token_ids=all_ids,
            latency_s=time.perf_counter() - st.t0,
            prompt_tokens=st.m,
            gen_tokens=len(st.emitted),
            reuse_depth=st.depth,
            cache_hit=st.hit,
            mode=st.mode if st.use_recycling else "baseline",
            prompt_similarity=st.sim,
            ttft_s=max(st.t_first - st.t0, 0.0),
            step_times_s=list(st.step_times_s),
        )
