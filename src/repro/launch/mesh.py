"""Production mesh definition.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS for 512 host devices *before* any
jax import; tests and benches see the real single CPU device).

  single pod:  16 x 16            axes (data, model)   = 256 chips (v5e pod)
  multi pod:   2 x 16 x 16        axes (pod, data, model) = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def serving_meshes(replicas: int, tp: int, devices=None):
    """Per-replica (data=1, model=tp) sub-meshes for the mesh-sharded
    paged server: replica r owns devices [r*tp, (r+1)*tp) and its engine
    never communicates with another replica's devices (data parallelism
    is N independent engines over a shared host L2, not a batch axis).

    Smoke/CI runs get their devices from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    jax import, like the dry-run)."""
    import numpy as np
    from jax.sharding import Mesh

    devices = list(jax.devices()) if devices is None else list(devices)
    need = replicas * tp
    if len(devices) < need:
        raise ValueError(
            f"mesh {replicas}x{tp} needs {need} devices, have "
            f"{len(devices)} (forced host devices require XLA_FLAGS "
            f"before jax import)")
    return [Mesh(np.array(devices[r * tp:(r + 1) * tp]).reshape(1, tp),
                 ("data", "model")) for r in range(replicas)]


# TPU v5e hardware constants (per chip) for the roofline terms.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (~; used for the collective term)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB
