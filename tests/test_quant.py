"""Properties of the shared int8 quantization module (core/quant.py) and
the HostKVStore fixes that ride with it: bounded round-trip error, bit-exact
NO_COMPRESS leaves, exactly-fp residual tails, idempotence, byte-accounting
invariants, and the save/load budget + tier-state round trip (including a
bit-exact quantized-entry npz round trip).

Hypothesis-based property tests are guarded like the rest of the suite so
the tier-1 job (no optional deps) still runs the deterministic cases.
"""
import tempfile

import numpy as np
import pytest

from repro.core import quant
from repro.core.kvstore import (CacheEntry, HostKVStore, dequantize_tree,
                                flatten_cache, is_quantized, quantize_tree,
                                tree_bytes, unflatten_cache)
from repro.core.recycler import Recycler, grow_capacity

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _attn_tree(rng, cap=32, length=None, hkv=2, dh=8, layers=2):
    sp = np.arange(cap, dtype=np.int32)
    if length is not None:
        sp = np.where(sp < length, sp, -1).astype(np.int32)
    return {"seg0": {
        "k": rng.standard_normal((layers, 1, cap, hkv, dh)).astype(np.float32),
        "v": rng.standard_normal((layers, 1, cap, hkv, dh)).astype(np.float32),
        "slot_pos": np.broadcast_to(sp, (layers, cap)).copy(),
    }}


# ---------------------------------------------------------------------------
# quantize_tree / dequantize_tree
# ---------------------------------------------------------------------------
def test_residual_tail_exactly_fp():
    rng = np.random.default_rng(0)
    cap, length, residual = 32, 25, 8
    tree = _attn_tree(rng, cap, length)
    q = quant.quantize_tree(tree, length=length, residual=residual)
    back = quant.dequantize_tree(q)
    split = length - residual
    for name in ("k", "v"):
        a, b = tree["seg0"][name], back["seg0"][name]
        # residual tail [split, length): bit-exact
        np.testing.assert_array_equal(a[:, :, split:length],
                                      b[:, :, split:length])
        # quantized region [0, split): small but nonzero error
        assert not np.array_equal(a[:, :, :split], b[:, :, :split])
        rel = (np.sqrt(np.mean((a[:, :, :split] - b[:, :, :split]) ** 2))
               / np.sqrt(np.mean(a[:, :, :split] ** 2)))
        assert rel < 0.01, rel
        # truncated invalid region [length, cap): reconstructed as zeros
        np.testing.assert_array_equal(b[:, :, length:], 0)
        assert b.shape == a.shape and b.dtype == a.dtype


def test_no_compress_leaves_bit_identical():
    rng = np.random.default_rng(1)
    tree = _attn_tree(rng, 16, 10)
    tree["seg0"]["k_scale"] = rng.standard_normal((2, 1, 16, 2)).astype(
        np.float32)
    q = quant.quantize_tree(tree, length=10, residual=4)
    np.testing.assert_array_equal(q["seg0"]["slot_pos"],
                                  tree["seg0"]["slot_pos"])
    np.testing.assert_array_equal(q["seg0"]["k_scale"],
                                  tree["seg0"]["k_scale"])
    assert not isinstance(q["seg0"]["k_scale"], dict)


def test_quantize_tree_idempotent():
    rng = np.random.default_rng(2)
    tree = _attn_tree(rng, 16, 12)
    q = quant.quantize_tree(tree, length=12, residual=4)
    assert is_quantized(q)
    assert quant.quantize_tree(q) is q            # never double-quantized
    assert quant.quantize_tree(q, length=12, residual=4) is q


def test_leaf_without_capacity_axis_quantized_whole():
    # recurrent state has no token axis; residual/length must not apply
    rng = np.random.default_rng(3)
    tree = {"wkv_state": rng.standard_normal((2, 4, 8, 8)).astype(np.float32)}
    q = quant.quantize_tree(tree, length=4, residual=2)
    assert quant._QKEY in q["wkv_state"] and "cap" not in q["wkv_state"]
    back = quant.dequantize_tree(q)
    assert back["wkv_state"].shape == tree["wkv_state"].shape


def test_truncation_cuts_bytes_further():
    rng = np.random.default_rng(4)
    tree = _attn_tree(rng, 64, 20)
    q_full = quant.quantize_tree(tree)
    q_trunc = quant.quantize_tree(tree, length=20, residual=8)
    assert tree_bytes(q_trunc) < tree_bytes(q_full)
    assert tree_bytes(tree) / tree_bytes(q_trunc) > 3.0


def test_npz_roundtrip_of_quantized_entry_bit_exact():
    """Disk round trip must preserve __q8__/scale/dtype/tail leaves
    bit-exactly (int8 codes are NOT re-derivable from a lossy copy)."""
    rng = np.random.default_rng(5)
    tree = _attn_tree(rng, 32, 30)
    q = quant.quantize_tree(tree, length=30, residual=8)
    store = HostKVStore()
    e = store.put("p", np.arange(30), q, 30, 32)
    with tempfile.TemporaryDirectory() as d:
        store.save_dir(d)
        loaded = HostKVStore.load_dir(d)
    lq = loaded.get(e.entry_id).cache
    for name in ("k", "v"):
        a, b = q["seg0"][name], lq["seg0"][name]
        np.testing.assert_array_equal(a[quant._QKEY], b[quant._QKEY])
        assert b[quant._QKEY].dtype == np.int8
        np.testing.assert_array_equal(a["scale"], b["scale"])
        np.testing.assert_array_equal(a["tail"], b["tail"])
        assert str(np.asarray(b["dtype"])) == str(a["dtype"])
    np.testing.assert_array_equal(
        dequantize_tree(q)["seg0"]["k"], dequantize_tree(lq)["seg0"]["k"])


def test_flatten_unflatten_quantized():
    rng = np.random.default_rng(6)
    q = quant.quantize_tree(_attn_tree(rng, 16, 12), length=12, residual=4)
    flat = flatten_cache(q)
    back = unflatten_cache(flat)
    np.testing.assert_array_equal(
        dequantize_tree(q)["seg0"]["v"], dequantize_tree(back)["seg0"]["v"])


def test_grow_capacity_after_dequantize():
    """The recycler's resize surgery composes with quantization: dequant
    reconstructs full capacity, then grow pads it like any host cache."""
    rng = np.random.default_rng(7)
    tree = _attn_tree(rng, 16, 12)
    q = quant.quantize_tree(tree, length=12, residual=4)
    g = grow_capacity(dequantize_tree(q), 32)
    assert g["seg0"]["k"].shape[2] == 32
    assert (g["seg0"]["slot_pos"][:, 16:] == -1).all()


# ---------------------------------------------------------------------------
# byte accounting
# ---------------------------------------------------------------------------
def test_tree_bytes_counts_metadata_leaves():
    rng = np.random.default_rng(8)
    q = quant.quantize_tree(_attn_tree(rng, 16, 12), length=12, residual=4)
    leaf = q["seg0"]["k"]
    meta = (np.asarray(leaf["dtype"]).nbytes + np.asarray(leaf["cap"]).nbytes
            + np.asarray(leaf["ax"]).nbytes)
    assert meta > 0
    assert tree_bytes({"k": leaf}) == (leaf[quant._QKEY].nbytes
                                       + leaf["scale"].nbytes
                                       + leaf["tail"].nbytes + meta)


def test_nbytes_reflects_post_quantization_size():
    rng = np.random.default_rng(9)
    rec = Recycler(compress=True, compress_residual=4)
    tree = _attn_tree(rng, 32, 28)
    e = rec.admit("p", np.arange(28), tree, 28, 32)
    assert is_quantized(e.cache)
    assert e.nbytes == tree_bytes(e.cache) < tree_bytes(tree)
    assert rec.store.total_bytes == e.nbytes


def test_admit_per_entry_compress_toggle_still_evicts():
    """Eviction must fire on admit regardless of the per-entry compress
    override, and total_bytes must track post-quantization sizes."""
    rng = np.random.default_rng(10)
    probe = tree_bytes(_attn_tree(rng, 32, 28))
    rec = Recycler(store=HostKVStore(max_bytes=int(probe * 2.5)),
                   compress=False, compress_residual=4)
    for i, compress in enumerate([True, False, True, False, True]):
        rec.admit(f"p{i}", np.arange(28), _attn_tree(rng, 32, 28), 28, 32,
                  compress=compress)
        assert rec.store.total_bytes <= rec.store.max_bytes
        assert rec.store.total_bytes == sum(
            rec.store.get(i_, touch=False).nbytes for i_ in rec.store.ids())
    assert rec.store.evictions > 0
    kinds = {is_quantized(rec.store.get(i_, touch=False).cache)
             for i_ in rec.store.ids()}
    assert kinds == {True, False}                 # both layouts coexist


# ---------------------------------------------------------------------------
# save/load budget + tier state
# ---------------------------------------------------------------------------
def test_load_dir_enforces_budget():
    rng = np.random.default_rng(11)
    store = HostKVStore()                          # unbounded at save time
    for i in range(4):
        store.put(f"p{i}", np.arange(8), _attn_tree(rng, 16, 8), 8, 16)
    per_entry = store.total_bytes // 4
    with tempfile.TemporaryDirectory() as d:
        store.save_dir(d)
        loaded = HostKVStore.load_dir(d, max_bytes=int(per_entry * 2.5))
    assert loaded.total_bytes <= loaded.max_bytes
    assert len(loaded) == 2 and loaded.evictions == 2
    # LRU order persisted: the two NEWEST entries survive
    assert loaded.ids() == store.ids()[-2:]


def test_load_dir_restores_tier_state():
    rng = np.random.default_rng(12)
    store = HostKVStore()
    a = store.put("a", np.arange(8), _attn_tree(rng, 16, 8), 8, 16)
    b = store.put("b", np.arange(8), _attn_tree(rng, 16, 8), 8, 16)
    store.get(a.entry_id)                          # touch: a becomes MRU
    store.get(a.entry_id)
    with tempfile.TemporaryDirectory() as d:
        store.save_dir(d)
        loaded = HostKVStore.load_dir(d)
    la = loaded.get(a.entry_id, touch=False)
    lb = loaded.get(b.entry_id, touch=False)
    assert (la.hits, la.last_hit) == (2, 2)
    assert (lb.hits, lb.last_hit) == (0, -1)
    assert loaded._clock == store._clock == 2
    assert loaded.ids() == store.ids()             # LRU order: b then a


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=40)
    @given(cap=st.integers(2, 24), dh=st.integers(2, 16),
           length=st.integers(0, 30), residual=st.integers(0, 30),
           seed=st.integers(0, 2 ** 16))
    def test_roundtrip_bounded_error_property(cap, dh, length, residual,
                                              seed):
        rng = np.random.default_rng(seed)
        tree = _attn_tree(rng, cap, min(length, cap), dh=dh)
        q = quant.quantize_tree(tree, length=length, residual=residual)
        back = quant.dequantize_tree(q)
        n = min(length, cap)
        split = max(0, n - residual)
        for name in ("k", "v"):
            a, b = tree["seg0"][name], back["seg0"][name]
            assert b.shape == a.shape and b.dtype == a.dtype
            np.testing.assert_array_equal(a[:, :, split:n], b[:, :, split:n])
            np.testing.assert_array_equal(b[:, :, n:], 0)
            if split:
                err = np.abs(a[:, :, :split] - b[:, :, :split])
                bound = np.max(np.abs(a[:, :, :split]),
                               axis=-1, keepdims=True) / 127.0 + 1e-6
                assert (err <= bound).all()        # per-vector step bound
        np.testing.assert_array_equal(back["seg0"]["slot_pos"],
                                      tree["seg0"]["slot_pos"])

    @settings(deadline=None, max_examples=30)
    @given(ops=st.lists(st.sampled_from(["put", "putq", "get", "remove",
                                         "evict"]),
                        min_size=1, max_size=30),
           budget=st.integers(1, 6), seed=st.integers(0, 2 ** 16))
    def test_store_total_bytes_invariant(ops, budget, seed):
        """store.total_bytes == sum(e.nbytes) under any op interleaving,
        with mixed quantized/fp entries and a byte budget."""
        rng = np.random.default_rng(seed)
        probe = tree_bytes(_attn_tree(rng, 16, 8))
        store = HostKVStore(max_bytes=int(probe * budget))
        rec = Recycler(store=store, compress_residual=4)
        n = 0
        for op in ops:
            if op in ("put", "putq"):
                rec.admit(f"p{n}", np.arange(8), _attn_tree(rng, 16, 8),
                          8, 16, compress=(op == "putq"))
                n += 1
            elif op == "get" and len(store):
                store.get(store.ids()[0])
            elif op == "remove" and len(store):
                eid = store.ids()[0]
                store.remove(eid)
                rec.index.remove(eid)
            elif op == "evict":
                store.evict_to_budget()
            assert store.total_bytes == sum(
                store.get(e, touch=False).nbytes for e in store.ids())
            assert store.max_bytes is None or \
                store.total_bytes <= store.max_bytes
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_quant_properties_need_hypothesis():
        pass
