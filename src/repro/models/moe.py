"""Expert-parallel Mixture-of-Experts FFN (DeepSeek-V2 / Kimi-K2 style).

Distribution design (DESIGN.md §5): experts are sharded over the ``model``
mesh axis; tokens arrive sharded over ``Runtime.token_axes``.  Dispatch is an
explicit ``shard_map`` with two static-shape capacity levels:

  level 1 — bucket token->expert assignments by *destination device* and
            exchange with ``lax.all_to_all`` over the expert axis;
  level 2 — bucket received entries by *local expert* and run batched
            per-expert SwiGLU matmuls (exact activated FLOPs — no dense
            all-expert compute).

Results take the reverse all-to-all and are gate-combined at the source.
When tokens are *replicated* over the expert axis (decode shapes), each
entry is dispatched by exactly one owner device (``tok % n_ep``) and the
combined output is ``psum``-broadcast — no duplicate expert compute.

Capacity overflow drops entries (standard Switch/GShard behaviour, factor
1.25); for small token counts (decode) capacities widen to "no drop".
The single-device path (mesh=None) is the same code with n_ep=1 and no
collectives — smoke tests compare it against a dense loop oracle exactly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import dense_init, split_tree, init_mlp, apply_mlp


# ---------------------------------------------------------------------------
def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def init_moe(cfg: ModelConfig, key, dtype):
    moe = cfg.moe
    d, f, E = cfg.d_model, moe.d_ff_expert, moe.num_experts
    ks = split_tree(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "we_gate": dense_init(ks[1], (E, d, f), dtype),
        "we_up": dense_init(ks[2], (E, d, f), dtype),
        "we_down": dense_init(ks[3], (E, f, d), dtype),
    }
    if moe.num_shared_experts:
        # shared experts fused into one wider dense SwiGLU MLP
        p["shared"] = init_mlp(cfg, ks[4], d, moe.num_shared_experts * f, dtype)
    return p


# ---------------------------------------------------------------------------
# per-device dispatch + expert compute (runs inside shard_map, or standalone)
# ---------------------------------------------------------------------------
def _moe_shard(x, router_w, w_gate, w_up, w_down, *, top_k: int, n_ep: int,
               axis: Optional[str], capacity_factor: float,
               dedup: bool, aux_axes):
    """x: (T, d) local tokens.  w_*: (E_local, ...) local experts."""
    T, d = x.shape
    E_local = w_gate.shape[0]
    E = n_ep * E_local
    K = top_k

    # ---- router (f32) ----------------------------------------------------
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gates, ids = jax.lax.top_k(probs, K)                     # (T, K)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # load-balance aux loss: E * sum_e f_e * P_e
    f_e = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    P_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * P_e)
    if axis is not None and aux_axes:
        aux = jax.lax.pmean(aux, aux_axes)

    # ---- flatten (token, k) assignments ----------------------------------
    TK = T * K
    flat_ids = ids.reshape(TK)
    flat_gates = gates.reshape(TK)
    src_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)

    valid = jnp.ones((TK,), bool)
    if dedup and axis is not None:
        me = jax.lax.axis_index(axis)
        valid = (src_tok % n_ep) == me

    # ---- level 1: bucket by destination device ---------------------------
    # dedup: only ~TK/n_ep entries are live on this rank, so capacities (and
    # hence the (n_ep, cap1, d) transfer buffers) scale with the owned
    # slice, not the full token set.  Small counts get full (no-drop)
    # capacity; large counts drop at capacity_factor (Switch behaviour).
    tk_eff = -(-TK // n_ep) if (dedup and axis is not None) else TK
    cap1 = tk_eff if tk_eff <= 1024 else min(
        TK, _round_up(int(tk_eff * capacity_factor / n_ep), 8))
    dst = jnp.where(valid, flat_ids // E_local, n_ep)        # invalid -> sentinel
    order = jnp.argsort(dst, stable=True)
    sdst = dst[order]
    pos1 = jnp.arange(TK, dtype=jnp.int32) - jnp.searchsorted(
        sdst, sdst, side="left").astype(jnp.int32)
    keep1 = (pos1 < cap1) & (sdst < n_ep)
    di = jnp.where(keep1, sdst, n_ep)                        # OOB -> dropped
    pi = jnp.where(keep1, pos1, 0)
    send_x = jnp.zeros((n_ep, cap1, d), x.dtype).at[di, pi].set(
        x[src_tok[order]], mode="drop")
    send_eid = jnp.full((n_ep, cap1), -1, jnp.int32).at[di, pi].set(
        (flat_ids % E_local)[order], mode="drop")
    send_src = jnp.full((n_ep, cap1), T, jnp.int32).at[di, pi].set(
        src_tok[order], mode="drop")
    send_gate = jnp.zeros((n_ep, cap1), jnp.float32).at[di, pi].set(
        flat_gates[order], mode="drop")

    if axis is not None and n_ep > 1:
        recv_x = jax.lax.all_to_all(send_x, axis, 0, 0)
        recv_eid = jax.lax.all_to_all(send_eid, axis, 0, 0)
    else:
        recv_x, recv_eid = send_x, send_eid

    # ---- level 2: bucket by local expert ----------------------------------
    R = n_ep * cap1
    fx = recv_x.reshape(R, d)
    fe = recv_eid.reshape(R)
    ekey = jnp.where(fe >= 0, fe, E_local)
    order2 = jnp.argsort(ekey, stable=True)
    se = ekey[order2]
    pos2 = jnp.arange(R, dtype=jnp.int32) - jnp.searchsorted(
        se, se, side="left").astype(jnp.int32)
    cap2 = R if R <= 1024 else min(
        R, _round_up(int(R * capacity_factor / E_local), 8))
    keep2 = (pos2 < cap2) & (se < E_local)
    ei = jnp.where(keep2, se, E_local)
    qi = jnp.where(keep2, pos2, 0)
    xe = jnp.zeros((E_local, cap2, d), x.dtype).at[ei, qi].set(
        fx[order2], mode="drop")

    # ---- batched per-expert SwiGLU (exact activated FLOPs) ----------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)               # (E_local, cap2, d)

    # ---- undo level 2 ------------------------------------------------------
    y_sorted = jnp.where(
        keep2[:, None],
        ye[jnp.minimum(se, E_local - 1), jnp.minimum(pos2, cap2 - 1)],
        0).astype(x.dtype)
    y_recv = jnp.zeros((R, d), x.dtype).at[order2].set(y_sorted)
    y_recv = y_recv.reshape(n_ep, cap1, d)

    # ---- undo level 1 ------------------------------------------------------
    if axis is not None and n_ep > 1:
        y_send = jax.lax.all_to_all(y_recv, axis, 0, 0)
    else:
        y_send = y_recv
    y_tok = jnp.zeros((T, d), jnp.float32).at[send_src.reshape(-1)].add(
        y_send.reshape(-1, d).astype(jnp.float32)
        * send_gate.reshape(-1, 1), mode="drop")
    y_tok = y_tok.astype(x.dtype)

    if dedup and axis is not None:
        # each token was dispatched by exactly one owner rank; psum
        # broadcasts the combined result (bf16 — halves all-reduce bytes)
        y_tok = jax.lax.psum(y_tok, axis)
        # aux already pmean'd over aux_axes; make it ep-invariant too
        aux = jax.lax.pmean(aux, axis) if axis not in (aux_axes or ()) else aux
    return y_tok, aux


# ---------------------------------------------------------------------------
def _moe_shard_fsharded(x, router_w, w_gate, w_up, w_down, *, top_k: int,
                        n_ep: int, ep_axis: str, dp_axes, dp_sizes,
                        capacity_factor: float):
    """Decode-path expert compute on f-sharded RESIDENT weights.

    x: (T_loc, d) sharded over dp_axes.  w_gate/w_up: (E_local, d, f_loc);
    w_down: (E_local, f_loc, d) — the per-expert FFN dim f stays sharded
    over the data axes exactly as stored, so no weight gather happens.
    Tokens are all-gathered over dp (MBs), every dp rank computes its
    f-chunk's partial expert outputs for ALL tokens, the down-projection
    partial sums are psum'd over dp, and each rank keeps its token slice.
    """
    T_loc = x.shape[0]
    x_all = x
    for ax in reversed(dp_axes):
        x_all = jax.lax.all_gather(x_all, ax, axis=0, tiled=True)
    y_all, aux = _moe_shard(
        x_all, router_w, w_gate, w_up, w_down, top_k=top_k, n_ep=n_ep,
        axis=ep_axis, capacity_factor=capacity_factor, dedup=True,
        aux_axes=None)
    y_all = jax.lax.psum(y_all.astype(jnp.float32), dp_axes)
    aux = jax.lax.pmean(aux, dp_axes)
    me = jnp.zeros((), jnp.int32)
    for ax, sz in zip(dp_axes, dp_sizes):
        me = me * sz + jax.lax.axis_index(ax)
    y = jax.lax.dynamic_slice_in_dim(y_all, me * T_loc, T_loc, 0)
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
def moe_ffn(cfg: ModelConfig, p, x, rt=None):
    """x: (B, S, d) -> (y, aux_loss).  Routed experts + shared experts."""
    moe = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(B * S, d)

    if rt is None or rt.mesh is None or not rt.model_axes:
        y, aux = _moe_shard(
            xf, p["router"], p["we_gate"], p["we_up"], p["we_down"],
            top_k=moe.top_k, n_ep=1, axis=None,
            capacity_factor=moe.capacity_factor, dedup=False, aux_axes=None)
    elif rt.moe_fsharded and rt.batch_axes:
        # §Perf kimi-decode: weights stay f-sharded and resident.
        ep = rt.ep_axis
        n_ep = rt.mesh.shape[ep]
        dp = rt.batch_axes
        tok_spec = P(dp, None)
        fn = functools.partial(
            _moe_shard_fsharded, top_k=moe.top_k, n_ep=n_ep, ep_axis=ep,
            dp_axes=dp, dp_sizes=tuple(rt.mesh.shape[a] for a in dp),
            capacity_factor=moe.capacity_factor)
        y, aux = jax.shard_map(
            fn, mesh=rt.mesh,
            in_specs=(tok_spec, P(None, None), P(ep, None, dp),
                      P(ep, None, dp), P(ep, dp, None)),
            out_specs=(tok_spec, P()),
        )(xf, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    else:
        ep = rt.ep_axis
        n_ep = rt.mesh.shape[ep]
        tok_axes = rt.token_axes
        dedup = ep not in tok_axes
        tok_spec = P(tok_axes if tok_axes else None, None)
        # Expert weights live FSDP-sharded over the data axes (a 1T MoE does
        # not fit over 'model' alone); this hint gathers the hidden dim once
        # per layer inside the scan so shard_map sees full (E_local, d, f).
        we_gate = rt.hint(p["we_gate"], ep, None, None)
        we_up = rt.hint(p["we_up"], ep, None, None)
        we_down = rt.hint(p["we_down"], ep, None, None)
        fn = functools.partial(
            _moe_shard, top_k=moe.top_k, n_ep=n_ep, axis=ep,
            capacity_factor=moe.capacity_factor, dedup=dedup,
            aux_axes=tok_axes if tok_axes else None)
        y, aux = jax.shard_map(
            fn, mesh=rt.mesh,
            in_specs=(tok_spec, P(None, None), P(ep, None, None),
                      P(ep, None, None), P(ep, None, None)),
            out_specs=(tok_spec, P()),
        )(xf, p["router"], we_gate, we_up, we_down)

    y = y.reshape(B, S, d)
    if moe.num_shared_experts:
        y = y + apply_mlp(cfg, p["shared"], x, rt)
    return y, aux * moe.router_aux_coef


# ---------------------------------------------------------------------------
def moe_ffn_oracle(cfg: ModelConfig, p, x):
    """Dense loop-over-experts oracle (tests only): mathematically identical
    routing, no capacity drops, no dispatch machinery."""
    moe = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, moe.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    y = jnp.zeros_like(xf, dtype=jnp.float32)
    for e in range(moe.num_experts):
        h = jax.nn.silu(xf @ p["we_gate"][e]) * (xf @ p["we_up"][e])
        ye = (h @ p["we_down"][e]).astype(jnp.float32)
        w_e = jnp.sum(jnp.where(ids == e, gates, 0.0), axis=-1)
        y = y + ye * w_e[:, None]
    y = y.astype(x.dtype).reshape(B, S, d)
    if moe.num_shared_experts:
        y = y + apply_mlp(cfg, p["shared"], x)
    return y
