"""Single-token decode attention over a (possibly ring) KV cache.

Grid = (batch * kv_heads, cache_blocks); the cache dimension is sequential
so the online-softmax state for the G grouped query heads stays in VMEM.
Validity comes from ``slot_pos`` (absolute position per slot, -1 = empty) —
the same structure the recycler trims, so a recycled + trimmed cache is
attended correctly with zero layout changes.  Ring-buffer (sliding-window)
caches work unchanged: masking is position-based, not index-based.

``paged_decode_attention`` is the block-table variant (PR 2): K/V live in
ONE shared pool of fixed-size blocks and each batch row names its blocks
via a table.  The table is a *scalar-prefetch* operand, so the BlockSpec
index map gathers each row's next pool block by table lookup — the kernel
body never sees the indirection, and shared prefix blocks are read in
place with no per-request copy.  Validity is implicit (tile i, slot j ->
position i*block_size + j, valid iff <= the row's decode position), so no
slot_pos array exists for paged caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def check_shard_view(H: int, Hkv: int) -> None:
    """Guard the q-heads / kv-heads pairing at kernel entry.

    The paged kernels derive every head count from operand shapes, so
    under a tensor-parallel ``shard_map`` they transparently run on the
    shard-LOCAL view (H/tp query heads against an Hkv/tp pool) — both
    operands must come from the SAME shard.  Mixing views (a head-sharded
    q against an unsharded pool, or vice versa) breaks the grouped
    reshape; for MHA-ratio pools that surfaces here as a non-divisible
    head pair instead of as a silently wrong grouping downstream.  A GQA
    mismatch whose wrong ratio still divides passes this check — the
    token-identity suites are the real gate."""
    if Hkv <= 0 or H % Hkv:
        raise ValueError(
            f"query heads ({H}) not a multiple of kv heads ({Hkv}); "
            "under shard_map both operands must be the same shard's "
            "local view — mixing a head-sharded tensor with an "
            "unsharded one produces exactly this mismatch")


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, sp_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, window, bk, nk):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (G, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    sp = sp_ref[...]                                  # (bk,) abs positions
    pos = pos_ref[0]

    s = q @ k.T * scale                               # (G, bk)
    ok = (sp >= 0) & (sp <= pos)
    if window:
        ok &= sp > pos - window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_new = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ki == nk - 1)
    def _write():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _decode_kernel_batched(pos_ref, q_ref, k_ref, v_ref, sp_ref, o_ref,
                           m_scr, l_scr, acc_scr, *, scale, window, bk, nk,
                           hkv):
    """Per-row variant: slot_pos and pos are indexed by the batch row this
    (batch*head) program belongs to, so every slot-pool row is masked by its
    own request's validity/causality — rows never see each other's slots."""
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (G, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    sp = sp_ref[0]                                    # (bk,) this row's slots
    pos = pos_ref[pl.program_id(0) // hkv]            # this row's position

    s = q @ k.T * scale                               # (G, bk)
    ok = (sp >= 0) & (sp <= pos)
    if window:
        ok &= sp > pos - window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_new = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ki == nk - 1)
    def _write():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention_batched(q, k_cache, v_cache, slot_pos, pos, *, window=0,
                             block_k=256, scale=None, interpret=True):
    """Continuous-batching decode: q (B,1,H,D); caches (B,C,Hkv,D);
    slot_pos (B,C) per-row; pos (B,) per-row int32.  Returns (B,1,H,D)."""
    B, _, H, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = scale or D ** -0.5
    bk = min(block_k, C)
    assert C % bk == 0, (C, bk)
    nk = C // bk

    qr = q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    pos_arr = pos.astype(jnp.int32)

    kernel = functools.partial(_decode_kernel_batched, scale=scale,
                               window=window, bk=bk, nk=nk, hkv=Hkv)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk), lambda bh, ki, hkv=Hkv: (bh // hkv, ki)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qr, kr, vr, slot_pos)
    return out.reshape(B, Hkv, G, D).reshape(B, 1, H, D)


def _paged_accumulate(ti, nbt, q_ref, k, v, pos, o_ref, m_scr, l_scr,
                      acc_scr, *, scale, bs):
    """Shared body of the paged decode kernels: one online-softmax step of
    the G grouped query heads against this program's (bs, d) K/V tile,
    masked by implicit positions (tile ti slot j == position ti*bs + j,
    valid iff <= the row's decode position), with the normalized write on
    the last tile.  The fp and int8 kernels differ only in how they
    source ``k``/``v`` — everything that must stay in lockstep for
    fp-vs-int8 token equivalence lives here."""
    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (G, d)
    tok = ti * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)

    s = q @ k.T * scale                               # (G, bs)
    s = jnp.where(tok <= pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_new = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ti == nbt - 1)
    def _write():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale, bs, nbt, hkv):
    """One (batch*kv_head, table_entry) program: the BlockSpec index map
    already resolved table entry ``ti`` to a pool block, so k_ref/v_ref
    hold that block's (bs, d) tile."""
    ti = pl.program_id(1)
    k = k_ref[0, 0].astype(jnp.float32)               # (bs, d)
    v = v_ref[0, 0].astype(jnp.float32)
    pos = pos_ref[pl.program_id(0) // hkv]            # this row's position
    _paged_accumulate(ti, nbt, q_ref, k, v, pos, o_ref, m_scr, l_scr,
                      acc_scr, scale=scale, bs=bs)


def paged_decode_attention(q, k_pool, v_pool, block_tables, pos, *,
                           scale=None, interpret=True):
    """Block-table decode: q (B,1,H,D); pools (NB, bs, Hkv, D) shared by
    every request; block_tables (B, NBt) int32 (sentinel-0 padded);
    pos (B,) per-row int32.  Returns (B,1,H,D)."""
    B, _, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = block_tables.shape[1]
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr = q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D)
    vr = v_pool.transpose(2, 0, 1, 3)

    kernel = functools.partial(_paged_decode_kernel, scale=scale, bs=bs,
                               nbt=NBt, hkv=Hkv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block table + per-row positions
        grid=(B * Hkv, NBt),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, ti, tbl, pos: (bh, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda bh, ti, tbl, pos, hkv=Hkv:
                         (bh % hkv, tbl[bh // hkv, ti], 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda bh, ti, tbl, pos, hkv=Hkv:
                         (bh % hkv, tbl[bh // hkv, ti], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda bh, ti, tbl, pos: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), pos.astype(jnp.int32), qr, kr, vr)
    return out.reshape(B, Hkv, G, D).reshape(B, 1, H, D)


def _paged_decode_kernel_quant(tbl_ref, pos_ref, q_ref, k_ref, v_ref,
                               ks_ref, vs_ref, kt_ref, vt_ref, o_ref,
                               m_scr, l_scr, acc_scr, *, scale, bs, nbt,
                               hkv, rtail):
    """int8 variant of ``_paged_decode_kernel``: K/V tiles arrive as int8
    pool blocks plus their per-vector f32 scales (same table-lookup index
    map), and the dequant multiply is fused into the gather — HBM traffic
    for sealed blocks is the int8 bytes.  The row's most recent ``rtail``
    blocks are instead read from its fp ring tail (ring slot ti % rtail),
    so quantization error never sits where attention mass is largest."""
    ti = pl.program_id(1)
    k8 = k_ref[0, 0].astype(jnp.float32)              # (bs, d) int8 tile
    v8 = v_ref[0, 0].astype(jnp.float32)
    ks = ks_ref[0, 0].astype(jnp.float32)             # (bs,) f32 scales
    vs = vs_ref[0, 0].astype(jnp.float32)
    kt = kt_ref[0, 0].astype(jnp.float32)             # (bs, d) fp ring tile
    vt = vt_ref[0, 0].astype(jnp.float32)
    pos = pos_ref[pl.program_id(0) // hkv]            # this row's position

    open_b = pos // bs
    use_fp = (ti <= open_b) & (ti > open_b - rtail)   # scalar: recent block?
    k = jnp.where(use_fp, kt, k8 * ks[:, None])
    v = jnp.where(use_fp, vt, v8 * vs[:, None])
    _paged_accumulate(ti, nbt, q_ref, k, v, pos, o_ref, m_scr, l_scr,
                      acc_scr, scale=scale, bs=bs)


def paged_decode_attention_quant(q, k_pool, v_pool, k_scale, v_scale,
                                 k_tail, v_tail, block_tables, pos, *,
                                 scale=None, interpret=True):
    """Fused-dequant block-table decode: q (B,1,H,D); int8 pools
    (NB, bs, Hkv, D) with f32 scales (NB, bs, Hkv); per-row fp ring tails
    (B, R*bs, Hkv, D); block_tables (B, NBt) int32; pos (B,).  The
    scalar-prefetch table gather is unchanged from the fp kernel — only
    the tile contents differ (int8 + scale, or the fp ring slot for the
    row's most recent R blocks).  Returns (B,1,H,D)."""
    B, _, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = block_tables.shape[1]
    R = k_tail.shape[1] // bs
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr = q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D) int8
    vr = v_pool.transpose(2, 0, 1, 3)
    ksr = k_scale.transpose(2, 0, 1)                  # (Hkv, NB, bs) f32
    vsr = v_scale.transpose(2, 0, 1)
    ktr = (k_tail.reshape(B, R, bs, Hkv, D)           # (B*Hkv, R, bs, D)
           .transpose(0, 3, 1, 2, 4).reshape(B * Hkv, R, bs, D))
    vtr = (v_tail.reshape(B, R, bs, Hkv, D)
           .transpose(0, 3, 1, 2, 4).reshape(B * Hkv, R, bs, D))

    kernel = functools.partial(_paged_decode_kernel_quant, scale=scale,
                               bs=bs, nbt=NBt, hkv=Hkv, rtail=R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block table + per-row positions
        grid=(B * Hkv, NBt),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, ti, tbl, pos: (bh, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda bh, ti, tbl, pos, hkv=Hkv:
                         (bh % hkv, tbl[bh // hkv, ti], 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda bh, ti, tbl, pos, hkv=Hkv:
                         (bh % hkv, tbl[bh // hkv, ti], 0, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda bh, ti, tbl, pos, hkv=Hkv:
                         (bh % hkv, tbl[bh // hkv, ti], 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda bh, ti, tbl, pos, hkv=Hkv:
                         (bh % hkv, tbl[bh // hkv, ti], 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda bh, ti, tbl, pos, r=R: (bh, ti % r, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda bh, ti, tbl, pos, r=R: (bh, ti % r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda bh, ti, tbl, pos: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), pos.astype(jnp.int32),
      qr, kr, vr, ksr, vsr, ktr, vtr)
    return out.reshape(B, Hkv, G, D).reshape(B, 1, H, D)


def decode_attention(q, k_cache, v_cache, slot_pos, pos, *, window=0,
                     block_k=256, scale=None, interpret=True):
    """q: (B,1,H,D); caches (B,C,Hkv,D); slot_pos (C,); pos scalar int32.
    Returns (B,1,H,D)."""
    B, _, H, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = scale or D ** -0.5
    bk = min(block_k, C)
    assert C % bk == 0, (C, bk)
    nk = C // bk

    qr = q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    pos_arr = jnp.reshape(pos, (1,)).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((bk,), lambda bh, ki: (ki,)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qr, kr, vr, slot_pos)
    return out.reshape(B, Hkv, G, D).reshape(B, 1, H, D)
