"""Public model API: init / train_loss / prefill / decode_step.

All functions are pure and jit-able; distribution comes from the Runtime
(mesh + axis rules) and the in/out shardings the launcher applies.  The KV
recycling entry point is simply ``prefill(..., cache=restored, start_pos=k)``
— attention writes suffix K/V after the recycled prefix and attends over
both, which is exactly the paper's ``generate(past_key_values=...)`` call
expressed functionally.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention, encdec
from repro.models.layers import (chunked_cross_entropy, init_embeddings,
                                 position_embedding, unembed)
from repro.models.transformer import apply_stack, init_stack
from repro.runtime import Runtime, LOCAL


def init_params(cfg: ModelConfig, rng, dtype=None):
    dtype = jnp.dtype(dtype or cfg.param_dtype)
    k_embed, k_stack, k_enc = jax.random.split(rng, 3)
    params = {"embed": init_embeddings(cfg, k_embed, dtype)}
    params.update(init_stack(cfg, k_stack, dtype))
    if cfg.frontend is not None:
        params["encoder"] = encdec.init_encoder(cfg, k_enc, dtype)
    return params


def embed_inputs(cfg: ModelConfig, params, tokens, *, start_pos=0,
                 frontend=None, rt: Runtime = LOCAL):
    """Token (+frontend) embedding with positions.

    Returns (x, n_prefix) where n_prefix is the number of non-text prefix
    positions (VLM patches) carrying no LM loss.
    """
    B, S = tokens.shape
    x = params["embed"]["wte"][tokens]
    n_prefix = 0
    if cfg.frontend is not None and not cfg.frontend.cross_attention:
        # VLM prefix concat (projector is the stub boundary)
        assert frontend is not None
        px = encdec.encode(cfg, params["encoder"], frontend, rt)
        x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
        n_prefix = px.shape[1]
        S = S + n_prefix
    positions = start_pos + jnp.arange(S, dtype=jnp.int32)
    pe = position_embedding(cfg, params["embed"], positions, x.dtype)
    if pe is not None:
        x = x + pe
    return x, n_prefix


def train_loss(cfg: ModelConfig, params, batch, rt: Runtime = LOCAL):
    """batch: {"tokens": (B,S) int32, "frontend": optional embeds}.
    Next-token LM loss; VLM patches and pad (-1) positions masked."""
    tokens = batch["tokens"]
    frontend = batch.get("frontend")
    enc_out = None
    if cfg.is_encdec:
        enc_out = encdec.encode(cfg, params["encoder"], frontend, rt)
        frontend = None
    x, n_prefix = embed_inputs(cfg, params, tokens, frontend=frontend, rt=rt)
    if rt.mesh is not None and rt.batch_axes:
        x = rt.hint(x, rt.batch_axes, None, None)
    x, _, aux = apply_stack(cfg, params, x, mode="train", cache=None,
                            pos=0, window=0, rt=rt, enc_out=enc_out)
    # labels: predict token t+1 at position t; frontend prefix has no loss
    labels = jnp.concatenate(
        [jnp.full((tokens.shape[0], n_prefix), -1, tokens.dtype),
         tokens], axis=1)[:, 1:]
    labels = jnp.concatenate(
        [labels, jnp.full((tokens.shape[0], 1), -1, tokens.dtype)], axis=1)
    ce = chunked_cross_entropy(cfg, params, x, labels, rt)
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


def prefill(cfg: ModelConfig, params, tokens, cache, *, start_pos=0,
            frontend=None, window: int = 0, rt: Runtime = LOCAL):
    """Process a prompt (or recycled-prefix *suffix* when start_pos=k and
    ``cache`` holds the recycled prefix KVs).  Returns (last-token logits,
    updated cache)."""
    enc_out = None
    if cfg.is_encdec:
        assert frontend is not None or start_pos != 0, \
            "whisper prefill needs frontend features"
        if frontend is not None:
            enc_out = encdec.encode(cfg, params["encoder"], frontend, rt)
        frontend = None
    x, _ = embed_inputs(cfg, params, tokens, start_pos=start_pos,
                        frontend=frontend, rt=rt)
    if rt.mesh is not None and rt.batch_axes:
        x = rt.hint(x, rt.batch_axes, None, None)
    x, cache, _ = apply_stack(cfg, params, x, mode="prefill", cache=cache,
                              pos=start_pos, window=window, rt=rt,
                              enc_out=enc_out)
    logits = unembed(cfg, params, x[:, -1:], rt)[:, 0]
    return logits, cache


def prefill_paged(cfg: ModelConfig, params, tokens, pool, row, table_row,
                  start_pos, w_floor, n_valid, *, rt: Runtime = LOCAL):
    """One chunk of a paged-native prefill (the chunked-admission path).

    ``tokens`` (1, C) is a FIXED-size chunk of prompt tokens at absolute
    positions [start_pos, start_pos + C) of pool row ``row``; positions
    i >= ``n_valid`` are padding (their K/V route to the pool's sentinel
    block and their logits are discarded).  K/V are written straight into
    pool blocks through ``table_row`` (NBt,) — the admitting row's block
    table, passed explicitly because the row's DEVICE table stays
    all-sentinel until the admission completes (see
    ``attention.paged_prefill_write``) — and attention runs over the same
    table.  There is no dense staging cache, and because row / start_pos /
    n_valid are traced scalars, ONE compiled executable covers every
    admission regardless of prefix depth or suffix length.  Positions
    below ``w_floor`` are query-only (their K/V were pre-uploaded — the
    sub-block remainder of a host promotion); their writes are dropped.

    Returns (logits of the LAST VALID token (1, V), updated pool)."""
    x, _ = embed_inputs(cfg, params, tokens, start_pos=start_pos, rt=rt)
    if rt.mesh is not None and rt.batch_axes:
        x = rt.hint(x, rt.batch_axes, None, None)
    row = jnp.asarray(row, jnp.int32)
    table_row = jnp.asarray(table_row, jnp.int32)
    start_pos = jnp.asarray(start_pos, jnp.int32)
    w_floor = jnp.asarray(w_floor, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    x, pool, _ = apply_stack(cfg, params, x, mode="prefill", cache=pool,
                             pos=(row, table_row, start_pos, w_floor,
                                  n_valid),
                             window=0, rt=rt)
    last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    logits = unembed(cfg, params, last, rt)[:, 0]
    return logits, pool


def prefill_paged_packed(cfg: ModelConfig, params, tokens, pool, rows,
                         tables, c0s, w_floors, valids, q_offs, seg_ids,
                         *, rt: Runtime = LOCAL):
    """EVERY pending admission's current chunk in ONE ragged packed
    dispatch (the multi-admission generalization of ``prefill_paged``).

    ``tokens`` (1, T) concatenates each admission's fixed-size chunk
    (segments bs-aligned, T padded to a bucket size).  Token t belongs to
    segment ``seg_ids[t]`` and sits at absolute position
    ``c0s[seg] + (t - q_offs[seg])`` of pool row ``rows[seg]``; positions
    at or past ``valids[seg]`` within a segment are padding (sentinel
    writes, garbage logits), and a whole padding SEGMENT (the buffer
    tail) carries valids == 0 with an all-sentinel table row.  Because
    every descriptor is a traced vector and the buffer/segment shapes are
    fixed buckets, the compile count is independent of both suffix length
    and the number of concurrent admissions.

    Returns (per-SEGMENT last-valid-token logits (S, V), updated pool)."""
    B, T = tokens.shape
    x = params["embed"]["wte"][tokens]
    rows = jnp.asarray(rows, jnp.int32)
    tables = jnp.asarray(tables, jnp.int32)
    c0s = jnp.asarray(c0s, jnp.int32)
    w_floors = jnp.asarray(w_floors, jnp.int32)
    valids = jnp.asarray(valids, jnp.int32)
    q_offs = jnp.asarray(q_offs, jnp.int32)
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    t = jnp.arange(T, dtype=jnp.int32)
    positions = (c0s[seg_ids] + (t - q_offs[seg_ids]))[None]    # (1, T)
    pe = position_embedding(cfg, params["embed"], positions, x.dtype)
    if pe is not None:
        x = x + pe
    if rt.mesh is not None and rt.batch_axes:
        x = rt.hint(x, rt.batch_axes, None, None)
    x, pool, _ = apply_stack(cfg, params, x, mode="prefill_packed",
                             cache=pool,
                             pos=(rows, tables, c0s, w_floors, valids,
                                  q_offs, seg_ids),
                             window=0, rt=rt)
    last = jnp.take(x[0], jnp.clip(q_offs + valids - 1, 0, T - 1), axis=0)
    logits = unembed(cfg, params, last[None], rt)[0]             # (S, V)
    return logits, pool


def verify_paged(cfg: ModelConfig, params, tokens, pool, c0s, n_valid,
                 act, *, rt: Runtime = LOCAL):
    """Batched multi-token speculative verification (ONE dispatch for
    every speculating row).

    ``tokens`` (B, Cv) is each row's pending token followed by its gamma
    draft tokens, at absolute positions [c0s[b], c0s[b] + Cv); positions
    i >= ``n_valid`` are padding (Cv is gamma + 1 rounded up to a block
    multiple).  Rows with ``act[b] == 0`` are not speculating this
    round: their writes route to the sentinel block and their logits are
    garbage the engine discards.  K/V seal into speculatively reserved
    blocks through each row's DEVICE table (verification only runs on
    armed rows), so a rejected tail is rolled back host-side.

    Returns (per-position logits (B, Cv, V), updated pool) — the
    all-position logits are what acceptance needs: position j's argmax
    is the greedy target that draft token j+1 must match, and the last
    accepted position's argmax is the free bonus token."""
    B, Cv = tokens.shape
    x = params["embed"]["wte"][tokens]
    c0s = jnp.asarray(c0s, jnp.int32)
    positions = c0s[:, None] + jnp.arange(Cv, dtype=jnp.int32)
    pe = position_embedding(cfg, params["embed"], positions, x.dtype)
    if pe is not None:
        x = x + pe
    if rt.mesh is not None and rt.batch_axes:
        x = rt.hint(x, rt.batch_axes, None, None)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    act = jnp.asarray(act, jnp.int32)
    x, pool, _ = apply_stack(cfg, params, x, mode="verify", cache=pool,
                             pos=(c0s, n_valid, act), window=0, rt=rt)
    logits = unembed(cfg, params, x, rt)
    return logits, pool


def draft_view(cfg: ModelConfig, pool, draft_tables, draft_base, pos):
    """Pre-gather the sparse sink+recent draft view from the paged pool,
    ONCE per speculative round (see ``attention.gather_draft_view``).
    Returns ({seg: {"vk", "vv"}} with (L, B, NDt*bs, Hkv, Dh) leaves,
    shared key positions (B, NDt*bs)) — everything the gamma draft
    steps read; the pool itself never enters their dispatches."""
    dt = jnp.dtype(cfg.dtype)
    view, vpos = {}, None
    for seg, c in pool.items():
        vk, vv, vpos = attention.gather_draft_view(c, draft_tables,
                                                   draft_base, pos, dt)
        view[seg] = {"vk": vk, "vv": vv}
    return view, vpos


def draft_refine(cfg: ModelConfig, params, tokens, view, vpos, pos, *,
                 rt: Runtime = LOCAL):
    """One fixed-point draft sweep: ``tokens`` (B, G) — each row's
    pending token followed by its first G - 1 draft guesses — run
    through the model at positions [pos[b], pos[b] + G) IN PARALLEL
    against the round's pre-gathered sparse view (staircase attention:
    position j attends the view plus guesses < j from this sweep's own
    fresh projections).  Returns logits (B, G, V); position j's argmax
    is the REFINED guess for draft token j + 1.

    This is a Jacobi iteration on the greedy decode recurrence: after k
    sweeps the first k guesses equal exact sequential greedy decoding
    over the view, and locally predictable spans converge much faster.
    Each sweep costs ONE multi-token dispatch — the same economics as
    verification — where a sequential drafter pays a full per-token
    dispatch (dominated by per-op overhead at small model sizes, not
    FLOPs) for every draft token.  The paged pool is not an input:
    sweeps read only the view and their own projections."""
    B, G = tokens.shape
    x = params["embed"]["wte"][tokens]
    qpos = pos.astype(jnp.int32)[:, None] + jnp.arange(G, dtype=jnp.int32)
    pe = position_embedding(cfg, params["embed"], qpos, x.dtype)
    if pe is not None:
        x = x + pe
    if rt.mesh is not None and rt.batch_axes:
        x = rt.hint(x, rt.batch_axes, None, None)
    x, _, _ = apply_stack(cfg, params, x, mode="draft", cache=view,
                          pos=(qpos, vpos), window=0, rt=rt)
    return unembed(cfg, params, x, rt)


def decode_step(cfg: ModelConfig, params, token, cache, pos, *,
                window: int = 0, rt: Runtime = LOCAL):
    """One decode step: token (B,1) at absolute position ``pos``.

    ``pos`` scalar: all rows share one position (single-request decoding).
    ``pos`` (B,): per-row positions over a per-slot pool cache — one jit
    dispatch decodes a continuous batch of requests at different depths.
    Returns (logits (B,V), updated cache)."""
    x = params["embed"]["wte"][token]
    pos = jnp.asarray(pos)
    batched = pos.ndim > 0
    positions = (pos.astype(jnp.int32)[:, None] if batched
                 else jnp.reshape(pos, (1,)).astype(jnp.int32))
    pe = position_embedding(cfg, params["embed"], positions, x.dtype)
    if pe is not None:
        x = x + (pe if batched else pe[None])
    if rt.mesh is not None and rt.batch_axes:
        x = rt.hint(x, rt.batch_axes, None, None)
    x, cache, _ = apply_stack(cfg, params, x, mode="decode", cache=cache,
                              pos=pos, window=window, rt=rt)
    logits = unembed(cfg, params, x[:, -1:], rt)[:, 0]
    return logits, cache
