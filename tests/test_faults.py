"""Deterministic fault-injection harness (PR 10): FaultPlan semantics,
the chaos soak over the paged engine (invariants hold after every
injected fault; faults never alter the tokens of requests they didn't
kill), corrupted-entry defenses (serve-time digest drop, ``load_dir``
bit-flip survival), and ShardedServer replica-failure containment.
"""
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.faults import (FaultPlan, FaultSpec, InjectedFault,
                               plan_from_spec)
from repro.core.kvstore import HostKVStore, cache_digest
from repro.models import init_params
from repro.serving import Engine, PagedEngine
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     RequestOutcome)

PROMPTS = [
    "the quick brown fox jumps over the lazy dog today and tomorrow",
    "what is the capital of france and why is it paris",
    "zzz qqq completely unrelated 12345 something else entirely here",
]


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# FaultPlan semantics
# ---------------------------------------------------------------------------
def test_fault_plan_deterministic():
    """Same seed + same call sequence -> identical firing pattern."""
    def pattern(seed):
        plan = plan_from_spec(seed, alloc=0.3, kvstore_get=0.5)
        return [(site, plan.should_fire(site))
                for site in ["alloc", "kvstore_get", "alloc", "alloc",
                             "kvstore_get"] * 20]
    assert pattern(42) == pattern(42)
    assert pattern(42) != pattern(43)     # seed actually matters


def test_fault_plan_at_exact_calls():
    plan = plan_from_spec(0, alloc=(0, 3))
    fires = [plan.should_fire("alloc") for _ in range(5)]
    assert fires == [True, False, False, True, False]
    assert plan.stats()["alloc"] == {"calls": 5, "fired": 2}


def test_fault_plan_unlisted_site_never_fires():
    plan = plan_from_spec(0, alloc=1.0)
    assert not any(plan.should_fire("kvstore_get") for _ in range(10))


def test_fault_plan_maybe_fire_raises():
    plan = plan_from_spec(0, kvstore_put=(0,))
    with pytest.raises(InjectedFault):
        plan.maybe_fire("kvstore_put", "boom")
    plan.maybe_fire("kvstore_put", "boom")     # second call: no fire


def test_injected_fault_is_not_oserror():
    """Containment code catches (InjectedFault, OSError) explicitly; the
    fault type must not silently satisfy unrelated OSError handlers."""
    assert not issubclass(InjectedFault, OSError)
    assert issubclass(InjectedFault, RuntimeError)


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(rate=1.5)
    with pytest.raises(ValueError):
        FaultSpec(at=(-1,))
    with pytest.raises(ValueError):
        FaultPlan(seed=0, sites={"bogus_site": FaultSpec(rate=0.5)})


# ---------------------------------------------------------------------------
# chaos soak: all sites armed, engine survives, invariants always hold
# ---------------------------------------------------------------------------
def test_chaos_soak_invariants_and_identity(stack):
    """Every injection site fires; after EVERY step the pool invariants
    hold, and every request that completes is token-identical to the
    fault-free run — an injected fault may cost recompute or a
    preemption round-trip, never a different answer."""
    cfg, params = stack
    clean = PagedEngine(cfg, params, max_batch=2, capacity=128,
                        max_new_tokens=6, block_size=8, enable_partial=True,
                        overcommit=True, num_blocks=12)
    csched = ContinuousBatchingScheduler(clean)
    creqs = [csched.submit(p, admit=True) for p in PROMPTS * 2]
    csched.run()
    want = {r.request_id: r.result.text for r in creqs}

    plan = plan_from_spec(7, alloc=0.15, kvstore_get=0.3, kvstore_put=0.3,
                          kvstore_corrupt=0.3)
    eng = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=6, block_size=8, enable_partial=True,
                      overcommit=True, num_blocks=12, fault_plan=plan)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, admit=True) for p in PROMPTS * 2]
    steps = 0
    while (sched._queue or sched.in_flight) and steps < 1000:
        sched.step()
        eng.check_invariants()       # crash-consistent after EVERY step
        steps += 1
    assert steps < 1000, "chaos run did not drain"
    stats = plan.stats()
    for site in ("alloc", "kvstore_get", "kvstore_put", "kvstore_corrupt"):
        assert stats[site]["fired"] > 0, (site, stats)
    for r in reqs:
        assert r.outcome == RequestOutcome.OK, (r.outcome, r.error)
        assert r.result.text == want[r.request_id]


def test_host_io_fault_is_a_miss_not_a_crash(stack):
    """A host-store IO fault during lookup serves the request as a miss
    (recompute — identical tokens); during admit it skips the store
    write.  Neither propagates."""
    cfg, params = stack
    eng = Engine(cfg, params, max_new_tokens=4, block_size=8)
    eng.precache(PROMPTS[:1])
    want = eng.generate(PROMPTS[0] + " more", use_recycling=True)

    eng2 = Engine(cfg, params, max_new_tokens=4, block_size=8)
    eng2.precache(PROMPTS[:1])
    eng2.recycler.store.fault_plan = plan_from_spec(1, kvstore_get=(0,))
    got = eng2.generate(PROMPTS[0] + " more", use_recycling=True)
    assert got.text == want.text
    assert eng2.recycler.stats["io_fault_misses"] == 1


# ---------------------------------------------------------------------------
# corruption defenses
# ---------------------------------------------------------------------------
def test_corrupt_entry_dropped_at_serve_time(stack):
    """A silently corrupted host entry fails its digest check at serve
    time: dropped from the store, the lookup degrades to a miss, tokens
    unchanged."""
    cfg, params = stack
    eng = Engine(cfg, params, max_new_tokens=4, block_size=8)
    eng.precache(PROMPTS[:1])
    want = eng.generate(PROMPTS[0] + " more", use_recycling=True)

    eng2 = Engine(cfg, params, max_new_tokens=4, block_size=8)
    eng2.precache(PROMPTS[:1])
    n0 = len(eng2.recycler.store)
    eng2.recycler.store.fault_plan = plan_from_spec(2, kvstore_corrupt=(0,))
    got = eng2.generate(PROMPTS[0] + " more", use_recycling=True)
    assert got.text == want.text
    assert eng2.recycler.stats["corrupt_entry_drops"] == 1
    assert len(eng2.recycler.store) == n0 - 1      # evicted, not served


def test_load_dir_survives_bitflip(stack):
    """Regression: a bit-flipped npz (or a stale sidecar digest) skips
    that entry — counted — instead of poisoning or failing the reload."""
    cfg, params = stack
    eng = Engine(cfg, params, max_new_tokens=4, block_size=8)
    eng.precache(PROMPTS[:2])
    n = len(eng.recycler.store)
    assert n >= 2
    with tempfile.TemporaryDirectory() as d:
        eng.recycler.store.save_dir(d)
        npzs = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
        path = os.path.join(d, npzs[0])
        raw = bytearray(open(path, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(path, "wb").write(raw)
        st2 = HostKVStore.load_dir(d)
        assert st2.load_corrupt_skips == 1
        assert len(st2) == n - 1
        # surviving entries still digest-clean
        for e in st2._entries.values():
            assert cache_digest(e.cache) == e.digest


def test_load_dir_survives_missing_npz(stack):
    cfg, params = stack
    eng = Engine(cfg, params, max_new_tokens=4, block_size=8)
    eng.precache(PROMPTS[:2])
    n = len(eng.recycler.store)
    with tempfile.TemporaryDirectory() as d:
        eng.recycler.store.save_dir(d)
        npzs = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
        os.remove(os.path.join(d, npzs[0]))
        st2 = HostKVStore.load_dir(d)
        assert st2.load_corrupt_skips == 1
        assert len(st2) == n - 1


# ---------------------------------------------------------------------------
# replica failure containment (needs forced host devices, like
# tests/test_sharded_serving.py — skips cleanly otherwise)
# ---------------------------------------------------------------------------
def _sharded_ready():
    return ("--xla_force_host_platform_device_count"
            in os.environ.get("XLA_FLAGS", "") and jax.device_count() >= 2)


@pytest.mark.skipif(not _sharded_ready(),
                    reason="needs XLA_FLAGS forced host devices")
def test_replica_failure_contained(stack):
    """One replica's step fault kills only ITS in-flight requests (typed
    ERRORED); its queued requests reroute to a survivor and complete;
    the other replica never notices."""
    from repro.launch.serve import ShardedServer
    cfg, params = stack
    srv = ShardedServer(cfg, params, replicas=2, tp=1, max_new_tokens=4,
                        block_size=8, max_batch=1)
    srv.engines[0].fault_plan = plan_from_spec(0, replica_step=(1,))
    res = srv.run(PROMPTS + [PROMPTS[0] + " again"],
                  replica=[0, 0, 1, 1], concurrent=False)
    # replica 0: first request was in flight when the fault hit (errored,
    # string); the queued one rerouted to replica 1 (GenResult)
    errored = [r for r in res if isinstance(r, str)]
    served = [r for r in res if not isinstance(r, str)]
    assert len(errored) >= 1 and "replica 0 failed" in errored[0]
    assert len(served) >= 3
    assert srv.shared_stats["replica_failures"] == 1
    assert srv.shared_stats["rerouted_requests"] >= 1
    srv.check_invariants()
