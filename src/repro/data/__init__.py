from repro.data.tokenizer import ByteTokenizer
from repro.data.pipeline import (SyntheticDialogues, paper_prompt_sets,
                                 TrainBatches)

__all__ = ["ByteTokenizer", "SyntheticDialogues", "paper_prompt_sets",
           "TrainBatches"]
