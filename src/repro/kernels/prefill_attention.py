"""Paged-native chunked prefill attention (the admission hot path).

The staged admission path ran the dense prefill over a full-capacity
staging cache: gather the resident prefix out of the pool, prefill the
suffix, scatter the result back into pool blocks — a device round-trip
per admission, and one compiled executable per distinct suffix length.
This kernel removes the round-trip: a fixed-size chunk of C query tokens
attends its *history* directly against the request's pool blocks,
gathered through the scalar-prefetched block table exactly like
``paged_decode_attention``, and its *own* K/V from the chunk's fresh fp
operands (flash-attention style) — sealing to the pool happens after,
so in-chunk attention is always full precision.

Structure: flash-style online softmax (same recurrence as
``flash_attention``) with grid = (kv_heads, table_entries + chunk_tiles);
the kv dimension is sequential so the (C*G, d) softmax state stays in
VMEM.  Tiles ti < NBt are history: pool block ``table[ti]``, implicit
positions ti*bs + j, valid iff < ``w_eff`` (the history/chunk boundary —
normally the chunk start, or the promoted depth when a host promotion
pre-uploaded a partial boundary block) and causally <= the query's
position.  Tiles ti >= NBt are the chunk itself: fp operand slice at
positions c0 + (ti - NBt)*bs + j, valid iff >= ``w_eff``.  Sentinel
(block 0) table entries beyond the written region are harmless: their
positions exceed ``w_eff``.

Because C is FIXED (block-aligned ``prefill_chunk``), ONE compiled
executable serves every admission regardless of suffix length — c0 and
w_eff arrive as scalar-prefetch operands, never as shape.

``paged_prefill_attention_quant`` is the int8-pool variant: the history
gather fuses the per-vector dequant, and the last ``R`` history blocks
(ending at the newest history block, derived from w_eff) are read from
the row's fp ring tail instead — the same recency gate the int8 decode
kernel applies, so chunked prefill and decode see one consistent view of
where full precision lives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import check_shard_view

NEG_INF = -1e30


def _accumulate(ti, ntiles, s, v, o_ref, m_scr, l_scr, acc_scr):
    """One online-softmax step over a pre-masked score tile ``s``
    (C*G, bs) against values ``v`` (bs, d), with the normalized write on
    the last tile.  Shared by the fp and int8 kernels so the
    normalization that must stay in lockstep for fp-vs-int8 token
    equivalence lives in one place."""
    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_new = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ti == ntiles - 1)
    def _write():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _tile_mask(ti, sc_ref, CG, bs, G, nbt):
    """(kv positions, validity) for tile ``ti``: history tiles hold
    implicit pool positions valid below w_eff; chunk tiles hold operand
    positions valid at/after it.  Causality against the query rows
    (query row r is token r // G at position c0 + r // G) applies to
    both."""
    c0, w_eff = sc_ref[0], sc_ref[1]
    j = jax.lax.broadcasted_iota(jnp.int32, (CG, bs), 1)
    qp = c0 + jax.lax.broadcasted_iota(jnp.int32, (CG, bs), 0) // G
    is_hist = ti < nbt
    kp = jnp.where(is_hist, ti * bs + j, c0 + (ti - nbt) * bs + j)
    ok = (kp <= qp) & jnp.where(is_hist, kp < w_eff, kp >= w_eff)
    return ok


def _paged_prefill_kernel(tbl_ref, sc_ref, q_ref, k_ref, v_ref, kc_ref,
                          vc_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
                          bs, nbt, G, cb):
    """One (kv_head, tile) program: for history tiles the BlockSpec index
    map already resolved table entry ``ti`` to a pool block; for chunk
    tiles it selected the matching slice of the chunk's fp K/V."""
    ti = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (C*G, d)
    is_hist = ti < nbt
    k = jnp.where(is_hist, k_ref[0, 0], kc_ref[0, 0]).astype(jnp.float32)
    v = jnp.where(is_hist, v_ref[0, 0], vc_ref[0, 0]).astype(jnp.float32)
    s = q @ k.T * scale                               # (C*G, bs)
    s = jnp.where(_tile_mask(ti, sc_ref, q.shape[0], bs, G, nbt),
                  s, NEG_INF)
    _accumulate(ti, nbt + cb, s, v, o_ref, m_scr, l_scr, acc_scr)


def _chunk_layouts(q, k_chunk, v_chunk, bs):
    """(1, C, H|Hkv, D) -> kernel layouts: q (Hkv, C*G, D) with query row
    r = (token r // G, group r % G); chunk K/V (Hkv, C/bs, bs, D)."""
    _, C, H, D = q.shape
    Hkv = k_chunk.shape[2]
    G = H // Hkv
    qr = (q.reshape(C, Hkv, G, D).transpose(1, 0, 2, 3)
          .reshape(Hkv, C * G, D))
    kcr = (k_chunk.reshape(C // bs, bs, Hkv, D).transpose(2, 0, 1, 3))
    vcr = (v_chunk.reshape(C // bs, bs, Hkv, D).transpose(2, 0, 1, 3))
    return qr, kcr, vcr


def paged_prefill_attention(q, k_chunk, v_chunk, k_pool, v_pool, table_row,
                            c0, w_eff, *, scale=None, interpret=True):
    """Chunked-prefill attention through a block table.

    q / k_chunk / v_chunk (1, C, H|Hkv, D): one admission chunk's roped
    projections at absolute positions [c0, c0 + C); pools
    (NB, bs, Hkv, D) shared by all requests; table_row (NBt,) int32 — the
    admitting request's block table (sentinel-0 padded); c0, w_eff scalar
    int32.  History (< w_eff) is read through the table; the chunk itself
    (>= w_eff) from the fp operands, so sealing K/V to the pool can
    happen AFTER attention.  Chunk padding queries produce garbage the
    caller discards.  Returns (1, C, H, D)."""
    _, C, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = table_row.shape[0]
    CB = C // bs
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr, kcr, vcr = _chunk_layouts(q, k_chunk, v_chunk, bs)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D)
    vr = v_pool.transpose(2, 0, 1, 3)
    sc = jnp.stack([jnp.asarray(c0, jnp.int32),
                    jnp.asarray(w_eff, jnp.int32)])

    def hist_ix(h, ti, tbl, sc, n=NBt):
        return (h, tbl[jnp.minimum(ti, n - 1)], 0, 0)

    def chunk_ix(h, ti, tbl, sc, n=NBt, c=CB):
        return (h, jnp.clip(ti - n, 0, c - 1), 0, 0)

    kernel = functools.partial(_paged_prefill_kernel, scale=scale, bs=bs,
                               nbt=NBt, G=G, cb=CB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block table + [c0, w_eff]
        grid=(Hkv, NBt + CB),
        in_specs=[
            pl.BlockSpec((1, C * G, D), lambda h, ti, tbl, sc: (h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
        ],
        out_specs=pl.BlockSpec((1, C * G, D),
                               lambda h, ti, tbl, sc: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, C * G, D), q.dtype),
        interpret=interpret,
    )(table_row.astype(jnp.int32), sc, qr, kr, vr, kcr, vcr)
    return (out.reshape(Hkv, C, G, D).transpose(1, 0, 2, 3)
            .reshape(1, C, H, D))


def _paged_prefill_kernel_quant(tbl_ref, sc_ref, q_ref, k_ref, v_ref,
                                ks_ref, vs_ref, kt_ref, vt_ref, kc_ref,
                                vc_ref, o_ref, m_scr, l_scr, acc_scr, *,
                                scale, bs, nbt, G, cb, rtail):
    """int8 variant: history K/V tiles arrive as int8 pool blocks plus
    their per-vector f32 scales (same table-lookup index map) with the
    dequant fused into the gather; the last ``rtail`` HISTORY blocks
    (ending at the newest history block hb, from w_eff) are read from the
    row's fp ring tail instead — attention runs before the chunk seals,
    so the ring still holds exactly those blocks.  Chunk tiles use the fp
    operands like the fp kernel."""
    ti = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (C*G, d)
    k8 = k_ref[0, 0].astype(jnp.float32)              # (bs, d) int8 tile
    v8 = v_ref[0, 0].astype(jnp.float32)
    ks = ks_ref[0, 0].astype(jnp.float32)             # (bs,) f32 scales
    vs = vs_ref[0, 0].astype(jnp.float32)
    kt = kt_ref[0, 0].astype(jnp.float32)             # (bs, d) fp ring tile
    vt = vt_ref[0, 0].astype(jnp.float32)
    kc = kc_ref[0, 0].astype(jnp.float32)             # (bs, d) fp chunk tile
    vc = vc_ref[0, 0].astype(jnp.float32)

    hb = (sc_ref[1] - 1) // bs                        # newest history block
    use_fp = (ti <= hb) & (ti > hb - rtail)           # scalar: ring block?
    is_hist = ti < nbt
    k = jnp.where(is_hist, jnp.where(use_fp, kt, k8 * ks[:, None]), kc)
    v = jnp.where(is_hist, jnp.where(use_fp, vt, v8 * vs[:, None]), vc)
    s = q @ k.T * scale
    s = jnp.where(_tile_mask(ti, sc_ref, q.shape[0], bs, G, nbt),
                  s, NEG_INF)
    _accumulate(ti, nbt + cb, s, v, o_ref, m_scr, l_scr, acc_scr)


def paged_prefill_attention_quant(q, k_chunk, v_chunk, k_pool, v_pool,
                                  k_scale, v_scale, k_tail_row, v_tail_row,
                                  table_row, c0, w_eff, *, scale=None,
                                  interpret=True):
    """Fused-dequant chunked prefill: q / chunk K/V (1, C, H|Hkv, D); int8
    pools (NB, bs, Hkv, D) with f32 scales (NB, bs, Hkv); the admitting
    row's fp ring tail (R*bs, Hkv, D); table_row (NBt,); c0, w_eff
    scalars.  The table gather is unchanged from the fp kernel — only
    history tile contents differ (int8 + scale, or the fp ring slot for
    the last R history blocks).  Returns (1, C, H, D)."""
    _, C, H, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NBt = table_row.shape[0]
    CB = C // bs
    R = k_tail_row.shape[0] // bs
    check_shard_view(H, Hkv)
    G = H // Hkv
    scale = scale or D ** -0.5

    qr, kcr, vcr = _chunk_layouts(q, k_chunk, v_chunk, bs)
    kr = k_pool.transpose(2, 0, 1, 3)                 # (Hkv, NB, bs, D) int8
    vr = v_pool.transpose(2, 0, 1, 3)
    ksr = k_scale.transpose(2, 0, 1)                  # (Hkv, NB, bs) f32
    vsr = v_scale.transpose(2, 0, 1)
    ktr = (k_tail_row.reshape(R, bs, Hkv, D)          # (Hkv, R, bs, D)
           .transpose(2, 0, 1, 3))
    vtr = (v_tail_row.reshape(R, bs, Hkv, D)
           .transpose(2, 0, 1, 3))
    sc = jnp.stack([jnp.asarray(c0, jnp.int32),
                    jnp.asarray(w_eff, jnp.int32)])

    def hist_ix(h, ti, tbl, sc, n=NBt):
        return (h, tbl[jnp.minimum(ti, n - 1)], 0, 0)

    def hist_ix_s(h, ti, tbl, sc, n=NBt):
        return (h, tbl[jnp.minimum(ti, n - 1)], 0)

    def ring_ix(h, ti, tbl, sc, r=R):
        return (h, ti % r, 0, 0)

    def chunk_ix(h, ti, tbl, sc, n=NBt, c=CB):
        return (h, jnp.clip(ti - n, 0, c - 1), 0, 0)

    kernel = functools.partial(_paged_prefill_kernel_quant, scale=scale,
                               bs=bs, nbt=NBt, G=G, cb=CB, rtail=R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block table + [c0, w_eff]
        grid=(Hkv, NBt + CB),
        in_specs=[
            pl.BlockSpec((1, C * G, D), lambda h, ti, tbl, sc: (h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs, D), hist_ix),
            pl.BlockSpec((1, 1, bs), hist_ix_s),
            pl.BlockSpec((1, 1, bs), hist_ix_s),
            pl.BlockSpec((1, 1, bs, D), ring_ix),
            pl.BlockSpec((1, 1, bs, D), ring_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
            pl.BlockSpec((1, 1, bs, D), chunk_ix),
        ],
        out_specs=pl.BlockSpec((1, C * G, D),
                               lambda h, ti, tbl, sc: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, C * G, D), q.dtype),
        interpret=interpret,
    )(table_row.astype(jnp.int32), sc, qr, kr, vr, ksr, vsr, ktr, vtr,
      kcr, vcr)
    return (out.reshape(Hkv, C, G, D).transpose(1, 0, 2, 3)
            .reshape(1, C, H, D))
