"""qwen1.5-32b — dense MHA decoder with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family card, assigned 32B dims] 64L, d_model=5120,
40 heads (kv=40, i.e. full MHA), d_ff=27392, vocab=152064.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B (family card, assigned 32B dims)",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    sliding_window=8192,
)
