"""Train a small decoder end-to-end on the synthetic dialogue corpus
(tokenizer -> packing -> AdamW -> checkpoint), then serve it with recycling.

    PYTHONPATH=src python examples/train_small.py [--steps 200]

This exercises the full training substrate; the serving check at the end
confirms recycled decode is identical on a TRAINED model too (not just
random weights).
"""
import argparse

import jax

from repro.configs import get_config
from repro.data import ByteTokenizer, TrainBatches
from repro.models import init_params
from repro.serving import Engine
from repro.training import save_checkpoint, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=96)
    args = ap.parse_args()

    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ {args.batch}x{args.seq_len}")

    tok = ByteTokenizer(cfg.vocab_size)
    batches = TrainBatches(tok, batch=args.batch, seq_len=args.seq_len)
    params, opt, hist = train(
        cfg, params, batches, steps=args.steps, lr=1e-3, warmup=20,
        log_every=25,
        callback=lambda m: print(f"  step {m['step']:4d} loss {m['loss']:.3f}"))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    save_checkpoint("checkpoints/train_small", params, opt, step=args.steps)

    # recycled serving on the trained model
    eng = Engine(cfg, params, max_new_tokens=12)
    eng.precache(["what do you think about the weather"])
    b = eng.generate("what do you think about the weather today in spring",
                     use_recycling=False)
    r = eng.generate("what do you think about the weather today in spring")
    print(f"recycled reuse={r.reuse_depth}/{r.prompt_tokens} tokens, "
          f"identical output: {b.text == r.text}")
    print(f"sample: {r.text!r}")


if __name__ == "__main__":
    main()
