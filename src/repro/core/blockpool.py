"""Ref-counted block allocator for the paged device KV pool.

The paged pool (PR 2) replaces per-request dense KV rows with one shared
pool of fixed-size token blocks: request r's cache is a *block table* —
a fixed-width vector of pool block ids — and a block may appear in many
tables at once.  This module is the host-side bookkeeping for that pool:

  * ``alloc()``      hand out a free block with refcount 1
  * ``ref(b)``       another holder (a table or the radix tier) shares b
  * ``unref(b)``     drop one holder; refcount 0 returns b to the free list

Block 0 is the **sentinel**: block tables are padded with it so inactive
pool rows and not-yet-allocated table entries route their (masked) writes
into one harmless scratch block.  It is pinned — never allocated, never
freed, never counted as live.

Invariants (property-tested in tests/test_paged_pool.py):

  I1  refcounts are >= 0; live blocks (refcount > 0) have refcount equal
      to the number of holders that acquired them
  I2  free-list and live sets are disjoint and together cover every
      non-sentinel block
  I3  a block is handed out at most once between free()s (no aliasing)

A **reserved-but-unfilled** block (speculative pre-allocation: decode
reserves the next table entry before the row's write position reaches it)
is indistinguishable from any other refcount-1 holding at this layer —
it is live, named by exactly one table, and returns through the same
``unref`` when its row releases, so I1-I3 cover it with no extra state.
What makes it "reserved" is purely that the owning row's fill has not
reached its positions yet, and the implicit-position masking upstream
guarantees nothing ever reads them.

Copy-on-write lives one level up (serving/paged.py): a shared block is
never written in place — divergence materializes a fresh block and the
new holder's table points at the copy.  The allocator only guarantees the
accounting that makes "is this block exclusively mine?" a cheap question
(``refcount(b) == 1``).
"""
from __future__ import annotations

from typing import List, Set

SENTINEL = 0


class BlockPoolExhausted(RuntimeError):
    """alloc() found no free block (caller should evict or reject)."""


class PoolSaturated(RuntimeError):
    """Admission cannot be covered RIGHT NOW but in-flight rows will free
    blocks as they finish — a transient, not a permanent reject.  The
    scheduler keeps the request queued and retries on a later step;
    ``ValueError`` stays the permanent "can never fit" reject."""


class BlockAllocator:
    """Free-list + refcount accounting over ``num_blocks`` pool blocks."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (sentinel + 1 usable)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list: recently freed blocks are re-used first (their
        # pool pages are warm); sentinel 0 is excluded for good.
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._refs: List[int] = [0] * num_blocks
        self.stats = {"allocs": 0, "frees": 0, "shares": 0, "peak_live": 0}
        # optional core.faults.FaultPlan; "alloc" site simulates exhaustion
        self.fault_plan = None

    # ------------------------------------------------------------------
    def alloc(self) -> int:
        """A fresh block with refcount 1; raises BlockPoolExhausted."""
        if self.fault_plan is not None and self.fault_plan.should_fire("alloc"):
            raise BlockPoolExhausted("injected: alloc fault")
        if not self._free:
            raise BlockPoolExhausted(
                f"no free blocks (pool={self.num_blocks}, "
                f"live={self.num_live()})")
        b = self._free.pop()
        self._refs[b] = 1
        self.stats["allocs"] += 1
        self.stats["peak_live"] = max(self.stats["peak_live"],
                                      self.num_live())
        return b

    def alloc_many(self, n: int):
        """``n`` fresh blocks with refcount 1, atomically: the free-list
        check happens before anything is popped, so either all ``n`` are
        handed out or none is — a multi-block reservation can never
        strand a partial grab.  The batched analogue of calling ``alloc``
        n times; callers that can evict fall back to their per-block
        eviction loop when this raises."""
        if self.fault_plan is not None and self.fault_plan.should_fire("alloc"):
            raise BlockPoolExhausted("injected: alloc_many fault")
        if len(self._free) < n:
            raise BlockPoolExhausted(
                f"need {n} blocks, {len(self._free)} free "
                f"(pool={self.num_blocks}, live={self.num_live()})")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._refs[b] = 1
        self.stats["allocs"] += n
        self.stats["peak_live"] = max(self.stats["peak_live"],
                                      self.num_live())
        return out

    def ref(self, block: int) -> int:
        """Acquire one more reference to a live block."""
        if block == SENTINEL:
            raise ValueError("cannot ref the sentinel block")
        if self._refs[block] <= 0:
            raise ValueError(f"ref of dead block {block}")
        self._refs[block] += 1
        self.stats["shares"] += 1
        return self._refs[block]

    def unref(self, block: int) -> int:
        """Drop one reference; refcount 0 frees the block.  Returns the
        remaining refcount."""
        if block == SENTINEL:
            raise ValueError("cannot unref the sentinel block")
        if self._refs[block] <= 0:
            raise ValueError(f"unref of dead block {block}")
        self._refs[block] -= 1
        if self._refs[block] == 0:
            self._free.append(block)
            self.stats["frees"] += 1
        return self._refs[block]

    def unref_many(self, blocks) -> int:
        """Drop one reference from each block in ``blocks`` — the
        speculative-rollback primitive: a rejected draft tail's reserved
        blocks leave their table and return here in one call.  Validity
        is checked for ALL blocks before any is released, so a bad id
        (sentinel, dead block) can never strand a partial rollback.
        Returns how many blocks the call actually freed (refcount hit
        0)."""
        blocks = list(blocks)
        for b in blocks:
            if b == SENTINEL:
                raise ValueError("cannot unref the sentinel block")
            if self._refs[b] <= 0:
                raise ValueError(f"unref of dead block {b}")
        freed = 0
        for b in blocks:
            if self.unref(b) == 0:
                freed += 1
        return freed

    # ------------------------------------------------------------------
    def refcount(self, block: int) -> int:
        return self._refs[block]

    def num_free(self) -> int:
        return len(self._free)

    def num_live(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    def live_blocks(self) -> Set[int]:
        return {b for b in range(1, self.num_blocks) if self._refs[b] > 0}

    def free_blocks(self) -> Set[int]:
        return set(self._free)

    # ------------------------------------------------------------------
    def check(self) -> None:
        """Assert the allocator invariants (used by tests and the paged
        engine's debug mode)."""
        free = self.free_blocks()
        live = self.live_blocks()
        assert SENTINEL not in free and SENTINEL not in live
        assert not (free & live), f"aliased blocks: {free & live}"
        assert free | live == set(range(1, self.num_blocks)), \
            "free ∪ live must cover every non-sentinel block"
        assert len(self._free) == len(free), "duplicate free-list entries"
        assert all(r >= 0 for r in self._refs)
