"""Semantic block-donor recycling (PagedEngine ``semantic=True``): a
prefix-MISS prompt grafts interior donor blocks and recomputes only the
boundary; the fidelity gate can refuse the graft, and a refusal — or
``semantic=False`` — is token-identical to the plain engine.  The mode is
off by default and must leave the exact/partial/miss paths untouched.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import HostKVStore, Recycler
from repro.data.tokenizer import EOS
from repro.models import init_params
from repro.serving import ContinuousBatchingScheduler, PagedEngine

# 64 shared characters = 8 full blocks at block_size 8; the 7-char head
# (+BOS) fills exactly one differing block, so donor and query share
# blocks 1..8 at the SAME positions while sharing no prefix
HEAD_A = "aaaaaaa"
HEAD_B = "bbbbbbb"
MID = "the quick brown fox jumps over the lazy dog again and again!!!!"
DONOR = HEAD_A + MID
QUERY = HEAD_B + MID

CACHED = [
    "the quick brown fox jumps over the lazy dog today",
    "what is the capital of france and why",
]
REQUESTS = [
    (CACHED[0] + " and tomorrow", "exact_prefix"),
    ("the quick brown fox jumps over a red fence", "partial_block"),
    ("zzz qqq completely unrelated 12345", "miss"),
    (CACHED[1] + " is it paris", "exact_prefix"),
]


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _paged(stack, *, quant=False, max_new=6, precache=None, **kw):
    cfg, params = stack
    kw.setdefault("prefill_mode", "chunked")
    eng = PagedEngine(cfg, params, max_batch=3, capacity=128,
                      max_new_tokens=max_new, block_size=8,
                      enable_partial=True, kv_quant=quant, **kw)
    if precache:
        eng.precache(precache)
    return eng


def _run(eng, prompts, **submit_kw):
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, **submit_kw) for p in prompts]
    sched.run()
    eng.check_invariants()
    return reqs


# ---------------------------------------------------------------------------
# the tentpole: reuse where exact-prefix sees nothing
# ---------------------------------------------------------------------------
def test_graft_reuses_where_prefix_paths_report_zero(stack):
    """Acceptance: the query shares no prefix with the donor (both
    prefix paths report depth 0) but 6 interior blocks of shared middle
    are grafted — reuse depth 48 at block_size 8."""
    plain = _paged(stack, semantic=False)
    _run(plain, [DONOR], admit=True)
    r0 = _run(plain, [QUERY])[0].result
    assert r0.mode == "miss" and r0.reuse_depth == 0

    # model weights are random (reduced test config), so the recomputed
    # boundary diverges numerically from the donor's — a permissive gate
    # isolates the graft plumbing from the fidelity policy (tested below)
    eng = _paged(stack, semantic=True, graft_max_div=1e9)
    _run(eng, [DONOR], admit=True)     # donor blocks stay trie-resident
    r = _run(eng, [QUERY])[0].result
    assert r.cache_hit and r.mode == "semantic_block"
    # prompt = BOS + 71 chars = 72 tokens = 9 blocks; block 0 (head)
    # differs, block 8 holds the final token (always recomputed), block 1
    # is the recomputed boundary -> interior blocks [2, 8) are grafted
    assert r.reuse_depth == 48
    assert eng.stats["semantic_grafts"] == 1
    assert eng.stats["semantic_resident_grafts"] == 1
    assert eng.stats["tokens_grafted"] == 48
    assert len(eng.semantic_gate_divs) == 1
    assert len(r.text) > 0


def test_gate_refusal_is_token_identical_to_plain(stack):
    """graft_max_div=0 refuses every graft; the fallback recompute must
    be byte-for-byte the semantic=False output (nothing approximate may
    leak from a refused graft)."""
    plain = _paged(stack, semantic=False, precache=[DONOR])
    want = _run(plain, [QUERY])[0].result

    eng = _paged(stack, semantic=True, graft_max_div=0.0,
                 precache=[DONOR])
    r = _run(eng, [QUERY])[0].result
    assert eng.stats["semantic_refusals"] == 1
    assert eng.stats["semantic_grafts"] == 0
    assert r.mode == "miss" and not r.cache_hit
    assert r.text == want.text
    np.testing.assert_array_equal(r.token_ids, want.token_ids)


@pytest.mark.parametrize("quant", [False, True], ids=["fp", "int8"])
def test_refusal_identity_under_early_eos(stack, quant, monkeypatch):
    """Refused grafts stay token-identical even when remapped greedy
    forces early EOS mid-batch, fp and int8."""
    import repro.serving.engine as engine_mod

    def eos_greedy(logits):
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(g % 5 == 1, jnp.int32(EOS), g)

    monkeypatch.setattr(engine_mod, "greedy", eos_greedy)
    plain = _paged(stack, quant=quant, semantic=False, max_new=8,
                   precache=[DONOR])
    wants = _run(plain, [QUERY, REQUESTS[2][0]])

    eng = _paged(stack, quant=quant, semantic=True, graft_max_div=0.0,
                 max_new=8, precache=[DONOR])
    got = _run(eng, [QUERY, REQUESTS[2][0]])
    assert eng.stats["semantic_refusals"] == 1
    for w, g in zip(wants, got):
        assert g.result.text == w.result.text
        np.testing.assert_array_equal(g.result.token_ids,
                                      w.result.token_ids)


def test_int8_graft_accepts_and_decodes(stack):
    """The graft path works on the int8 pool: verbatim q8 interior
    movement plus the fp ring-tail reseed at the graft boundary."""
    eng = _paged(stack, quant=True, semantic=True, graft_max_div=1e9)
    _run(eng, [DONOR], admit=True)
    r = _run(eng, [QUERY])[0].result
    assert r.mode == "semantic_block" and r.reuse_depth == 48
    assert eng.stats["semantic_grafts"] == 1
    assert eng.stats["semantic_resident_grafts"] == 1
    assert len(r.text) > 0


# ---------------------------------------------------------------------------
# the mode must not perturb the existing paths
# ---------------------------------------------------------------------------
def test_prefix_paths_unchanged_with_semantic_on(stack):
    """exact/partial/miss requests behave identically whether semantic
    mode is on or off — grafting only ever fires on a prefix miss with a
    qualifying donor."""
    off = _paged(stack, semantic=False, precache=CACHED)
    on = _paged(stack, semantic=True, precache=CACHED)
    roff = _run(off, [p for p, _ in REQUESTS])
    ron = _run(on, [p for p, _ in REQUESTS])
    for (p, want), a, b in zip(REQUESTS, roff, ron):
        assert b.result.mode == a.result.mode, p
        assert b.result.text == a.result.text, p
        np.testing.assert_array_equal(b.result.token_ids,
                                      a.result.token_ids)
    assert on.stats["semantic_grafts"] == 0
    assert on.stats["semantic_refusals"] == 0


def test_semantic_requires_chunked_prefill(stack):
    with pytest.raises(ValueError, match="chunked"):
        _paged(stack, semantic=True, prefill_mode="staged")


# ---------------------------------------------------------------------------
# host-tier graft: donor lives only in the (reloaded) host store
# ---------------------------------------------------------------------------
def test_host_graft_from_reloaded_store(stack):
    """A fresh engine over a store reloaded from disk has an empty
    device trie, so the donor's interior blocks must be promoted from
    host block-by-block — and the reload-rebuilt LSH must surface the
    donor at all."""
    warm = _paged(stack, semantic=False, precache=[DONOR])
    with tempfile.TemporaryDirectory() as d:
        warm.recycler.store.save_dir(d)
        rec = Recycler(HostKVStore.load_dir(d), enable_partial=True,
                       block_size=8, semantic=True)
        eng = _paged(stack, recycler=rec, semantic=True,
                     graft_max_div=1e9)
        r = _run(eng, [QUERY])[0].result
    assert r.mode == "semantic_block" and r.reuse_depth == 48
    assert eng.stats["semantic_host_grafts"] == 1
    assert eng.stats["semantic_resident_grafts"] == 0
    assert eng.stats["h2d_copies"] > 0
