"""Token sampling: deterministic greedy (the paper's do_sample=False) plus
temperature / top-k for the examples."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_logits(logits, rng, *, temperature: float = 1.0, top_k: int = 0):
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[..., -1:], -jnp.inf, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)
