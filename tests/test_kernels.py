"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 64, 4, 2, 32), (1, 128, 8, 8, 64), (2, 96, 4, 1, 16),
    (1, 256, 2, 2, 128),
])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, Hkv, D, window, dtype):
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32)
    exp = ref.ref_flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_q_start():
    """Recycled prefill: q block positions offset by the reuse depth."""
    B, S, H, D, k0 = 1, 64, 2, 32, 32
    q = jnp.asarray(RNG.standard_normal((B, S - k0, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, q_start=k0,
                              block_q=32, block_k=32)
    exp = ref.ref_flash_attention(q, k, v, causal=True, q_start=k0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,Hkv,D,C", [
    (2, 4, 2, 32, 64), (1, 8, 1, 64, 128), (3, 2, 2, 16, 96),
])
@pytest.mark.parametrize("window", [0, 20])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, Hkv, D, C, window, dtype):
    q = jnp.asarray(RNG.standard_normal((B, 1, H, D)), dtype)
    kc = jnp.asarray(RNG.standard_normal((B, C, Hkv, D)), dtype)
    vc = jnp.asarray(RNG.standard_normal((B, C, Hkv, D)), dtype)
    pos = 70
    slot_pos = np.full(C, -1, np.int32)
    for p in range(max(0, pos + 1 - C), pos + 1):
        slot_pos[p % C] = p
    sp = jnp.asarray(slot_pos)
    out = ops.decode_attention(q, kc, vc, sp, jnp.int32(pos), window=window,
                               block_k=32)
    exp = ref.ref_decode_attention(q, kc, vc, sp, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_decode_attention_empty_slots():
    """Half-filled cache: empty slots (-1) are masked out."""
    B, H, D, C = 1, 2, 16, 64
    q = jnp.asarray(RNG.standard_normal((B, 1, H, D)), jnp.float32)
    kc = jnp.asarray(RNG.standard_normal((B, C, H, D)), jnp.float32)
    vc = jnp.asarray(RNG.standard_normal((B, C, H, D)), jnp.float32)
    sp = jnp.asarray(np.where(np.arange(C) < 20, np.arange(C), -1), jnp.int32)
    out = ops.decode_attention(q, kc, vc, sp, jnp.int32(19), block_k=16)
    exp = ref.ref_decode_attention(q, kc, vc, sp, 19)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,D", [(2, 64, 2, 16), (1, 48, 4, 32),
                                     (1, 33, 1, 64)])
def test_rwkv6_wkv(B, S, H, D):
    r = jnp.asarray(RNG.standard_normal((B, S, H, D)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, D)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, D)) * 0.5, jnp.float32)
    w = jnp.asarray(RNG.uniform(0.7, 0.999, (B, S, H, D)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, D)) * 0.5, jnp.float32)
    s0 = jnp.asarray(RNG.standard_normal((B, H, D, D)) * 0.1, jnp.float32)
    y, sT = ops.rwkv6_wkv(r, k, v, w, u, s0, chunk=16)
    ye, sTe = ref.ref_rwkv6_wkv(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sTe),
                               rtol=2e-4, atol=2e-4)


def test_rwkv6_wkv_state_continuity():
    """Two chunked calls == one long call (state carry across calls)."""
    B, S, H, D = 1, 64, 2, 16
    r, k, v = (jnp.asarray(RNG.standard_normal((B, S, H, D)) * 0.5,
                           jnp.float32) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.8, 0.999, (B, S, H, D)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, D)) * 0.5, jnp.float32)
    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    y_all, s_all = ops.rwkv6_wkv(r, k, v, w, u, s0)
    h = S // 2
    y1, s1 = ops.rwkv6_wkv(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, s0)
    y2, s2 = ops.rwkv6_wkv(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, s1)
    np.testing.assert_allclose(np.asarray(y_all[:, h:]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_all), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,S,W", [(2, 64, 32), (1, 48, 128), (3, 37, 16)])
def test_rglru_scan(B, S, W):
    a = jnp.asarray(RNG.uniform(0.2, 0.999, (B, S, W)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((B, S, W)), jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((B, W)), jnp.float32)
    y, hT = ops.rglru_scan(a, b, h0, chunk=16, block_w=16)
    ye, hTe = ref.ref_rglru_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTe),
                               rtol=1e-6, atol=1e-6)


def test_rglru_scan_state_continuity():
    B, S, W = 1, 64, 32
    a = jnp.asarray(RNG.uniform(0.5, 0.999, (B, S, W)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((B, S, W)), jnp.float32)
    h0 = jnp.zeros((B, W), jnp.float32)
    y_all, h_all = ops.rglru_scan(a, b, h0)
    h = S // 2
    _, h1 = ops.rglru_scan(a[:, :h], b[:, :h], h0)
    y2, h2 = ops.rglru_scan(a[:, h:], b[:, h:], h1)
    np.testing.assert_allclose(np.asarray(y_all[:, h:]), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)
