"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.radix import RadixPrefixCache
from repro.core.recycler import common_prefix_len, trim_to_depth
from repro.data.tokenizer import ByteTokenizer

tokens_st = st.lists(st.integers(0, 50), min_size=0, max_size=40)


class TestRadixInvariants:
    @given(st.lists(tokens_st, min_size=1, max_size=8), tokens_st,
           st.integers(1, 5))
    @settings(max_examples=200, deadline=None)
    def test_lookup_is_true_block_prefix(self, corpus, query, block):
        """I1/I2: lookup depth is block-aligned, <= len(query), the returned
        entry really covers that prefix, and depth is maximal."""
        r = RadixPrefixCache(block_size=block)
        corpus = [np.asarray(c, np.int32) for c in corpus]
        for i, c in enumerate(corpus):
            r.insert(c, i)
        q = np.asarray(query, np.int32)
        depth, eid = r.lookup(q)
        assert depth % block == 0 and depth <= len(q)
        if eid is not None:
            assert depth > 0
            assert common_prefix_len(q, corpus[eid]) >= depth
        # maximality over the corpus
        best = 0
        for c in corpus:
            lcp = common_prefix_len(q, c)
            best = max(best, (min(lcp, (len(c) // block) * block)
                              // block) * block)
        assert depth == best

    @given(st.lists(tokens_st, min_size=2, max_size=6), st.data())
    @settings(max_examples=100, deadline=None)
    def test_forget_makes_unreachable(self, corpus, data):
        """I3: forgotten entries never serve a hit."""
        r = RadixPrefixCache(block_size=2)
        for i, c in enumerate(corpus):
            r.insert(np.asarray(c, np.int32), i)
        victim = data.draw(st.integers(0, len(corpus) - 1))
        r.forget_entry(victim)
        for c in corpus:
            depth, eid = r.lookup(np.asarray(c, np.int32))
            assert eid != victim

    @given(tokens_st, st.integers(1, 4))
    @settings(max_examples=100, deadline=None)
    def test_self_lookup(self, toks, block):
        """Inserting t then looking up t finds floor(len/block)*block."""
        r = RadixPrefixCache(block_size=block)
        t = np.asarray(toks, np.int32)
        r.insert(t, 0)
        depth, eid = r.lookup(t)
        assert depth == (len(t) // block) * block
        assert (eid == 0) == (depth > 0)


class TestPrefixProperties:
    @given(tokens_st, tokens_st)
    @settings(max_examples=200, deadline=None)
    def test_common_prefix_len_definition(self, a, b):
        r = common_prefix_len(a, b)
        assert a[:r] == b[:r]
        if r < min(len(a), len(b)):
            assert a[r] != b[r]

    @given(st.text(max_size=60), st.text(max_size=30))
    @settings(max_examples=200, deadline=None)
    def test_tokenizer_prefix_consistency(self, base, ext):
        """Text prefix => token prefix (what makes the paper's exact prefix
        test equivalent to a text-prefix test)."""
        tok = ByteTokenizer(512)
        a = tok.encode(base)
        ab = tok.encode(base + ext)
        assert common_prefix_len(a, ab) == len(a)

    @given(st.text(max_size=60))
    @settings(max_examples=100, deadline=None)
    def test_tokenizer_deterministic(self, text):
        tok = ByteTokenizer(1024)
        np.testing.assert_array_equal(tok.encode(text), tok.encode(text))


class TestTrimProperty:
    @given(st.integers(0, 20), st.integers(1, 20))
    @settings(max_examples=50, deadline=None)
    def test_trim_never_exposes_deeper_positions(self, depth, filled):
        sp = np.where(np.arange(20) < filled, np.arange(20), -1)
        cache = {"k": np.zeros((1, 20, 1, 2)), "slot_pos": sp.astype(np.int32)}
        t = trim_to_depth(cache, depth)
        assert (t["slot_pos"] < depth).all()
        # positions below depth that existed are preserved
        keep = (sp >= 0) & (sp < depth)
        np.testing.assert_array_equal(t["slot_pos"][keep], sp[keep])
