"""Data pipeline: the paper's prompt sets + a synthetic training corpus.

``paper_prompt_sets`` reproduces §4.3's construction exactly: a cache set of
concise knowledge queries and a test set of *extended-prefix* variants
("near duplicate and extended prefix cases, precisely the scenarios where
token recycling should offer measurable benefit"), persisted as CSVs
(data/cache_prompts.csv, data/test_prompts.csv) like the notebook.

``SyntheticDialogues`` generates deterministic Reddit-exchange-shaped text
for training the ~100M example model; ``TrainBatches`` packs it to
(global_batch, seq_len) token blocks.
"""
from __future__ import annotations

import csv
import os
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.data.tokenizer import ByteTokenizer, EOS

# --- the paper's prompt design (§4.3) --------------------------------------
CACHE_PROMPTS = [
    "Explain machine learning in simple terms.",
    "What is the capital of France?",
    "How do airplanes fly?",
    "What causes rain to fall?",
    "Describe how photosynthesis works.",
    "What is the speed of light?",
    "Explain how vaccines protect the body.",
    "Why is the sky blue during the day?",
    "What is a black hole in space?",
    "How do computers store information?",
]

TEST_PROMPTS = [
    "Explain machine learning in simple terms. Give an example application.",
    "What is the capital of France? Also mention a nearby tourist destination.",
    "How do airplanes fly? Keep the answer short.",
    "What causes rain to fall? Explain for a child.",
    "Describe how photosynthesis works. Focus on the role of sunlight.",
    "What is the speed of light? State it in kilometers per second.",
]


def paper_prompt_sets(data_dir: Optional[str] = None
                      ) -> Tuple[List[str], List[str]]:
    """Returns (cache_prompts, test_prompts); writes the notebook's CSVs."""
    if data_dir:
        os.makedirs(data_dir, exist_ok=True)
        for name, rows in (("cache_prompts.csv", CACHE_PROMPTS),
                           ("test_prompts.csv", TEST_PROMPTS)):
            with open(os.path.join(data_dir, name), "w", newline="") as f:
                wr = csv.writer(f)
                wr.writerow(["prompt"])
                wr.writerows([[r] for r in rows])
    return list(CACHE_PROMPTS), list(TEST_PROMPTS)


# --- synthetic corpus -------------------------------------------------------
_TOPICS = ["the weather", "a new phone", "a football match", "cooking pasta",
           "a sci-fi movie", "guitar practice", "a road trip", "gardening",
           "video games", "the stock market", "a history book", "running"]
_OPENERS = ["what do you think about", "anyone else into", "need advice on",
            "just tried", "can someone explain", "hot take about"]
_REPLIES = ["honestly it depends on the details.",
            "i had the same experience last week.",
            "source? that does not sound right.",
            "great point, never thought of it that way.",
            "this is the way.", "counterpoint: not always true."]


class SyntheticDialogues:
    """Deterministic pseudo-Reddit exchanges (seeded), shaped like the
    DialoGPT training distribution the paper describes."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def sample(self) -> str:
        op = self.rng.choice(_OPENERS)
        topic = self.rng.choice(_TOPICS)
        n_turns = int(self.rng.integers(1, 4))
        turns = [f"{op} {topic}?"]
        turns += [str(self.rng.choice(_REPLIES)) for _ in range(n_turns)]
        return " <eot> ".join(turns)

    def stream(self) -> Iterator[str]:
        while True:
            yield self.sample()


class TrainBatches:
    """Pack the dialogue stream into (batch, seq_len) int32 blocks with EOS
    separators — a minimal but real packed-LM pipeline."""

    def __init__(self, tokenizer: ByteTokenizer, batch: int, seq_len: int,
                 seed: int = 0):
        self.tok = tokenizer
        self.batch = batch
        self.seq_len = seq_len
        self._src = SyntheticDialogues(seed).stream()
        self._buf = np.zeros((0,), np.int32)

    def _fill(self, n: int) -> np.ndarray:
        while len(self._buf) < n:
            ids = self.tok.encode(next(self._src))
            self._buf = np.concatenate(
                [self._buf, ids, np.asarray([EOS], np.int32)])
        out, self._buf = self._buf[:n], self._buf[n:]
        return out

    def __iter__(self):
        return self

    def __next__(self):
        flat = self._fill(self.batch * self.seq_len)
        return {"tokens": flat.reshape(self.batch, self.seq_len)}
