"""Paper-reproduction benchmarks — one per table/figure in the paper.

  table1            §5.1 summary table (hit rate, tokens reused, speedups,
                    output similarity, latencies)
  latency_fig       §5.2 per-prompt baseline-vs-recycled latency
  speedup_vs_depth  §5.5 S ≈ alpha * k/m — controlled prefix-fraction sweep
                    and the fitted alpha

Default model: reduced DialoGPT (CPU-friendly).  ``--full`` runs the paper's
real 345M config (slow on 1 CPU core).  Magnitudes differ from the paper's
T4 — the *claims* under test are the mechanism ones: 100% hit rate on the
designed prompt set, identical greedy outputs, latency strictly improved by
prefix skipping, and speedup growing with reuse fraction.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.configs import get_config
from repro.core import HashEmbedder
from repro.core.metrics import RunMetrics, summarize_runs
from repro.data.pipeline import paper_prompt_sets
from repro.models import init_params
from repro.serving import Engine


def _engine(full: bool = False, max_new: int = 12) -> Engine:
    cfg = get_config("dialogpt-medium")
    if not full:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, max_new_tokens=max_new, block_size=16)


def _two_phase(eng: Engine, prompts, repeats: int = 3):
    """Warm both shapes, then best-of-N timing per method (paper uses single
    runs on a GPU with no jit; best-of-N suppresses CPU scheduler noise)."""
    rows_b, rows_r = [], []
    for p in prompts:
        eng.warmup(p, use_recycling=False)
        eng.warmup(p)
        best_b, best_r = None, None
        for _ in range(repeats):
            b = eng.generate(p, use_recycling=False)
            r = eng.generate(p)
            if best_b is None or b.latency_s < best_b.latency_s:
                best_b = b
            if best_r is None or r.latency_s < best_r.latency_s:
                best_r = r
        rows_b.append(RunMetrics(p, "baseline", best_b.latency_s,
                                 best_b.prompt_tokens, best_b.gen_tokens,
                                 output_text=best_b.text))
        rows_r.append(RunMetrics(p, "recycled", best_r.latency_s,
                                 best_r.prompt_tokens, best_r.gen_tokens,
                                 best_r.reuse_depth, best_r.cache_hit,
                                 best_r.prompt_similarity, best_r.mode,
                                 best_r.text))
    return rows_b, rows_r


def table1(full: bool = False):
    """Paper Table 1 (§5.1).  Returns CSV rows (name, us_per_call, derived)."""
    eng = _engine(full)
    cache_prompts, test_prompts = paper_prompt_sets()
    eng.precache(cache_prompts)
    rows_b, rows_r = _two_phase(eng, test_prompts)
    t = summarize_runs(rows_b, rows_r, embedder=HashEmbedder())
    out = []
    out.append(("table1.cache_hits", 0.0,
                f"{t['cache_hits']}/{t['total_prompts']}"))
    out.append(("table1.total_tokens_reused", 0.0,
                str(t["total_tokens_reused"])))
    out.append(("table1.avg_speedup_pct", 0.0,
                f"{t['avg_speedup_pct']:.2f}"))
    out.append(("table1.avg_output_similarity", 0.0,
                f"{t['avg_output_similarity']:.3f}"))
    out.append(("table1.avg_prompt_similarity", 0.0,
                f"{t['avg_prompt_similarity']:.3f}"))
    out.append(("table1.latency_baseline_avg", t["latency_baseline_avg_s"] * 1e6,
                f"{t['latency_baseline_avg_s']:.4f}s"))
    out.append(("table1.latency_recycled_avg", t["latency_recycled_avg_s"] * 1e6,
                f"{t['latency_recycled_avg_s']:.4f}s"))
    out.append(("table1.high_similarity_prompts", 0.0,
                f"{t['high_similarity_prompts']}/{t['total_prompts']}"))
    return out, (rows_b, rows_r)


def latency_fig(rows=None, full: bool = False):
    """§5.2 per-prompt latency pairs."""
    if rows is None:
        _, rows = table1(full)
    rows_b, rows_r = rows
    out = []
    for b, r in zip(rows_b, rows_r):
        sp = (b.latency_s - r.latency_s) / b.latency_s * 100
        out.append((f"latency.prompt{rows_b.index(b)}",
                    r.latency_s * 1e6,
                    f"base={b.latency_s*1e3:.1f}ms;rec={r.latency_s*1e3:.1f}ms;"
                    f"speedup={sp:.1f}%;k={r.reuse_depth}/{r.prompt_tokens}"))
    return out


def speedup_vs_depth(full: bool = False, repeats: int = 3):
    """§5.5: controlled k/m sweep.  One long base prompt; test prompts share
    a prefix of fraction f in {1/8..7/8}; fit S ~ alpha * k/m.

    max_new_tokens=2 keeps the run prefill-dominated — the regime the
    paper's S ≈ alpha*k/m model describes (the relation washes out when
    decode time dominates; see EXPERIMENTS.md §Paper-repro)."""
    eng = _engine(full, max_new=2)
    base_text = ("the history of computing begins with mechanical devices "
                 "and moves through vacuum tubes transistors and integrated "
                 "circuits toward modern accelerators and beyond ") * 4
    words = base_text.split()
    fracs = [1 / 8, 1 / 4, 3 / 8, 1 / 2, 5 / 8, 3 / 4, 7 / 8]
    pts = []
    out = []
    for f in fracs:
        n = max(1, int(len(words) * f))
        prefix = " ".join(words[:n])
        probe = prefix + " and further details follow here"
        eng.precache([prefix])
        eng.warmup(probe, use_recycling=False)
        eng.warmup(probe)
        lb = min(eng.generate(probe, use_recycling=False).latency_s
                 for _ in range(repeats))
        res = [eng.generate(probe) for _ in range(repeats)]
        lr = min(r.latency_s for r in res)
        r0 = res[0]
        k_over_m = r0.reuse_depth / r0.prompt_tokens
        s = (lb - lr) / lb
        pts.append((k_over_m, s))
        out.append((f"speedup_vs_depth.f{f:.3f}", lr * 1e6,
                    f"k/m={k_over_m:.3f};S={s*100:.1f}%"))
    km = np.asarray([p[0] for p in pts])
    ss = np.asarray([p[1] for p in pts])
    alpha = float(np.sum(km * ss) / np.sum(km * km))   # through-origin fit
    out.append(("speedup_vs_depth.alpha", 0.0,
                f"alpha={alpha:.3f} (paper: 1.2-1.5 on T4)"))
    return out
