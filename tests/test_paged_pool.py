"""Paged block-table pool: token-for-token equivalence with the dense
slot pool (and therefore with serial ``generate``) across every admission
mode, real prefix sharing (refcount > 1, fewer device bytes than the
dense pool), zero host→device traffic on warm-prefix admissions, and the
block-allocator invariants (refcounts, free/live partition, no aliasing).

The dense ``BatchedEngine`` stays the equivalence reference: every paged
behavior is asserted against it (or serial) rather than against golden
outputs.  Property-style allocator tests run only when hypothesis is
installed, mirroring tests/test_slot_pool.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import BlockAllocator, BlockPoolExhausted, BlockTrie
from repro.data.tokenizer import EOS
from repro.models import init_params
from repro.models.cache import cache_bytes
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           Engine, PagedEngine)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CACHED = [
    "the quick brown fox jumps over the lazy dog today",
    "what is the capital of france and why",
]
REQUESTS = [
    (CACHED[0] + " and tomorrow", "exact_prefix"),
    ("the quick brown fox jumps over a red fence", "partial_block"),
    ("zzz qqq completely unrelated 12345", "miss"),
    (CACHED[1] + " is it paris", "exact_prefix"),
]


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engines(stack, *, max_new=6, max_batch=3, capacity=128):
    cfg, params = stack
    ser = Engine(cfg, params, max_new_tokens=max_new, block_size=8,
                 enable_partial=True)
    ser.precache(CACHED)
    pag = PagedEngine(cfg, params, max_batch=max_batch, capacity=capacity,
                      max_new_tokens=max_new, block_size=8,
                      enable_partial=True)
    pag.precache(CACHED)
    return ser, pag


# ---------------------------------------------------------------------------
# equivalence: paged == dense == serial, all admission modes
# ---------------------------------------------------------------------------
def test_paged_equals_serial_all_modes(stack):
    ser, pag = _engines(stack)
    serial = {p: ser.generate(p) for p, _ in REQUESTS}

    sched = ContinuousBatchingScheduler(pag)
    reqs = [sched.submit(p) for p, _ in REQUESTS]
    sched.run()
    pag.check_invariants()

    for (p, want_mode), req in zip(REQUESTS, reqs):
        s, b = serial[p], req.result
        # the paged tier may upgrade a host hit to resident_block when the
        # prefix became device-resident mid-batch; tokens must not drift
        assert b.mode in (want_mode, "resident_block"), (p, b.mode)
        assert b.text == s.text, (p, b.mode)
        np.testing.assert_array_equal(b.token_ids, s.token_ids)
        assert b.gen_tokens == s.gen_tokens
        assert b.prompt_tokens == s.prompt_tokens


def test_paged_equals_dense_pool(stack):
    """Same workload through the dense slot pool and the paged pool over
    identical recycler contents: identical tokens, request by request."""
    cfg, params = stack
    dense = BatchedEngine(cfg, params, max_batch=3, capacity=128,
                          max_new_tokens=6, block_size=8,
                          enable_partial=True)
    dense.precache(CACHED)
    _, pag = _engines(stack)

    dsched = ContinuousBatchingScheduler(dense)
    dreqs = [dsched.submit(p) for p, _ in REQUESTS]
    dsched.run()
    psched = ContinuousBatchingScheduler(pag)
    preqs = [psched.submit(p) for p, _ in REQUESTS]
    psched.run()

    for (p, _), d, q in zip(REQUESTS, dreqs, preqs):
        assert q.result.text == d.result.text, p
        np.testing.assert_array_equal(q.result.token_ids,
                                      d.result.token_ids)


def test_paged_early_eos_equivalence(stack, monkeypatch):
    """Early-EOS rows free their blocks mid-flight; survivors must keep
    decoding exactly like their serial runs (same greedy remap trick as
    the dense-pool test, patched once for all three paths)."""
    import repro.serving.engine as engine_mod

    def eos_greedy(logits):
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(g % 5 == 1, jnp.int32(EOS), g)

    monkeypatch.setattr(engine_mod, "greedy", eos_greedy)
    ser, pag = _engines(stack, max_new=8)
    serial = {p: ser.generate(p) for p, _ in REQUESTS}
    assert any(r.gen_tokens < 8 and r.token_ids[-1] == EOS
               for r in serial.values()), "remap produced no early EOS"

    sched = ContinuousBatchingScheduler(pag)
    reqs = [sched.submit(p) for p, _ in REQUESTS]
    sched.run()
    pag.check_invariants()
    for (p, _), req in zip(REQUESTS, reqs):
        s, b = serial[p], req.result
        assert b.text == s.text and b.gen_tokens == s.gen_tokens
        np.testing.assert_array_equal(b.token_ids, s.token_ids)


def test_paged_mixed_budgets_and_refill(stack):
    """Mid-flight slot refill over the paged pool: different budgets free
    rows at different steps; outputs stay identical to serial."""
    ser, pag = _engines(stack, max_new=8, max_batch=2)
    prompts = [p for p, _ in REQUESTS] + ["one more cold prompt"]
    budgets = [8, 3, 5, 2, 8]
    serial = [ser.generate(p, max_new_tokens=n)
              for p, n in zip(prompts, budgets)]

    sched = ContinuousBatchingScheduler(pag)
    reqs = [sched.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, budgets)]
    sched.run()
    pag.check_invariants()
    assert sched.stats["slot_reuses"] >= 1
    for s, req in zip(serial, reqs):
        assert req.result.text == s.text
        np.testing.assert_array_equal(req.result.token_ids, s.token_ids)


# ---------------------------------------------------------------------------
# sharing: one physical prefix, many tables
# ---------------------------------------------------------------------------
def test_shared_prefix_blocks_refcount_gt_one(stack):
    """Two in-flight requests extending the same cached prompt must NAME
    the same pool blocks (refcount > 1), not hold private copies."""
    cfg, params = stack
    pag = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=8, block_size=8, enable_partial=True)
    pag.precache(CACHED)
    sched = ContinuousBatchingScheduler(pag)
    sched.submit(CACHED[0] + " tonight")
    sched.submit(CACHED[0] + " tomorrow")
    sched.step()                      # both admitted, both still in flight
    pag.check_invariants()

    rows = [set(b for b in pag._tables[i] if b != 0) for i in range(2)]
    both = rows[0] & rows[1]
    assert both, "no pool block is shared between the two tables"
    assert all(pag.allocator.refcount(b) >= 2 for b in both)
    sched.run()
    pag.check_invariants()


def test_paged_uses_fewer_device_bytes_than_dense(stack):
    """At batch 8 the dense pool pays max_batch * capacity slots up
    front; the paged pool's referenced bytes track actual lengths and
    shared prefixes are counted once."""
    cfg, params = stack
    dense = BatchedEngine(cfg, params, max_batch=8, capacity=128,
                          max_new_tokens=6, block_size=8,
                          enable_partial=True)
    dense.precache(CACHED)
    pag = PagedEngine(cfg, params, max_batch=8, capacity=128,
                      max_new_tokens=6, block_size=8, enable_partial=True)
    pag.precache(CACHED)

    sched = ContinuousBatchingScheduler(pag)
    for i in range(8):                # all extend the same cached prompt
        sched.submit(CACHED[0] + f" variant {i}")
    sched.step()
    pag.check_invariants()
    used = pag.device_kv_bytes_in_use()
    dense_bytes = cache_bytes(dense.pool)
    assert used < dense_bytes, (used, dense_bytes)
    sched.run()


def test_warm_admission_no_host_copy(stack):
    """Once a prefix is device-resident, re-admitting it must perform no
    host→device cache copy at all (the L1 zero-copy contract)."""
    _, pag = _engines(stack)
    sched = ContinuousBatchingScheduler(pag)
    sched.submit(CACHED[0] + " first pass")
    sched.run()
    h2d = pag.stats["h2d_copies"]
    assert pag.stats["host_promotions"] >= 1

    sched2 = ContinuousBatchingScheduler(pag)
    r = sched2.submit(CACHED[0] + " second pass")
    sched2.run()
    assert r.result.cache_hit and r.result.mode == "resident_block"
    assert np.isnan(r.result.prompt_similarity)   # no retrieval backs L1
    assert pag.stats["h2d_copies"] == h2d         # zero new host traffic
    pag.check_invariants()


def test_cow_never_mutates_shared_blocks(stack):
    """A divergent admission sharing a prefix must copy the boundary
    block, never write the donor's: the donor's pool content is bitwise
    unchanged after the sharer runs."""
    cfg, params = stack
    pag = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=4, block_size=8, enable_partial=True)
    sched = ContinuousBatchingScheduler(pag)
    sched.submit("the quick brown fox jumps over the lazy dog today")
    sched.run()
    donor_blocks = sorted(pag.trie.blocks())
    before = {
        seg: np.asarray(c["k"][:, donor_blocks])
        for seg, c in pag.pool.items()
    }

    sched2 = ContinuousBatchingScheduler(pag)
    # extends the donor EXACTLY, so the chain includes its partial tail
    # block -> the sharer must CoW it before writing its own suffix
    r = sched2.submit("the quick brown fox jumps over the lazy dog today"
                      " tonight")
    sched2.run()
    assert r.result.cache_hit and r.result.mode == "resident_block"
    assert r.result.reuse_depth % pag.block != 0   # genuinely mid-block
    assert pag.stats["cow_copies"] >= 1
    for seg, c in pag.pool.items():
        np.testing.assert_array_equal(
            np.asarray(c["k"][:, donor_blocks]), before[seg])
    pag.check_invariants()


# ---------------------------------------------------------------------------
# lifecycle and pressure
# ---------------------------------------------------------------------------
def test_pool_exhaustion_rejects_cleanly(stack):
    """A request the allocator cannot promise blocks for is rejected with
    an error (scheduler records it); the rest of the queue proceeds."""
    cfg, params = stack
    pag = PagedEngine(cfg, params, max_batch=2, capacity=64,
                      max_new_tokens=4, block_size=8,
                      num_blocks=6)          # sentinel + 5 usable
    sched = ContinuousBatchingScheduler(pag)
    ok = sched.submit("ab")                  # tiny: 1 block now + later
    bad = sched.submit("a prompt long enough to need many more blocks "
                       "than five")
    sched.run()
    assert ok.result is not None and ok.result.gen_tokens > 0
    assert bad.result is None and "exhausted" in bad.error
    assert sched.stats["rejected"] == 1
    pag.check_invariants()
    assert pag.free_slots() == [0, 1]


def test_trie_eviction_under_pressure(stack):
    """When the free list runs dry, cold L1 prefixes are evicted (LRU)
    instead of failing the admission; tokens stay correct."""
    cfg, params = stack
    ser = Engine(cfg, params, max_new_tokens=4, block_size=8)
    pag = PagedEngine(cfg, params, max_batch=1, capacity=64,
                      max_new_tokens=4, block_size=8, num_blocks=12)
    sched = ContinuousBatchingScheduler(pag)
    prompts = [f"pressure prompt number {i} with padding words" for i in
               range(4)]
    serial = [ser.generate(p) for p in prompts]
    reqs = [sched.submit(p) for p in prompts]
    sched.run()
    assert pag.stats["trie_evictions"] >= 1
    pag.check_invariants()
    for s, r in zip(serial, reqs):
        assert r.result.text == s.text


def test_instant_finish_keeps_prefix_warm(stack):
    """max_new_tokens=1 never occupies a row, but its prompt blocks stay
    indexed in L1 and serve the next admission residentially."""
    _, pag = _engines(stack)
    sched = ContinuousBatchingScheduler(pag)
    req = sched.submit("warm me up please", max_new_tokens=1)
    finished = sched.step()
    assert req in finished and req.result.gen_tokens == 1
    pag.check_invariants()
    assert len(pag.trie) > 0

    r2 = sched.submit("warm me up please and continue")
    sched.run()
    assert r2.result.mode == "resident_block"
    pag.check_invariants()


def test_paged_admit_feeds_host_store(stack):
    """admit=True harvests the row from pool blocks back into the host
    (L2) store at prompt depth, like the dense engines."""
    cfg, params = stack
    pag = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(pag)
    p = "tell me about rivers"
    sched.submit(p, admit=True)
    sched.run()
    assert len(pag.recycler.store) == 1
    follow = pag.recycler.lookup(p + " and lakes too",
                                 pag.tok.encode(p + " and lakes too"))
    assert follow.hit and follow.reuse_depth >= len(pag.tok.encode(p)) - 1


def test_paged_rejects_window(stack):
    cfg, params = stack
    with pytest.raises(NotImplementedError):
        PagedEngine(cfg, params, window=32)


def test_paged_pool_rejects_stateful_arch():
    from repro.models import init_paged_pool
    cfg = get_config("rwkv6-3b").reduced()
    with pytest.raises(NotImplementedError):
        init_paged_pool(cfg, 8, 8, 2, 4)


# ---------------------------------------------------------------------------
# int8 paged pool (kv_quant=True): greedy-identical to the fp pool,
# ~2-4x fewer device bytes, int8-verbatim host promotions
# ---------------------------------------------------------------------------
def _paged(stack, *, quant, max_new=6, max_batch=3, capacity=128):
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=max_batch, capacity=capacity,
                      max_new_tokens=max_new, block_size=8,
                      enable_partial=True, kv_quant=quant)
    eng.precache(CACHED)
    return eng


def _run_workload(eng, reqs=REQUESTS, **submit_kw):
    sched = ContinuousBatchingScheduler(eng)
    out = [sched.submit(p, **submit_kw) for p, _ in reqs]
    sched.run()
    eng.check_invariants()
    return out


def test_int8_paged_equals_fp_paged_all_modes(stack):
    """Acceptance: the int8 pool is greedy-token-identical to the fp pool
    on exact/partial/miss admissions of the reduced DialoGPT workload."""
    fp = _paged(stack, quant=False)
    q8 = _paged(stack, quant=True)
    fp_reqs = _run_workload(fp)
    q8_reqs = _run_workload(q8)
    for (p, want), rf, rq in zip(REQUESTS, fp_reqs, q8_reqs):
        assert rq.result.mode == rf.result.mode, p
        assert rq.result.text == rf.result.text, (p, rq.result.mode)
        np.testing.assert_array_equal(rq.result.token_ids,
                                      rf.result.token_ids)
    assert q8.stats["hits"] == fp.stats["hits"] == 3
    # host promotions moved full int8 blocks verbatim
    assert q8.stats["q8_block_promotions"] > 0


def test_int8_paged_early_eos_equivalence(stack, monkeypatch):
    import repro.serving.engine as engine_mod

    def eos_greedy(logits):
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(g % 5 == 1, jnp.int32(EOS), g)

    monkeypatch.setattr(engine_mod, "greedy", eos_greedy)
    fp = _paged(stack, quant=False, max_new=8)
    q8 = _paged(stack, quant=True, max_new=8)
    fp_reqs = _run_workload(fp)
    q8_reqs = _run_workload(q8)
    assert any(r.result.gen_tokens < 8 and r.result.token_ids[-1] == EOS
               for r in fp_reqs), "remap produced no early EOS"
    for rf, rq in zip(fp_reqs, q8_reqs):
        assert rq.result.text == rf.result.text
        assert rq.result.gen_tokens == rf.result.gen_tokens


def test_int8_pool_bytes_reduction(stack):
    """Acceptance: >= 1.8x reduction in device_kv_bytes_in_use vs the fp
    pool for the same workload at the same occupancy."""
    fp = _paged(stack, quant=False, max_batch=4)
    q8 = _paged(stack, quant=True, max_batch=4)
    _run_workload(fp)
    _run_workload(q8)
    # identical greedy trajectories allocate identical block counts
    assert q8.allocator.num_live() == fp.allocator.num_live()
    ratio = fp.device_kv_bytes_in_use() / q8.device_kv_bytes_in_use()
    assert ratio >= 1.8, ratio


def test_int8_pool_layout(stack):
    cfg, _ = stack
    q8 = _paged(stack, quant=True)
    seg = q8.pool["seg0"]
    assert seg["k"].dtype == jnp.int8 and seg["v"].dtype == jnp.int8
    assert seg["k_scale"].dtype == jnp.float32
    bs = q8.block
    assert seg["k_tail"].shape[2] == q8.fp_tail_blocks * bs
    assert seg["k_tail"].dtype == jnp.dtype(cfg.dtype)
    # the int8 host tier is on by default with a residual covering the
    # device fp ring tail
    assert q8.recycler.compress
    assert q8.recycler.compress_residual == (q8.fp_tail_blocks + 1) * bs


def test_int8_harvest_preserves_pool_bits(stack):
    """admit=True on the int8 pool stores the pool's int8 codes verbatim
    (plus an fp residual tail) — harvesting is not a requantization."""
    from repro.core.quant import _QKEY, is_quantized
    q8 = _paged(stack, quant=True)
    sched = ContinuousBatchingScheduler(q8)
    p = "tell me about rivers and their deltas in detail"
    sched.submit(p, admit=True)
    sched.run()
    assert len(q8.recycler.store) == len(CACHED) + 1
    e = q8.recycler.store.get(q8.recycler.store.ids()[-1], touch=False)
    assert is_quantized(e.cache)
    m = len(q8.tok.encode(p))
    split = max(0, m - q8.recycler.compress_residual)
    leaf = e.cache["seg0"]["k"]
    assert leaf[_QKEY].shape[2] == split
    assert leaf[_QKEY].dtype == np.int8
    # the stored codes equal the pool bits for the entry's blocks
    chain = [b for b, _ in q8.trie.lookup(q8.tok.encode(p))[1]]
    pool_k = np.asarray(q8.pool["seg0"]["k"])[:, chain]
    pool_k = pool_k.reshape(pool_k.shape[0], -1, *pool_k.shape[3:])
    np.testing.assert_array_equal(leaf[_QKEY][:, 0],
                                  pool_k[:, :split])


def test_int8_promotes_legacy_quantized_entries_via_fallback(stack):
    """A host entry in the PRE-residual quantized format (only
    __q8__/scale/dtype leaves, e.g. reloaded from an old save_dir) must
    still promote — through the dequant+scatter fallback, not the
    verbatim int8 upload (which needs the ax/cap/tail metadata)."""
    from repro.core import quant
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=5, block_size=8, kv_quant=True)
    eng.precache(CACHED[:1])
    e = eng.recycler.store.get(eng.recycler.store.ids()[0], touch=False)

    def legacy(t):
        if isinstance(t, dict):
            if quant._QKEY in t:
                full = quant.dequantize_tree({"x": t})["x"]
                amax = np.max(np.abs(full.astype(np.float32)), axis=-1,
                              keepdims=True)
                s = (amax / 127.0 + 1e-12).astype(np.float32)
                q = np.clip(np.round(full.astype(np.float32) / s),
                            -127, 127).astype(np.int8)
                return {quant._QKEY: q, "scale": s,
                        "dtype": np.dtype(full.dtype).str}
            return {k: legacy(v) for k, v in t.items()}
        return t

    e.cache = legacy(e.cache)
    # the deliberate format rewrite must re-stamp the integrity digest,
    # or the serve-time corruption check (correctly) drops the entry
    from repro.core.kvstore import cache_digest
    e.digest = cache_digest(e.cache)
    sched = ContinuousBatchingScheduler(eng)
    r = sched.submit(CACHED[0] + " and tomorrow")
    sched.run()
    eng.check_invariants()
    assert r.result.mode == "exact_prefix"
    assert eng.stats["q8_block_promotions"] == 0     # fallback path


def test_paged_converts_dense_quant_host_entries(stack):
    """A host entry in the dense kv_quant layout (native k_scale leaves)
    can't be consumed by the paged admission layouts directly — instead
    of the old honest-miss skip, it is CONVERTED (dequant -> staging
    layout, value-preserving to within half a quant step) on promotion
    and serves the hit; the conversion counter records the event for the
    bench."""
    cfg, params = stack
    from repro.serving import BatchedEngine
    donor = BatchedEngine(cfg, params, max_batch=2, capacity=128,
                          max_new_tokens=4, block_size=8, kv_quant=True)
    donor.precache(CACHED[:1])
    for pm in ("chunked", "staged"):
        pag = PagedEngine(cfg, params, max_batch=2, capacity=128,
                          max_new_tokens=4, block_size=8,
                          recycler=donor.recycler, prefill_mode=pm)
        sched = ContinuousBatchingScheduler(pag)
        r = sched.submit(CACHED[0] + " and tomorrow")
        sched.run()
        assert r.result.mode == "exact_prefix", pm
        assert r.result.reuse_depth > 0
        assert pag.stats["layout_conversions"] == 1, pm
        assert pag.stats["host_promotions"] == 1
        assert r.result.gen_tokens > 0
        pag.check_invariants()


def test_paged_quant_kernel_matches_reference():
    """Fused-dequant kernel == jnp reference gather (int8 pool + fp ring
    tail overlay) across rows at different depths."""
    from repro.kernels import ops
    from repro.models.attention import attend_paged
    rng = np.random.default_rng(5)
    B, NB, bs, H, hkv, dh, R = 3, 12, 8, 4, 2, 16, 2
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.integers(-127, 128, size=(NB, bs, hkv, dh)),
                     jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, size=(NB, bs, hkv, dh)),
                     jnp.int8)
    ks = jnp.asarray(rng.uniform(0.001, 0.02, size=(NB, bs, hkv)),
                     jnp.float32)
    vs = jnp.asarray(rng.uniform(0.001, 0.02, size=(NB, bs, hkv)),
                     jnp.float32)
    kt = jnp.asarray(rng.normal(size=(B, R * bs, hkv, dh)), jnp.float32)
    vt = jnp.asarray(rng.normal(size=(B, R * bs, hkv, dh)), jnp.float32)
    tables = jnp.asarray([[3, 5, 7, 0], [1, 2, 0, 0], [9, 8, 6, 4]],
                         jnp.int32)
    pos = jnp.asarray([25, 12, 31], jnp.int32)
    cache = {"k": kp, "v": vp, "k_scale": ks, "v_scale": vs,
             "k_tail": kt, "v_tail": vt, "block_tables": tables}
    out = ops.paged_decode_attention_quant(q, kp, vp, ks, vs, kt, vt,
                                           tables, pos, interpret=True)
    ref = attend_paged(q, cache, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_quant_pallas_engine_equivalence(stack):
    """The Pallas int8 decode path produces the same greedy tokens as the
    jnp reference path on a real engine workload."""
    from repro.runtime import Runtime
    cfg, params = stack
    outs = []
    for rt in (Runtime(), Runtime(use_pallas=True)):
        eng = PagedEngine(cfg, params, max_batch=2, capacity=128,
                          max_new_tokens=5, block_size=8,
                          enable_partial=True, kv_quant=True, rt=rt)
        eng.precache(CACHED[:1])
        reqs = _run_workload(eng, REQUESTS[:2])
        outs.append([r.result.text for r in reqs])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# kernel: block-table gather matches the reference gather
# ---------------------------------------------------------------------------
def test_paged_kernel_matches_reference():
    from repro.kernels import ops
    from repro.models.attention import attend_paged
    rng = np.random.default_rng(3)
    B, NB, bs, H, hkv, dh = 3, 12, 8, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    tables = jnp.asarray([[3, 5, 7, 0], [1, 2, 0, 0], [9, 8, 6, 4]],
                         jnp.int32)
    pos = jnp.asarray([25, 12, 31], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, tables, pos, interpret=True)
    ref = attend_paged(q, {"k": kp, "v": vp, "block_tables": tables}, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_gather_equals_row_alone():
    """Each row's paged attention equals the same row attended over a
    dense buffer built from its blocks (no cross-table leakage)."""
    from repro.models.attention import attend_direct, attend_paged
    rng = np.random.default_rng(4)
    B, NB, bs, H, hkv, dh = 3, 10, 4, 2, 1, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    tables = jnp.asarray([[1, 2, 3], [4, 5, 0], [6, 0, 0]], jnp.int32)
    pos = jnp.asarray([11, 6, 2], jnp.int32)
    out = attend_paged(q, {"k": kp, "v": vp, "block_tables": tables}, pos)
    for b in range(B):
        k = kp[tables[b]].reshape(1, -1, hkv, dh)
        v = vp[tables[b]].reshape(1, -1, hkv, dh)
        kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        solo = attend_direct(q[b:b + 1], k, v, pos[b:b + 1, None], kv_pos,
                             causal=True)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(solo[0]),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# allocator invariants (property-style; skipped without hypothesis)
# ---------------------------------------------------------------------------
def test_allocator_basics():
    a = BlockAllocator(6, 8)
    b1, b2 = a.alloc(), a.alloc()
    assert b1 != b2 and 0 not in (b1, b2)
    a.ref(b1)
    assert a.refcount(b1) == 2
    a.unref(b1)
    a.unref(b1)
    assert a.refcount(b1) == 0 and b1 in a.free_blocks()
    a.check()
    with pytest.raises(ValueError):
        a.unref(b1)                          # double free
    with pytest.raises(ValueError):
        a.ref(0)                             # sentinel is pinned
    for _ in range(4):
        a.alloc()
    with pytest.raises(BlockPoolExhausted):
        a.alloc()


def test_trie_register_lookup_evict():
    trie = BlockTrie(4)
    a = BlockAllocator(10, 4)
    ids = list(range(10))
    blocks = [a.alloc(), a.alloc(), a.alloc()]
    for b in trie.register(ids, 10, blocks):
        a.ref(b)
    depth, chain = trie.lookup(ids)
    assert depth == 10 and [b for b, _ in chain] == blocks
    assert chain[-1][1] == 2                 # partial tail fill
    d2, c2 = trie.lookup(ids[:6] + [99, 98])
    assert d2 == 4 and [b for b, _ in c2] == blocks[:1]
    # evict: only refcount-1 blocks, leaves first
    for b in blocks:
        a.unref(b)                           # drop the "table" refs
    dropped = trie.evict(10, lambda b: a.refcount(b) == 1)
    assert set(dropped) == set(blocks)
    assert trie.lookup(ids)[0] == 0


if HAVE_HYPOTHESIS:
    class TestAllocatorProperty:
        @given(ops=st.lists(st.tuples(st.integers(0, 2),
                                      st.integers(0, 30)),
                            min_size=1, max_size=200),
               nb=st.integers(2, 12))
        @settings(max_examples=60, deadline=None)
        def test_random_op_sequences_hold_invariants(self, ops, nb):
            """For ANY interleaving of alloc/ref/unref: refcounts >= 0,
            free ∪ live partitions the pool, and a block is never handed
            out twice without an intervening free."""
            a = BlockAllocator(nb, 8)
            held = []                        # (block, holders) we own
            for op, pick in ops:
                if op == 0:                  # alloc
                    try:
                        held.append([a.alloc(), 1])
                    except BlockPoolExhausted:
                        assert a.num_free() == 0
                elif op == 1 and held:       # ref
                    h = held[pick % len(held)]
                    a.ref(h[0])
                    h[1] += 1
                elif op == 2 and held:       # unref
                    i = pick % len(held)
                    h = held[i]
                    left = a.unref(h[0])
                    h[1] -= 1
                    assert left == h[1]
                    if h[1] == 0:
                        del held[i]
                a.check()
                for b, n in held:
                    assert a.refcount(b) == n
            live_expected = {b for b, _ in held}
            assert a.live_blocks() == live_expected

    class TestPagedEngineInvariantFuzz:
        @given(seed=st.integers(0, 2**16))
        @settings(max_examples=5, deadline=None)
        def test_scheduler_run_holds_invariants(self, seed):
            """Random mixed workloads through the scheduler: after every
            step, refcounts equal table+trie holders exactly and the free
            list never aliases a live block."""
            cfg = get_config("dialogpt-medium").reduced()
            params = init_params(cfg, jax.random.PRNGKey(0))
            rng = np.random.default_rng(seed)
            pag = PagedEngine(cfg, params, max_batch=2, capacity=64,
                              max_new_tokens=4, block_size=8,
                              enable_partial=True, num_blocks=20)
            sched = ContinuousBatchingScheduler(pag)
            words = ["alpha", "beta", "gamma", "delta"]
            for i in range(6):
                n = rng.integers(2, 6)
                sched.submit(" ".join(rng.choice(words) for _ in range(n)),
                             max_new_tokens=int(rng.integers(1, 5)),
                             admit=bool(rng.integers(0, 2)))
            while sched.pending() or sched.in_flight:
                sched.step()
                pag.check_invariants()
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocator_property():
        pass
