"""Deterministic hashed n-gram sentence embedder.

The paper indexes cached prompts with a pretrained sentence-transformer.
Offline we cannot ship MiniLM weights, so the retrieval substrate is a
feature-hashing embedder: character n-grams and word unigrams/bigrams hashed
into a d-dim space with signed buckets, L2-normalized.  Properties we rely
on (validated in tests):

  * identical texts -> identical embeddings (cos = 1)
  * a prompt and its extended-prefix variant (the paper's test design,
    §4.3) -> high cosine similarity
  * unrelated prompts -> low similarity

The recycler's *correctness* never depends on the embedder — retrieval only
nominates a candidate; the exact token-prefix test gates reuse (§3.1).
"""
from __future__ import annotations

import hashlib
import re

import numpy as np

_WORD_RE = re.compile(r"[a-z0-9']+")


def _kind_seed(kind: str) -> int:
    """Stable per-feature-kind hash salt.  MUST NOT use builtin ``hash``:
    str hashing is salted per process (PYTHONHASHSEED), which silently made
    embeddings process-dependent — similarities against a reloaded store
    were garbage.  blake2b is the same digest everywhere, forever."""
    return int.from_bytes(
        hashlib.blake2b(kind.encode(), digest_size=2).digest(), "little")


_KIND_SEEDS = {k: _kind_seed(k) for k in ("w", "b", "c", "r")}


def _bucket(token: str, seed: int, dim: int) -> tuple[int, float]:
    h = hashlib.blake2b(f"{seed}:{token}".encode(), digest_size=8).digest()
    v = int.from_bytes(h, "little")
    return (v >> 1) % dim, 1.0 if (v & 1) else -1.0


class HashEmbedder:
    """L2-normalized signed-hash bag of {char 3-grams, words, word bigrams}."""

    def __init__(self, dim: int = 384, char_n: int = 3):
        self.dim = dim
        self.char_n = char_n

    def _features(self, text: str):
        t = text.lower().strip()
        words = _WORD_RE.findall(t)
        feats = []
        feats.extend(("w", w) for w in words)
        feats.extend(("b", f"{a} {b}") for a, b in zip(words, words[1:]))
        compact = " ".join(words)
        n = self.char_n
        feats.extend(("c", compact[i:i + n])
                     for i in range(max(len(compact) - n + 1, 0)))
        if not feats:
            # non-word text (e.g. random-init model babble): raw char
            # n-grams keep identical texts at cos=1 instead of a 0 vector
            feats.extend(("r", t[i:i + n])
                         for i in range(max(len(t) - n + 1, 0)))
            feats.append(("r", t[:n] or t))
        return feats

    def encode(self, text: str) -> np.ndarray:
        v = np.zeros((self.dim,), np.float32)
        for kind, tok in self._features(text):
            idx, sign = _bucket(tok,
                                _KIND_SEEDS.get(kind) or _kind_seed(kind),
                                self.dim)
            v[idx] += sign
        norm = float(np.linalg.norm(v))
        return v / norm if norm > 0 else v

    def encode_batch(self, texts) -> np.ndarray:
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        return np.stack([self.encode(t) for t in texts])
