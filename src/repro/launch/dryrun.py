import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, record memory/cost/collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--recycled]

Each run writes experiments/dryrun/<arch>__<shape>__<mesh>[__rec].json with:
  bytes-per-device (memory_analysis), FLOPs/bytes (cost_analysis),
  collective schedule + bytes (parsed HLO), derived roofline terms, and the
  fallback-to-replication log from the sharding rules.

``--recycled`` lowers the *recycled prefill* variant: suffix = half the
tokens against a cache already holding the other half — the paper's
technique as a compiled artifact (suffix prefill FLOPs ~ half of full).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs import ASSIGNED_ARCHS, get_config, get_shape
from repro.launch.mesh import make_production_mesh, HBM_BYTES
from repro.launch.specs import input_specs, decode_window, text_len
from repro.models import decode_step, prefill, train_loss, init_params
from repro.models.cache import cache_struct
from repro.roofline import (RooflineTerms, model_flops, max_scan_trip,
                            parse_collective_bytes)
from repro.sharding import (batch_shardings, cache_shardings,
                            clear_fallback_log, fallback_log,
                            param_shardings, runtime_for)
from repro.training.optimizer import AdamWState, adamw_update, cosine_lr

OUT_DIR = "experiments/dryrun"


def _params_struct(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def _opt_struct(params_struct):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                      jax.tree.map(f32, params_struct),
                      jax.tree.map(f32, params_struct))


def build_step(cfg: ModelConfig, shape: InputShape, mesh, rt,
               *, recycled: bool = False, suffix_frac: float = 0.5,
               kv_quant: bool = False):
    """Returns (fn, arg_structs, in_shardings, donate)."""
    specs = input_specs(cfg, shape, kv_quant=kv_quant)
    pstruct = _params_struct(cfg)
    dp_all = tuple(a for a in mesh.axis_names if a != "model")
    pshard = param_shardings(pstruct, mesh, expert_fsdp_axes=dp_all)
    window = decode_window(cfg, shape)

    if shape.kind == "train":
        ostruct = _opt_struct(pstruct)
        dp = rt.batch_axes
        oshard = AdamWState(
            NamedSharding(mesh, P()),
            param_shardings(pstruct, mesh, zero1_axes=dp,
                            expert_fsdp_axes=dp_all),
            param_shardings(pstruct, mesh, zero1_axes=dp,
                            expert_fsdp_axes=dp_all))
        schedule = cosine_lr(3e-4, 100, 10_000)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: train_loss(cfg, p, batch, rt), has_aux=True)(params)
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 schedule)
            return params, opt_state, {**metrics, **om}

        bshard = batch_shardings(cfg, shape, mesh, rt)
        args = (pstruct, ostruct, specs["batch"])
        shards = (pshard, oshard, bshard)
        out_shards = (pshard, oshard, None)
        return train_step, args, shards, out_shards, (0, 1)

    cstruct = specs["cache"]
    cshard = cache_shardings(cstruct, cfg, mesh, rt.batch_axes,
                             shape.global_batch)
    if shape.kind == "prefill":
        if recycled:
            # the paper's technique: a (1-suffix_frac) prefix is recycled
            tl = text_len(cfg, shape)
            suf = max(int(tl * suffix_frac) // 256 * 256, 256)
            tok_struct = jax.ShapeDtypeStruct((shape.global_batch, suf),
                                              jnp.int32)
            start = tl - suf
        else:
            tok_struct = specs["tokens"]
            start = 0
        has_fe = "frontend" in specs

        if has_fe:
            def prefill_step(params, tokens, cache, frontend):
                return prefill(cfg, params, tokens, cache, start_pos=start,
                               frontend=frontend, window=window, rt=rt)
            bsh = batch_shardings(cfg, shape, mesh, rt)
            args = (pstruct, tok_struct, cstruct, specs["frontend"])
            shards = (pshard, bsh["tokens"], cshard, bsh["frontend"])
            return prefill_step, args, shards, (None, cshard), (2,)

        def prefill_step(params, tokens, cache):
            return prefill(cfg, params, tokens, cache, start_pos=start,
                           window=window, rt=rt)
        bsh = batch_shardings(cfg, shape, mesh, rt)
        args = (pstruct, tok_struct, cstruct)
        shards = (pshard, bsh["tokens"], cshard)
        return prefill_step, args, shards, (None, cshard), (2,)

    # decode
    def serve_step(params, token, cache, pos):
        return decode_step(cfg, params, token, cache, pos, window=window,
                           rt=rt)

    b = rt.batch_axes if rt.batch_axes else None
    tshard = NamedSharding(mesh, P(b, None))
    args = (pstruct, specs["token"], cstruct, specs["pos"])
    shards = (pshard, tshard, cshard, NamedSharding(mesh, P()))
    return serve_step, args, shards, (None, cshard), (2,)


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            recycled: bool = False, out_dir: str = OUT_DIR,
            save_hlo: bool = False, seq_parallel: bool = False,
            moe_fsharded: bool = False, suffix_frac: float = 0.5,
            kv_quant: bool = False, tag_suffix: str = "") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = (f"{arch}__{shape_name}__{mesh_name}"
           + ("__rec" if recycled else "") + tag_suffix)
    rt = runtime_for(cfg, shape, mesh, seq_parallel=seq_parallel,
                     moe_fsharded=moe_fsharded)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "recycled": recycled, "chips": mesh.size, "ok": False}
    clear_fallback_log()
    try:
        fn, args, in_sh, out_sh, donate = build_step(
            cfg, shape, mesh, rt, recycled=recycled, suffix_frac=suffix_frac,
            kv_quant=kv_quant)
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0 - rec["lower_s"], 1)

        # --- memory ------------------------------------------------------
        try:
            ma = compiled.memory_analysis()
            mem = {k: int(getattr(ma, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                   if hasattr(ma, k)}
        except Exception as e:                      # CPU backend limitations
            mem = {"error": str(e)}
        rec["memory"] = mem
        arg_b = mem.get("argument_size_in_bytes", 0)
        tmp_b = mem.get("temp_size_in_bytes", 0)
        rec["bytes_per_device"] = arg_b + tmp_b
        rec["fits_hbm"] = bool(arg_b + tmp_b <= HBM_BYTES)

        # --- cost --------------------------------------------------------
        try:
            ca = compiled.cost_analysis() or {}
        except Exception as e:
            ca = {"error": str(e)}
        flops = float(ca.get("flops", 0.0))
        bytes_acc = float(ca.get("bytes accessed", 0.0))
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float))}

        # --- collectives ---------------------------------------------------
        trip = max_scan_trip(cfg)
        hlo = compiled.as_text()
        colls = parse_collective_bytes(hlo, scan_trip=trip)
        rec["collectives"] = colls
        rec["scan_trip_multiplier"] = trip
        if save_hlo:
            import os as _os
            _os.makedirs(out_dir, exist_ok=True)
            with open(f"{out_dir}/{tag}.hlo.txt", "w") as f:
                f.write(hlo)

        # --- roofline -----------------------------------------------------
        mf = model_flops(cfg, shape)
        if recycled and shape.kind == "prefill":
            mf *= suffix_frac               # only suffix tokens recomputed
        terms = RooflineTerms(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=mesh.size,
            hlo_flops=flops, hlo_bytes=bytes_acc,
            collective_bytes=colls.get("total", 0.0) / mesh.size,
            model_flops=mf,
        ).finalize()
        rec["roofline"] = terms.as_dict()
        rec["ok"] = True
    except Exception:
        rec["error"] = traceback.format_exc()[-4000:]
    # which leaves the divisibility rules refused to shard (replication
    # fallbacks) for THIS build — populated by the sharding-rule calls
    # above, recorded even on failure so a surprise replication blowing
    # the memory budget is visible in the artifact
    rec["sharding_fallbacks"] = fallback_log()
    rec["total_s"] = round(time.time() - t0, 1)

    import os as _os
    _os.makedirs(out_dir, exist_ok=True)
    with open(f"{out_dir}/{tag}.json", "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK " if rec["ok"] else "FAIL"
    fb = (f"  [{len(rec['sharding_fallbacks'])} sharding fallback(s)]"
          if rec["sharding_fallbacks"] else "")
    print(f"[{status}] {tag}  ({rec['total_s']}s){fb}", flush=True)
    if not rec["ok"]:
        print(rec["error"].splitlines()[-1], flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--recycled", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--moe-fsharded", action="store_true")
    ap.add_argument("--suffix-frac", type=float, default=0.5)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--tag-suffix", default="")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    results = []
    for a, s in pairs:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        tag = f"{a}__{s}__{mesh_name}" + ("__rec" if args.recycled else "")
        tag += args.tag_suffix
        if args.skip_existing:
            import os as _os
            p = f"{args.out_dir}/{tag}.json"
            if _os.path.exists(p):
                with open(p) as f:
                    if json.load(f).get("ok"):
                        print(f"[skip] {tag}", flush=True)
                        continue
        results.append(run_one(a, s, multi_pod=args.multi_pod,
                               recycled=args.recycled, out_dir=args.out_dir,
                               save_hlo=args.save_hlo,
                               seq_parallel=args.seq_parallel,
                               moe_fsharded=args.moe_fsharded,
                               suffix_frac=args.suffix_frac,
                               kv_quant=args.kv_int8,
                               tag_suffix=args.tag_suffix))
    ok = sum(r["ok"] for r in results)
    print(f"done: {ok}/{len(results)} ok", flush=True)


if __name__ == "__main__":
    main()
