from repro.serving.engine import BatchedEngine, Engine, GenResult
from repro.serving.paged import PagedEngine
from repro.serving.sampling import greedy, sample_batched, sample_logits
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     FIFOScheduler)

__all__ = ["Engine", "BatchedEngine", "PagedEngine", "GenResult", "greedy",
           "sample_logits", "sample_batched", "Request", "FIFOScheduler",
           "ContinuousBatchingScheduler"]
