"""int8 host-cache compression (beyond-paper extension, cf. the paper's
CacheGen citation): roundtrip error bounds, byte savings, engine e2e."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.kvstore import (dequantize_tree, is_quantized, quantize_tree,
                                tree_bytes)
from repro.models import init_params
from repro.serving import Engine


def test_quantize_roundtrip_error():
    rng = np.random.default_rng(0)
    cache = {"seg0": {"k": rng.standard_normal((2, 1, 32, 4, 8)).astype(np.float32),
                      "v": rng.standard_normal((2, 1, 32, 4, 8)).astype(np.float32),
                      "slot_pos": np.arange(32, dtype=np.int32)}}
    q = quantize_tree(cache)
    assert is_quantized(q)
    back = dequantize_tree(q)
    # per-vector int8: <1% RMS relative error
    for key in ("k", "v"):
        a, b = cache["seg0"][key], back["seg0"][key]
        rel = np.sqrt(np.mean((a - b) ** 2)) / np.sqrt(np.mean(a ** 2))
        assert rel < 0.01, rel
    np.testing.assert_array_equal(back["seg0"]["slot_pos"],
                                  cache["seg0"]["slot_pos"])


def test_quantize_saves_bytes():
    rng = np.random.default_rng(1)
    cache = {"k": rng.standard_normal((4, 64, 8, 16)).astype(np.float32)}
    q = quantize_tree(cache)
    # f32 -> int8 + f32 per-16-elem scales: ~3.2x smaller
    assert tree_bytes(cache) / tree_bytes(q) > 2.8


def test_engine_with_compression_end_to_end():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new_tokens=6, block_size=16,
                 compress_host_cache=True)
    eng_ref = Engine(cfg, params, max_new_tokens=6, block_size=16)
    p0 = "what is the capital of france?"
    p1 = "what is the capital of france? and of italy?"
    eng.precache([p0])
    eng_ref.precache([p0])
    assert eng.recycler.store.total_bytes < eng_ref.recycler.store.total_bytes / 1.7

    base = eng.generate(p1, use_recycling=False)
    rec = eng.generate(p1)
    assert rec.cache_hit and rec.reuse_depth > 0
    # int8 cache reuse keeps greedy output identical on this scale
    assert rec.text == base.text


def test_compression_deep_prefix_argmax_stable():
    """Regression for the greedy-divergence bug: quantize a DEEP prefix
    (several radix blocks, so the quantized region is non-trivial even
    with the fp residual tail) and check the greedy argmax over the fresh
    suffix is identical to the uncompressed path."""
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    deep = ("the committee reviewed the annual budget line by line and "
            "flagged every discrepancy it could find in the report")
    eng = Engine(cfg, params, max_new_tokens=8, block_size=16,
                 compress_host_cache=True)
    eng.precache([deep])
    e = next(iter(eng.recycler.store._entries.values()))
    from repro.core.quant import _QKEY
    assert _QKEY in e.cache["seg0"]["k"]
    # the quantized (non-tail) region must span multiple blocks
    assert e.cache["seg0"]["k"][_QKEY].shape[2] > 2 * 16

    eng_ref = Engine(cfg, params, max_new_tokens=8, block_size=16)
    eng_ref.precache([deep])
    for suffix in (" and then voted", " before adjourning for the day"):
        base = eng_ref.generate(deep + suffix)
        rec = eng.generate(deep + suffix)
        assert rec.cache_hit and rec.reuse_depth > 0
        assert base.cache_hit
        assert rec.text == base.text, (suffix, rec.text, base.text)


def test_int8_device_kv_cache_equivalence():
    """§Perf-4: int8 on-device KV cache — greedy decode tokens match the
    bf16/f32 cache path (logits within quantization tolerance)."""
    import jax.numpy as jnp
    from repro.models import decode_step, init_cache, prefill
    cfg = get_config("qwen1.5-32b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    c_ref = init_cache(cfg, B, 64)
    c_q8 = init_cache(cfg, B, 64, kv_quant=True)
    l_ref, c_ref = prefill(cfg, params, tokens, c_ref)
    l_q8, c_q8 = prefill(cfg, params, tokens, c_q8)
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_q8),
                               rtol=0.1, atol=0.1)
    tok = jnp.argmax(l_ref, -1)[:, None]
    for i in range(4):
        d_ref, c_ref = decode_step(cfg, params, tok, c_ref, S + i)
        d_q8, c_q8 = decode_step(cfg, params, tok, c_q8, S + i)
        assert bool((jnp.argmax(d_ref, -1) == jnp.argmax(d_q8, -1)).all())
        tok = jnp.argmax(d_ref, -1)[:, None]


def test_engine_int8_device_cache_recycling():
    """int8 device cache + recycling compose: the host store holds int8
    buffers natively (half the bytes), hits still reuse correctly."""
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new_tokens=6, block_size=16, kv_quant=True)
    eng_ref = Engine(cfg, params, max_new_tokens=6, block_size=16)
    p0 = "how do airplanes fly?"
    eng.precache([p0])
    eng_ref.precache([p0])
    assert eng.recycler.store.total_bytes < eng_ref.recycler.store.total_bytes / 1.7
    p1 = p0 + " keep the answer short."
    base = eng.generate(p1, use_recycling=False)
    rec = eng.generate(p1)
    assert rec.cache_hit and rec.reuse_depth > 0
    assert rec.text == base.text
