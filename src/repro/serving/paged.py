"""Paged continuous-batching engine: block-table KV pool with ref-counted
prefix sharing (the device-resident recycling tier).

The dense slot pool (``BatchedEngine``) gives every in-flight request a
private ``[capacity]`` KV row and materializes every recycled hit from the
host store — two requests sharing a 500-token prefix hold two device
copies and both pay a host→device transfer.  This engine replaces the row
with a *block table*: all K/V lives in ONE shared pool of ``block_size``-
token blocks (``models.cache.init_paged_pool``), request r's cache is the
ordered list of pool block ids in its table, and a block may appear in any
number of tables at once.

Two cache tiers serve admissions:

  L1 — ``core.radix.BlockTrie``: token-block keys → live device blocks.
       A warm-prefix admission composes its table from the resident chain
       with **zero host round-trip**: shared full blocks are referenced in
       place (refcount++), and only the divergent boundary block is
       materialized fresh (copy-on-write through the prefill staging
       buffer — a shared block is never written in place).
  L2 — the existing ``Recycler``/``HostKVStore`` path: on an L1 miss the
       host entry is promoted back to device in block-granular chunks and
       indexed in L1 for the next admission.

Static shapes still rule: the pool is one fixed ``[num_blocks, bs, ...]``
allocation per layer, tables are fixed-width (sentinel-0 padded), and ONE
compiled decode executable (`decode_step` over the paged cache) advances
every in-flight request per step regardless of occupancy or sharing.

``kv_quant=True`` stores the L1 pool in **int8** (``repro.core.quant``
scheme, shared with the host tier): ~2-4x more resident blocks per HBM
byte, dequant fused into the block-table gather
(``kernels.paged_decode_attention_quant``), a per-row fp ring tail over
the most recent blocks, and int8-verbatim block movement between the
tiers — see the ``PagedEngine`` docstring for the one-quantization
invariant.

Correctness contract (tests/test_paged_pool.py): paged decode is
token-for-token identical to the dense slot pool — and therefore to serial
``generate`` — for every admission mode (and the int8 pool to the fp
pool); blocks shared between requests have refcount > 1 and are never
written by either sharer.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import BlockAllocator, BlockPoolExhausted, BlockTrie
from repro.core.blockpool import SENTINEL
from repro.core.kvstore import to_host, tree_bytes
from repro.core import quant as kvq
from repro.core.quant import dequantize_vectors_jnp, quantize_vectors_jnp
from repro.core.recycler import grow_capacity
from repro.data.tokenizer import EOS
from repro.models import (decode_step, init_cache, init_paged_pool,
                          paged_block_bytes)
from repro.serving import engine as engine_mod
from repro.serving.engine import Engine, GenResult, _Slot
from repro.serving.sampling import sample_batched, sample_logits


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# jitted pool <-> staging composition (device-to-device; no host traffic)
# ---------------------------------------------------------------------------
def _stage_from_pool(pool, chain_ids, depth: int, cap: int):
    """Compose a dense single-request staging cache holding positions
    [0, depth) gathered from pool blocks ``chain_ids`` — the layout the
    existing (compiled) prefill consumes.  Pure device gather.  Staging
    is always full precision: int8 pools dequantize in the gather (the
    prefill needs fp operands anyway; the int8 bytes in the pool are
    untouched)."""
    stage = {}
    for seg, c in pool.items():
        sub = {}
        for name in ("k", "v"):
            a = c[name][:, chain_ids]              # (L, ncb, bs, H, D)
            if name + "_scale" in c:
                a = dequantize_vectors_jnp(
                    a, c[name + "_scale"][:, chain_ids], c["k_tail"].dtype)
            L = a.shape[0]
            a = a.reshape(L, -1, *a.shape[3:])[:, :depth]
            a = jnp.pad(a, ((0, 0), (0, cap - depth), (0, 0), (0, 0)))
            sub[name] = a[:, None]                 # (L, 1, cap, H, D)
        pos = jnp.arange(cap, dtype=jnp.int32)
        sp = jnp.where(pos < depth, pos, -1)
        sub["slot_pos"] = jnp.broadcast_to(sp, (c["k"].shape[0], cap))
        stage[seg] = sub
    return stage


def _gather_quant(pool, chain_ids, depth: int, cap: int):
    """Harvest gather for int8 pools: like ``_stage_from_pool`` but the
    int8 codes and f32 scales are copied VERBATIM (no dequant) — the host
    entry built from this keeps the pool's exact bits, so a later
    promotion can put them back without a requant round-trip."""
    stage = {}
    for seg, c in pool.items():
        sub = {}
        for name in ("k", "v", "k_scale", "v_scale"):
            a = c[name][:, chain_ids]              # (L, ncb, bs, H[, D])
            L = a.shape[0]
            a = a.reshape(L, -1, *a.shape[3:])[:, :depth]
            pad = [(0, 0), (0, cap - depth)] + [(0, 0)] * (a.ndim - 2)
            sub[name] = jnp.pad(a, pad)[:, None]   # (L, 1, cap, H[, D])
        pos = jnp.arange(cap, dtype=jnp.int32)
        sp = jnp.where(pos < depth, pos, -1)
        sub["slot_pos"] = jnp.broadcast_to(sp, (c["k"].shape[0], cap))
        stage[seg] = sub
    return stage


def _scatter_to_pool(pool, stage, dst_ids, start: int, n: int, bs: int):
    """Write staging positions [start, start + n) into pool blocks
    ``dst_ids`` (dst_ids[i] holds positions [start + i*bs, ...)).  The
    copy-on-write boundary block is materialized here: staging already
    holds the donor prefix for [start, depth), so the divergent block's
    private copy costs no extra pass.  int8 pools quantize the scattered
    region here — for fresh tokens this is their first (and only)
    quantization."""
    ps = start + jnp.arange(n, dtype=jnp.int32)
    blk = dst_ids[(ps - start) // bs]
    off = ps % bs
    out = {}
    for seg, c in pool.items():
        upd = {}
        for name in ("k", "v"):
            vals = stage[seg][name][:, 0, start:start + n]
            if name + "_scale" in c:
                q, s = quantize_vectors_jnp(vals)
                upd[name] = c[name].at[:, blk, off].set(q)
                upd[name + "_scale"] = \
                    c[name + "_scale"].at[:, blk, off].set(s)
            else:
                upd[name] = c[name].at[:, blk, off].set(vals)
        out[seg] = {**c, **upd}
    return out


def _upload_q8(pool, ent, dst_ids, bs: int):
    """L2 -> L1 promotion of a quantized host entry's int8 region: copy
    the stored int8 codes + f32 scales straight into pool blocks
    ``dst_ids`` (positions [0, n), block-aligned).  No dequant/requant —
    the bits that were quantized once at the vectors' first write are the
    bits that land back in the pool."""
    out = {}
    for seg, c in pool.items():
        e = ent[seg]
        n = e["k"].shape[2]
        ps = jnp.arange(n, dtype=jnp.int32)
        blk = dst_ids[ps // bs]
        off = ps % bs
        upd = {name: c[name].at[:, blk, off].set(e[name][:, 0])
               for name in e}
        out[seg] = {**c, **upd}
    return out


def _fill_tail(pool, stage, row, m):
    """Populate pool row ``row``'s fp ring tail from a staging cache:
    ring slot r receives the fp values of block ``ti(r)`` — the unique
    block in the row's initial recency window (m//bs - R, m//bs] with
    ti % R == r — so the first decode step already attends its most
    recent R blocks at full precision.  Slots whose ti falls before the
    prompt (or holds no data yet) are zeroed; the kernel's recency gate
    never selects them.  int8 staging is dequantized here — tail fidelity
    is best-effort for admitted positions (exact for fp misses' freshly
    prefilled tokens, dequant for promoted/resident ones) and exact for
    every token decode later dual-writes."""
    out = {}
    for seg, c in pool.items():
        bs = c["k"].shape[2]                       # (L, NB, bs, H, D)
        R = c["k_tail"].shape[2] // bs             # (L, B, R*bs, H, D)
        cap = stage[seg]["k"].shape[2]             # (L, 1, cap, H[, D])
        open_b = m // bs
        r = jnp.arange(R, dtype=jnp.int32)
        ti = open_b - ((open_b - r) % R)           # block held by ring slot r
        j = jnp.arange(bs, dtype=jnp.int32)
        posm = ti[:, None] * bs + j[None]          # (R, bs) abs positions
        valid = ((posm >= 0) & (posm < cap)).reshape(-1)
        idx = jnp.clip(posm, 0, cap - 1).reshape(-1)
        upd = {}
        for name in ("k", "v"):
            vals = stage[seg][name][:, 0, idx]
            if name + "_scale" in stage[seg]:
                vals = dequantize_vectors_jnp(
                    vals, stage[seg][name + "_scale"][:, 0, idx],
                    c[name + "_tail"].dtype)
            vals = vals * valid[None, :, None, None]
            upd[name + "_tail"] = c[name + "_tail"].at[:, row].set(vals)
        out[seg] = {**c, **upd}
    return out


def _set_row(pool, tokens, pos, row, table_row, tok0, m):
    out = {}
    for seg, c in pool.items():
        out[seg] = {**c,
                    "block_tables": c["block_tables"].at[:, row].set(table_row)}
    return out, tokens.at[row].set(tok0), pos.at[row].set(m)


def _set_table_entry(pool, row, idx, blk):
    out = {}
    for seg, c in pool.items():
        out[seg] = {**c,
                    "block_tables": c["block_tables"].at[:, row, idx].set(blk)}
    return out


def _clear_row(pool, row):
    out = {}
    for seg, c in pool.items():
        out[seg] = {**c, "block_tables":
                    c["block_tables"].at[:, row].set(SENTINEL)}
    return out


class PagedEngine(Engine):
    """Continuous batching over a paged, prefix-shared device KV pool.

    Drop-in replacement for ``BatchedEngine`` behind the scheduler surface
    (``free_slots`` / ``admit_slot`` / ``decode_batch``); the dense slot
    pool stays as the equivalence reference.  Trunk attention only, no
    sliding window (paged blocks have no ring semantics).

    ``kv_quant=True`` switches the pool to the **int8 tier layout**
    (``core.quant`` scheme): pool K/V are int8 with per-vector f32 scales
    — ~2-4x more resident blocks per HBM byte, i.e. deeper batches and
    longer shareable prefixes on the same hardware.  Decode fuses the
    dequant into the block-table gather, and each row's most recent
    ``fp_tail_blocks`` blocks are attended from a full-precision ring
    tail (the device analogue of the host residual tail, which keeps
    greedy decoding token-identical to the fp pool).  The host (L2) tier
    holds quantized-tree entries with an fp residual tail; both tiers
    share one scheme, so:

      * a token's K/V is quantized ONCE — at the scatter/seal of its
        block (prefill scatter or decode dual-write);
      * harvest copies pool int8 verbatim into the host entry, and
        promotion copies the entry's full int8 blocks verbatim back into
        pool blocks — no dequant/requant round-trip (only the sub-block
        remainder of a partial boundary block re-quantizes, a
        value-preserving <= half-step event bounded to < block_size
        tokens per promotion);
      * the entry's fp residual tail feeds the pool's fp ring tail — the
        recent window is exact for entries admitted from an fp staging
        cache (precache, instant finish) and dequant-precision for
        pool-harvested ones, whose tails are rebuilt from the sealed int8
        codes (see ROADMAP "Int8 two-tier quantization", Known limits).
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 capacity: int = 256, num_blocks: Optional[int] = None,
                 fp_tail_blocks: int = 2, **kw):
        if kw.get("kv_quant"):
            # the int8 tier compresses its host tier by default, with a
            # residual deep enough that a promoted prefix can fill the
            # whole device fp ring tail with exact values
            kw.setdefault("compress_host_cache", True)
            kw.setdefault("compress_residual",
                          (fp_tail_blocks + 1) * kw.get("block_size", 64))
        super().__init__(cfg, params, **kw)
        if self.window:
            raise NotImplementedError("paged pool does not support "
                                      "sliding-window rings")
        bs = self.block                      # page size == radix block size
        if capacity % bs:
            capacity = _ceil_div(capacity, bs) * bs
        self.max_batch = max_batch
        self.capacity = capacity
        self.fp_tail_blocks = fp_tail_blocks
        self.nbt = capacity // bs            # fixed table width
        if num_blocks is None:
            # worst case every row full + one row's worth of retained
            # prefixes in the L1 trie + the sentinel
            num_blocks = max_batch * self.nbt + self.nbt + 1
        self.allocator = BlockAllocator(num_blocks, bs)
        self.trie = BlockTrie(bs)
        self.pool = init_paged_pool(cfg, num_blocks, bs, max_batch,
                                    self.nbt, dtype=jnp.dtype(cfg.dtype),
                                    quant=self.kv_quant,
                                    fp_tail_blocks=fp_tail_blocks)
        self._tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self._pos = jnp.zeros((max_batch,), jnp.int32)
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._tables = np.zeros((max_batch, self.nbt), np.int32)  # host mirror
        self._row_blocks: List[List[int]] = [[] for _ in range(max_batch)]
        self._committed: List[int] = [0] * max_batch  # future allocs owed
        self._temp = np.zeros((max_batch,), np.float32)
        self._topk = np.zeros((max_batch,), np.int32)
        self._step_rng = self._sample_key

        self._stage_fn = jax.jit(_stage_from_pool, static_argnums=(2, 3))
        self._gather_q_fn = jax.jit(_gather_quant, static_argnums=(2, 3))
        self._scatter_fn = jax.jit(_scatter_to_pool,
                                   static_argnums=(3, 4, 5),
                                   donate_argnums=(0,))
        self._upload_fn = jax.jit(_upload_q8, static_argnums=(3,),
                                  donate_argnums=(0,))
        self._tail_fn = jax.jit(_fill_tail, donate_argnums=(0,))
        self._setrow_fn = jax.jit(_set_row, donate_argnums=(0, 1, 2))
        self._setent_fn = jax.jit(_set_table_entry, donate_argnums=(0,))
        self._clear_fn = jax.jit(_clear_row, donate_argnums=(0,))
        self._pstep_fn = jax.jit(self._paged_step, donate_argnums=(1, 2, 3))
        self._pstep_sampled_fn = jax.jit(self._paged_step_sampled,
                                         donate_argnums=(1, 2, 3),
                                         static_argnums=(7,))
        self.stats.update({
            "batched_decode_steps": 0, "admissions": 0, "sampled_steps": 0,
            "resident_hits": 0, "host_promotions": 0, "cow_copies": 0,
            "h2d_copies": 0, "h2d_bytes": 0, "trie_evictions": 0,
            "layout_skips": 0, "q8_block_promotions": 0,
        })

    # ------------------------------------------------------------------
    def _make_cache(self, capacity: int):
        """Admission staging is always full precision — the int8 pool
        quantizes at the scatter boundary (once per token), never inside
        the prefill — so one staging layout serves both pool tiers."""
        return init_cache(self.cfg, 1, capacity, window=self.window,
                          dtype=jnp.dtype(self.cfg.dtype), kv_quant=False)

    def _host_layout_ok(self, cache) -> bool:
        """A host entry is promotable iff it materializes to the plain fp
        staging layout.  Entries admitted by the dense ``kv_quant``
        engines carry native int8 + k_scale leaves the staged prefill
        can't consume — honest miss instead of corrupting the pool."""
        return not any(isinstance(c, dict) and "k_scale" in c
                       for c in cache.values())

    def _q8_blocks(self, raw, depth: int) -> int:
        """How many FULL blocks of a quantized host entry's int8 region
        cover [0, depth) — the part of a promotion that moves verbatim."""
        if raw is None or not kvq.is_quantized(raw):
            return 0
        for c in raw.values():
            leaf = c.get("k") if isinstance(c, dict) else None
            if isinstance(leaf, dict) and kvq._QKEY in leaf:
                if "ax" not in leaf:
                    # legacy quantized entry (pre-residual format): no
                    # verbatim upload — fall back to dequant + scatter
                    return 0
                ax = int(np.asarray(leaf["ax"]))
                split = leaf[kvq._QKEY].shape[ax]
                return min(split, depth) // self.block
        return 0

    def _slice_q8(self, raw, n8: int):
        """Host-side view of a quantized entry's first ``n8`` positions in
        the pool's upload layout: int8 codes + f32 scales (keepdim
        dropped), per segment.  Pure slicing — no arithmetic touches the
        stored bits."""
        ent = {}
        for seg, c in raw.items():
            sub = {}
            for name in ("k", "v"):
                leaf = c[name]
                ax = int(np.asarray(leaf["ax"]))
                sl = [slice(None)] * leaf[kvq._QKEY].ndim
                sl[ax] = slice(0, n8)
                sub[name] = jnp.asarray(leaf[kvq._QKEY][tuple(sl)])
                sub[name + "_scale"] = jnp.asarray(
                    np.asarray(leaf["scale"])[tuple(sl)][..., 0])
            ent[seg] = sub
        return ent

    def _harvest(self, chain_ids, depth: int, cap: int):
        """Gather pool blocks [0, depth) into a host-store entry.  fp
        pools return the dense staging layout; int8 pools return the
        quantized-tree host format built from the pool's VERBATIM int8
        codes (plus a dequantized fp residual tail), so the quantization
        the blocks received at their seal is the only one they ever get."""
        if not self.kv_quant:
            return to_host(self._stage_fn(self.pool, chain_ids, depth, cap))
        g = to_host(self._gather_q_fn(self.pool, chain_ids, depth, cap))
        residual = self.recycler.compress_residual
        split = max(0, depth - residual)
        dt = jnp.dtype(self.cfg.dtype)
        entry = {}
        for seg, c in g.items():
            sub = {"slot_pos": c["slot_pos"]}
            for name in ("k", "v"):
                q = c[name]                        # (L, 1, cap, H, D) int8
                s = c[name + "_scale"]             # (L, 1, cap, H) f32
                tail = (q[:, :, split:depth].astype(np.float32)
                        * s[:, :, split:depth, :, None]).astype(dt)
                sub[name] = {
                    kvq._QKEY: q[:, :, :split],
                    "scale": s[:, :, :split, :, None],
                    "dtype": np.dtype(dt).str,
                    "tail": tail,
                    "cap": np.int64(cap),
                    "ax": np.int64(2),
                }
            entry[seg] = sub
        return entry

    # ------------------------------------------------------------------
    def _paged_step(self, params, tokens, pool, pos):
        # greedy via the engine module so tests can substitute it (early
        # EOS) in the serial, dense-pool and paged paths at once
        logits, pool = decode_step(self.cfg, params, tokens, pool, pos,
                                   window=0, rt=self.rt)
        nxt = engine_mod.greedy(logits)
        return nxt, nxt[:, None], pool, pos + 1

    def _paged_step_sampled(self, params, tokens, pool, pos, temp, topk,
                            rng, topk_cap):
        logits, pool = decode_step(self.cfg, params, tokens, pool, pos,
                                   window=0, rt=self.rt)
        nxt = sample_batched(logits, rng, temperature=temp, top_k=topk,
                             top_k_cap=topk_cap)
        return nxt, nxt[:, None], pool, pos + 1

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    # ------------------------------------------------------------------
    # block bookkeeping
    # ------------------------------------------------------------------
    def _evictable(self, exclude=()) -> int:
        ex = set(exclude)
        return self.trie.evictable(
            lambda b: b not in ex and self.allocator.refcount(b) == 1)

    def _alloc_block(self, protect=()) -> int:
        """A fresh private block, evicting cold L1 prefixes if needed.
        ``protect`` names blocks that must survive eviction (e.g. the
        boundary block an in-progress admission is about to gather)."""
        try:
            return self.allocator.alloc()
        except BlockPoolExhausted:
            ex = set(protect)
            dropped = self.trie.evict(
                1, lambda b: b not in ex and self.allocator.refcount(b) == 1)
            for b in dropped:
                self.allocator.unref(b)
                self.stats["trie_evictions"] += 1
            if not dropped:
                raise
            return self.allocator.alloc()

    def device_kv_bytes_in_use(self) -> int:
        """Bytes of pool K/V actually referenced (live blocks, counted
        once however many tables share them).  In int8 mode a block costs
        int8 K/V + per-vector f32 scales — the ~2-4x reduction this
        returns vs an fp pool is the whole point of the tier; the per-row
        fp ring tails are a constant (max_batch-sized) overhead, not a
        per-block cost, and are excluded."""
        return self.allocator.num_live() * paged_block_bytes(
            self.cfg, self.block, dtype=jnp.dtype(self.cfg.dtype),
            quant=self.kv_quant)

    # ------------------------------------------------------------------
    def admit_slot(self, slot: int, prompt: str, *,
                   max_new_tokens: Optional[int] = None,
                   use_recycling: bool = True, admit: bool = False,
                   stop_at_eos: bool = True, temperature: float = 0.0,
                   top_k: int = 0) -> Optional[GenResult]:
        """Admit ``prompt`` into pool row ``slot``: L1 block-table reuse
        when the prefix is device-resident, else L2 host promotion, else a
        cold prefill — all through one staged dense prefill whose result
        is scattered into (copy-on-write) private blocks."""
        if self._slots[slot] is not None:
            raise ValueError(f"slot {slot} is occupied")
        max_new = max_new_tokens or self.max_new
        t0 = time.perf_counter()
        ids = self.tok.encode(prompt)
        m = len(ids)
        if m + max_new > self.capacity:
            raise ValueError(f"request needs {m + max_new} positions; pool "
                             f"capacity is {self.capacity}")
        bs = self.block
        nb_prompt = _ceil_div(m, bs)
        nb_total = _ceil_div(m + max_new, bs)

        depth, hit, mode, sim = 0, False, "baseline", 0.0
        chain: List[Tuple[int, int]] = []
        res = None
        if use_recycling:
            d1, chain = self.trie.lookup(ids)
            d1 = min(d1, m - 1)
            d2 = 0
            if d1 < m - 1:
                # L1 can still be beaten — consult the host (L2) tier.
                # At maximal resident depth the lookup is skipped: no host
                # hit (d2 <= m-1) could win, and Recycler.lookup would
                # materialize the whole host cache just to be discarded.
                res = self.recycler.lookup(prompt, ids)
                if res.hit and self._host_layout_ok(res.cache):
                    d2 = res.reuse_depth
                elif res.hit:
                    # entry admitted by an engine with the other pool
                    # layout (fp vs int8) — can't promote it; honest miss
                    self.stats["layout_skips"] += 1
                    d2 = 0
                else:
                    d2 = 0
                sim = res.similarity
            # prefer the resident tier unless the host hit is deeper by
            # MORE than one block: re-prefilling a partial-block tail is
            # far cheaper than a host→device copy of the whole prefix
            if d1 > 0 and d1 >= d2 - bs:
                depth, hit, mode = d1, True, "resident_block"
                # a resident hit is served by the trie, not retrieval —
                # there is no honest similarity to report
                sim = float("nan")
                self.stats["resident_hits"] += 1
            elif d2 > 0:
                depth, hit, mode = d2, True, res.mode
                self.stats["host_promotions"] += 1
            else:
                mode = "miss"
        if mode != "resident_block":
            chain = []

        nb_shared = depth // bs if chain else 0
        start = nb_shared * bs               # first position written fresh
        shared = [b for b, _ in chain[:nb_shared]]
        gather = [b for b, _ in chain[:_ceil_div(depth, bs)]] if chain else []

        # admission guarantee: every block this request will ever need —
        # now or at a later decode boundary — must be obtainable without
        # starving the futures other in-flight rows were promised
        need_now = nb_prompt - nb_shared
        need_later = nb_total - nb_prompt
        owed = sum(self._committed)
        avail = self.allocator.num_free() + self._evictable(exclude=gather)
        if avail < need_now + need_later + owed:
            raise ValueError(
                f"paged pool exhausted: request needs {need_now + need_later}"
                f" blocks, {avail - owed} obtainable "
                f"(free={self.allocator.num_free()}, "
                f"in-flight reservations={owed})")

        for b in shared:                      # share the resident prefix
            self.allocator.ref(b)
        fresh = [self._alloc_block(protect=gather) for _ in range(need_now)]
        if chain and depth % bs:
            # divergent boundary block: its private copy is written from
            # staging below instead of mutating the shared original
            self.stats["cow_copies"] += 1

        # ---- staged dense prefill (the compiled serial path) ----------
        cap = self._capacity(m)
        if mode == "resident_block":
            stage = self._stage_fn(self.pool, jnp.asarray(gather, jnp.int32),
                                   depth, cap)
        elif hit:
            self.stats["h2d_copies"] += 1
            self.stats["h2d_bytes"] += tree_bytes(res.cache)
            stage = jax.tree.map(jnp.asarray, grow_capacity(res.cache, cap))
        else:
            stage = self._make_cache(cap)
        suffix = jnp.asarray(ids[depth:])[None]
        logits, stage = self._prefill_fn(self.params, suffix, stage, depth)

        # ---- scatter the fresh region [start, m) into private blocks --
        # A quantized host entry's full int8 blocks are promoted verbatim
        # (_upload_q8, no requant); everything after them — the entry's fp
        # residual tail, the sub-block remainder, the fresh suffix — is
        # quantized here, at its one scatter.
        if fresh:
            up = (self._q8_blocks(res.entry.cache, depth)
                  if self.kv_quant and hit and mode != "resident_block"
                  and res is not None and res.entry is not None else 0)
            if up:
                self.pool = self._upload_fn(
                    self.pool, self._slice_q8(res.entry.cache, up * bs),
                    jnp.asarray(fresh[:up], jnp.int32), bs)
                self.stats["q8_block_promotions"] += up
            s0 = start + up * bs             # start == 0 on the host path
            self.pool = self._scatter_fn(
                self.pool, stage, jnp.asarray(fresh[up:], jnp.int32),
                s0, m - s0, bs)
        if self.kv_quant:
            # the row's fp ring tail must cover its last R blocks from the
            # very first decode step — even on a fully-shared resident hit
            # the previous occupant's tail is stale for this request
            self.pool = self._tail_fn(self.pool, stage, jnp.int32(slot),
                                      jnp.int32(m))

        # ---- index the now-resident prompt prefix in L1 ---------------
        table_blocks = shared + fresh        # covers [0, m)
        for b in self.trie.register(ids, m, table_blocks):
            self.allocator.ref(b)            # the trie's own reference

        if temperature > 0.0:
            self._step_rng, sub = jax.random.split(self._step_rng)
            tok0 = sample_logits(logits, sub, temperature=temperature,
                                 top_k=top_k)
        else:
            tok0 = engine_mod.greedy(logits)

        self.stats["requests"] += 1
        self.stats["hits"] += int(hit)
        self.stats["tokens_reused"] += depth
        self.stats["tokens_prefilled"] += m - depth
        self.stats["admissions"] += 1

        st = _Slot(prompt, ids, m, max_new, use_recycling, admit,
                   stop_at_eos, depth, hit, mode, sim,
                   emitted=[int(tok0[0])], t0=t0,
                   temperature=temperature, top_k=top_k)
        if (st.stop_at_eos and st.emitted[0] == EOS) or max_new == 1:
            # finished at its first token: the prompt prefix stays warm in
            # L1, but the row is never occupied
            result = self._result(st, stage=stage, cap=cap)
            for b in table_blocks:
                self.allocator.unref(b)
            return result

        row = np.full((self.nbt,), SENTINEL, np.int32)
        row[:len(table_blocks)] = table_blocks
        self._tables[slot] = row
        self._row_blocks[slot] = list(table_blocks)
        self._committed[slot] = need_later
        self._temp[slot] = temperature
        self._topk[slot] = top_k
        self.pool, self._tokens, self._pos = self._setrow_fn(
            self.pool, self._tokens, self._pos, slot, jnp.asarray(row),
            tok0, jnp.int32(m))
        self._slots[slot] = st
        return None

    # ------------------------------------------------------------------
    def decode_batch(self) -> List[Tuple[int, GenResult]]:
        """One masked decode step over the paged pool (single dispatch).
        Before stepping, rows whose next write position crosses into an
        unallocated table entry get a fresh private block (allocation is
        on demand — device bytes track actual lengths, not capacity)."""
        active = self.active_slots()
        if not active:
            return []
        for i in active:
            st = self._slots[i]
            p = st.m + len(st.emitted) - 1   # position this step writes
            idx = p // self.block
            if self._tables[i, idx] == SENTINEL:
                b = self._alloc_block()
                self._tables[i, idx] = b
                self._row_blocks[i].append(b)
                self._committed[i] -= 1
                self.pool = self._setent_fn(self.pool, i, idx, jnp.int32(b))

        if np.any(self._temp > 0.0):
            self._step_rng, sub = jax.random.split(self._step_rng)
            self.stats["sampled_steps"] += 1
            nxt, self._tokens, self.pool, self._pos = self._pstep_sampled_fn(
                self.params, self._tokens, self.pool, self._pos,
                jnp.asarray(self._temp), jnp.asarray(self._topk), sub,
                max(int(self._topk.max()), 1))
        else:
            nxt, self._tokens, self.pool, self._pos = self._pstep_fn(
                self.params, self._tokens, self.pool, self._pos)
        toks = np.asarray(nxt)
        self.stats["batched_decode_steps"] += 1
        done: List[Tuple[int, GenResult]] = []
        for i in active:
            st = self._slots[i]
            st.emitted.append(int(toks[i]))
            if ((st.stop_at_eos and st.emitted[-1] == EOS)
                    or len(st.emitted) >= st.max_new):
                done.append((i, self._result(st, row=i)))
                self._release_row(i)
        return done

    # ------------------------------------------------------------------
    def _release_row(self, row: int) -> None:
        """Free the row: drop its table references (prefix blocks indexed
        in L1 survive as the device cache tier; generation-only blocks
        fall to refcount 0 and return to the free list)."""
        for b in self._row_blocks[row]:
            self.allocator.unref(b)
        self._row_blocks[row] = []
        self._committed[row] = 0
        self._tables[row] = SENTINEL
        self._temp[row] = 0.0
        self._topk[row] = 0
        self.pool = self._clear_fn(self.pool, row)
        self._slots[row] = None

    # ------------------------------------------------------------------
    def _result(self, st: _Slot, *, row: Optional[int] = None, stage=None,
                cap: Optional[int] = None) -> GenResult:
        if st.admit:
            cap = cap or self._capacity(st.m + st.max_new)
            if stage is None:
                # harvest from the pool: gather the row's prompt blocks
                # back into the host-store layout, valid [0, m) — int8
                # pools keep their codes verbatim (_harvest)
                ids = [b for b in self._tables[row]
                       if b != SENTINEL][:_ceil_div(st.m, self.block)]
                host = self._harvest(jnp.asarray(ids, jnp.int32),
                                     st.m, cap)
            else:
                # instant finish: the staging cache already holds exactly
                # [0, m) — generated positions were never written into it
                host = to_host(stage)
            self.recycler.admit(st.prompt, st.ids, host, st.m, cap)
        all_ids = np.concatenate([st.ids, np.asarray(st.emitted, np.int32)])
        return GenResult(
            text=self.tok.decode(st.emitted),
            token_ids=all_ids,
            latency_s=time.perf_counter() - st.t0,
            prompt_tokens=st.m,
            gen_tokens=len(st.emitted),
            reuse_depth=st.depth,
            cache_hit=st.hit,
            mode=st.mode if st.use_recycling else "baseline",
            prompt_similarity=st.sim,
        )

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Paged-pool global invariants (fuzzed in tests):
          * allocator free/live accounting is consistent
          * every block's refcount equals (#tables naming it) + (1 if the
            L1 trie indexes it) — so a block in two tables is provably
            shared, and no freed block is reachable
          * table entries beyond a row's blocks are sentinel"""
        self.allocator.check()
        expected: Dict[int, int] = {}
        for i in range(self.max_batch):
            for b in self._row_blocks[i]:
                expected[b] = expected.get(b, 0) + 1
            named = [b for b in self._tables[i] if b != SENTINEL]
            assert named == self._row_blocks[i], \
                (i, named, self._row_blocks[i])
        for b in self.trie.blocks():
            expected[b] = expected.get(b, 0) + 1
        for b in range(1, self.allocator.num_blocks):
            assert self.allocator.refcount(b) == expected.get(b, 0), \
                (b, self.allocator.refcount(b), expected.get(b, 0))
