"""Training launcher.

Local (CPU, reduced config — the example driver):
  PYTHONPATH=src python -m repro.launch.train --arch dialogpt-medium \
      --reduced --steps 200 --batch 8 --seq-len 128

Production mesh (on a real TPU slice; here validated by the dry-run):
  python -m repro.launch.train --arch qwen3-1.7b --mesh pod16x16 ...
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import ByteTokenizer, TrainBatches
from repro.models import init_params
from repro.runtime import Runtime, LOCAL
from repro.training import train, save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="checkpoints")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq_len}")

    tok = ByteTokenizer(cfg.vocab_size)
    batches = TrainBatches(tok, batch=args.batch, seq_len=args.seq_len,
                           seed=args.seed)

    def log(m):
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}  "
              f"{m['elapsed_s']:.1f}s", flush=True)

    params, opt_state, history = train(
        cfg, params, batches, steps=args.steps, lr=args.lr,
        warmup=args.warmup, log_every=args.log_every, callback=log)

    out = os.path.join(args.out, cfg.name)
    save_checkpoint(out, params, opt_state, step=args.steps,
                    extra={"arch": cfg.name})
    with open(os.path.join(out, "history.json"), "w") as f:
        json.dump(history, f, indent=1)
    print(f"checkpoint -> {out}")


if __name__ == "__main__":
    main()
