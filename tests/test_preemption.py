"""Pressure-safe serving (PR 10): preemption with EXACT resume, bounded
backpressure admission, and typed request outcomes.

The core claim is token identity: a greedy request that is preempted
(its rows demoted to the host L2 / its pending admission cancelled) and
later resumed through the warm admission machinery emits EXACTLY the
tokens an uninterrupted run emits — fp and int8 pools, chunked and
packed prefill.  ``BlockPoolExhausted`` never escapes an engine step:
under an undersized pool the engine preempts victims (least-progress
first, latest-deadline tiebreak) instead of failing the step.

The bounded-backpressure surface is data, not exceptions: full queues
shed at submit, expired deadlines shed before claiming blocks, and every
terminal request carries a typed ``RequestOutcome``.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.blockpool import BlockPoolExhausted, PoolSaturated
from repro.core.faults import plan_from_spec
from repro.models import init_params
from repro.serving import PagedEngine
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     RequestOutcome)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

PROMPTS = [
    "the quick brown fox jumps over the lazy dog today and tomorrow",
    "what is the capital of france and why is it paris",
    "zzz qqq completely unrelated 12345 something else entirely here",
]


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(engine, prompts, **kw):
    sched = ContinuousBatchingScheduler(engine)
    reqs = [sched.submit(p, **kw) for p in prompts]
    sched.run()
    return sched, reqs


def _reference(stack, *, prefill_mode="chunked", kv_quant=False, max_new=8):
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=3, capacity=128,
                      max_new_tokens=max_new, block_size=8,
                      enable_partial=True, prefill_mode=prefill_mode,
                      kv_quant=kv_quant)
    _, reqs = _run(eng, PROMPTS, admit=True)
    return {p: r.result.text for p, r in zip(PROMPTS, reqs)}


# ---------------------------------------------------------------------------
# overload: undersized pool, every request preempted-or-not must match
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("prefill_mode,kv_quant", [
    ("chunked", False), ("packed", False),
    ("chunked", True), ("packed", True)])
def test_overload_token_identity(stack, prefill_mode, kv_quant):
    """An undersized overcommitted pool forces preemptions; all requests
    still complete with tokens identical to an uncapped run, invariants
    intact, and BlockPoolExhausted never escapes a step."""
    cfg, params = stack
    want = _reference(stack, prefill_mode=prefill_mode, kv_quant=kv_quant)
    small = PagedEngine(cfg, params, max_batch=3, capacity=128,
                        max_new_tokens=8, block_size=8, enable_partial=True,
                        prefill_mode=prefill_mode, kv_quant=kv_quant,
                        num_blocks=10, overcommit=True)
    sched, reqs = _run(small, PROMPTS, admit=True)
    small.check_invariants()
    assert small.stats["preemptions"] > 0
    assert sched.stats["preemptions"] > 0
    for p, r in zip(PROMPTS, reqs):
        assert r.outcome == RequestOutcome.OK, (r.outcome, r.error)
        assert r.result.text == want[p], (p, prefill_mode, kv_quant)
    # at least one result records the preemption it survived
    assert any(r.result.preemptions > 0 for r in reqs)


def test_overload_staged_defers_not_fails(stack):
    """The staged (reference) path cannot chunk, so saturation surfaces
    as PoolSaturated — the scheduler defers and retries, it does not
    reject, and every request still completes."""
    cfg, params = stack
    want = _reference(stack, prefill_mode="staged")
    small = PagedEngine(cfg, params, max_batch=3, capacity=128,
                        max_new_tokens=8, block_size=8, enable_partial=True,
                        prefill_mode="staged", num_blocks=12,
                        overcommit=True)
    sched, reqs = _run(small, PROMPTS, admit=True)
    small.check_invariants()
    for p, r in zip(PROMPTS, reqs):
        assert r.outcome == RequestOutcome.OK, (r.outcome, r.error)
        assert r.result.text == want[p], p
    assert sched.stats["admissions_deferred"] >= 0   # surface exists


# ---------------------------------------------------------------------------
# property: preempt at an ARBITRARY step, resume must be token-identical
# ---------------------------------------------------------------------------
_WANT: dict = {}

if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(n=st.integers(min_value=0, max_value=30),
           mode_i=st.integers(min_value=0, max_value=1),
           quant=st.booleans())
    def test_preempt_anywhere_token_identity(stack, n, mode_i, quant):
        """One injected alloc fault at the n-th allocator call preempts
        some request at an arbitrary point in its life (mid-admission or
        mid-decode); the resumed run is token-identical regardless of
        where the axe fell."""
        mode = ("chunked", "packed")[mode_i]
        cfg, params = stack
        key = (mode, quant)
        if key not in _WANT:
            eng = PagedEngine(cfg, params, max_batch=3, capacity=128,
                              max_new_tokens=6, block_size=8,
                              enable_partial=True, prefill_mode=mode,
                              kv_quant=quant)
            _, reqs = _run(eng, PROMPTS)
            _WANT[key] = {p: r.result.text for p, r in zip(PROMPTS, reqs)}
        plan = plan_from_spec(0, alloc=(n,))
        eng = PagedEngine(cfg, params, max_batch=3, capacity=128,
                          max_new_tokens=6, block_size=8,
                          enable_partial=True, prefill_mode=mode,
                          kv_quant=quant, fault_plan=plan)
        sched, reqs = _run(eng, PROMPTS)
        eng.check_invariants()
        for p, r in zip(PROMPTS, reqs):
            assert r.outcome == RequestOutcome.OK, (r.outcome, r.error)
            assert r.result.text == _WANT[key][p], (p, n, mode, quant)


# ---------------------------------------------------------------------------
# bounded backpressure: typed shed outcomes
# ---------------------------------------------------------------------------
def test_queue_full_sheds_typed(stack):
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(eng, queue_limit=2)
    ok = [sched.submit(p) for p in PROMPTS[:2]]
    shed = sched.submit(PROMPTS[2])
    assert shed.outcome == RequestOutcome.SHED_QUEUE_FULL
    assert shed.done and shed.result is None
    assert sched.stats["shed_queue_full"] == 1
    sched.run()
    for r in ok:
        assert r.outcome == RequestOutcome.OK


def test_tenant_queue_limit(stack):
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(
        eng, tenant_queue_limits={"flood": 1})
    a = sched.submit(PROMPTS[0], tenant="flood")
    b = sched.submit(PROMPTS[1], tenant="flood")    # over the tenant bound
    c = sched.submit(PROMPTS[2], tenant="calm")     # other tenants unharmed
    assert a.outcome is None and c.outcome is None
    assert b.outcome == RequestOutcome.SHED_QUEUE_FULL
    sched.run()
    assert a.outcome == RequestOutcome.OK
    assert c.outcome == RequestOutcome.OK


def test_deadline_sheds_before_admission(stack):
    """An already-expired deadline is shed at the step boundary BEFORE
    claiming blocks; live deadlines serve normally."""
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(eng)
    dead = sched.submit(PROMPTS[0], deadline_s=0.0)
    live = sched.submit(PROMPTS[1], deadline_s=3600.0)
    time.sleep(0.01)
    sched.run()
    assert dead.outcome == RequestOutcome.SHED_DEADLINE
    assert dead.result is None
    assert live.outcome == RequestOutcome.OK
    assert sched.stats["shed_deadline"] == 1
    assert eng.stats["admissions"] == 1       # the dead one never admitted


def test_permanent_reject_is_errored(stack):
    """A prompt the pool can NEVER hold is a permanent typed reject, not
    a deferral loop."""
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=2, capacity=32,
                      max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(eng)
    req = sched.submit("word " * 200)
    sched.run()
    assert req.outcome == RequestOutcome.ERRORED
    assert req.error is not None


def test_victim_policy_least_progress(stack):
    """Under pressure the victim is the least-progress row (fewest
    emitted tokens), latest deadline breaking ties."""
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=3, capacity=128,
                      max_new_tokens=8, block_size=8, num_blocks=64)
    sched = ContinuousBatchingScheduler(eng)
    old = sched.submit(PROMPTS[0], max_new_tokens=32)
    for _ in range(4):              # let the first request build progress
        sched.step()
    young = sched.submit(PROMPTS[1], max_new_tokens=32)
    sched.step()                    # young admits
    assert len(sched.in_flight) == 2
    # force pressure: exhaust the free list, then step until a decode
    # write crosses a block boundary and must alloc under an empty pool
    # — the YOUNG row (least progress) must be the victim
    grabbed = []
    while True:
        try:
            grabbed.append(eng.allocator.alloc())
        except BlockPoolExhausted:
            break
    for _ in range(10):
        sched.step()
        if eng.stats["preemptions"]:
            break
    for b in grabbed:
        eng.allocator.unref(b)
    assert eng.stats["preemptions"] >= 1
    assert old in sched.in_flight.values()      # survivor: the old row
    sched.run()
    eng.check_invariants()
    assert old.outcome == RequestOutcome.OK
    assert young.outcome == RequestOutcome.OK
    assert young.result.preemptions >= 1


def test_deadline_threads_to_engine_victim_choice(stack):
    """Equal-progress victims: the LATEST deadline is sacrificed first,
    so the tightest-SLO row survives.

    Prompt lengths are chosen around block_size 8 (char tokenizer, +1
    BOS): the ALLOCATOR row (61 chars -> 62 positions) crosses into a
    fresh block at write position 64, i.e. on its 3rd emit — BEFORE the
    two victim rows (49 chars -> 50 positions, crossing at 56 on their
    7th emit).  When the allocator hits the drained pool both victims
    have equal progress, so the engine must break the tie by deadline."""
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=3, capacity=128,
                      max_new_tokens=8, block_size=8, num_blocks=64)
    sched = ContinuousBatchingScheduler(eng)
    alloc_row = sched.submit("x" * 61, max_new_tokens=16)
    tight = sched.submit("a" * 49, deadline_s=5.0, max_new_tokens=16)
    loose = sched.submit("b" * 49, deadline_s=3600.0, max_new_tokens=16)
    while sched._queue or not sched.in_flight:
        sched.step()
    grabbed = []
    while True:
        try:
            grabbed.append(eng.allocator.alloc())
        except BlockPoolExhausted:
            break
    for _ in range(10):
        sched.step()
        if eng.stats["preemptions"]:
            break
    for b in grabbed:
        eng.allocator.unref(b)
    assert eng.stats["preemptions"] >= 1
    survivors = list(sched.in_flight.values())
    assert tight in survivors           # tightest SLO kept its row
    assert loose not in survivors       # latest deadline was the victim
    sched.run()
    eng.check_invariants()
    assert alloc_row.outcome == RequestOutcome.OK
    assert tight.outcome == RequestOutcome.OK
    assert loose.outcome == RequestOutcome.OK
    assert loose.result.preemptions >= 1


def test_genresult_carries_preemption_counters(stack):
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=3, capacity=128,
                      max_new_tokens=8, block_size=8, num_blocks=10,
                      overcommit=True)
    _, reqs = _run(eng, PROMPTS)
    total = sum(r.result.preemptions for r in reqs)
    assert total == eng.stats["preemptions"] - eng.stats["preempt_errors"]
    assert (sum(r.result.tokens_recomputed for r in reqs)
            == eng.stats["preempted_tokens_recomputed"])


def test_slo_summary_reports_pressure(stack):
    from repro.core.metrics import slo_summary
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=2, capacity=128,
                      max_new_tokens=4, block_size=8)
    sched = ContinuousBatchingScheduler(eng, queue_limit=2)
    reqs = [sched.submit(p) for p in PROMPTS]
    sched.run()
    results = [r.result for r in reqs if r.result is not None]
    s = slo_summary(results, reqs)
    assert s["requests_submitted"] == 3
    assert s["outcome_counts"].get("shed_queue_full") == 1
    assert s["shed_rate"] == pytest.approx(1 / 3)
    assert s["tokens_recomputed"] == 0
    assert s["preemption_rate"] == 0.0
