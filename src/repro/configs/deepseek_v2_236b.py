"""deepseek-v2-236b — MoE with Multi-head Latent Attention.

[arXiv:2405.04434] DeepSeek-V2.  60L, d_model=5120, 128 heads, MLA with
kv_lora_rank=512, expert d_ff=1536, vocab=102400; 2 shared + 160 routed
experts, top-6; first layer uses a dense FFN (12288).
The KV cache stores only the 512+64 latent per token — the smallest recycled
bytes of any assigned arch (recycling synergy, see DESIGN.md §4).
"""
from repro.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    source="arXiv:2405.04434 (DeepSeek-V2 236B-A21B)",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,            # MLA: logical heads; cache is latent
    d_ff=1536,                   # routed-expert d_ff (assigned)
    vocab_size=102_400,
    head_dim=128,
    sliding_window=8192,
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_ff_expert=1536,
        num_shared_experts=2,
        first_dense_layers=1,
        dense_d_ff=12288,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
)
