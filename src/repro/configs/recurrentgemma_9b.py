"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 1:2 pattern.

[arXiv:2402.19427] Griffin: Mixing Gated Linear Recurrences with Local
Attention.  38L, d_model=4096, 16 heads (MQA kv=1) for the local-attention
layers, d_ff=12288, vocab=256000.  Pattern repeats (rglru, rglru, local_attn).
Runs long_500k natively (state + 2048-token window).
"""
from repro.config import ModelConfig, HybridConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    source="arXiv:2402.19427 (RecurrentGemma-9B / Griffin)",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    head_dim=256,
    mlp="swiglu",
    sliding_window=0,            # hybrid handles long context natively
    hybrid=HybridConfig(
        pattern=("rglru", "rglru", "local_attn"),
        lru_width=4096,
        local_window=2048,
        conv1d_width=4,
    ),
)
