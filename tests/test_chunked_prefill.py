"""Paged-native chunked prefill: the chunked admission route is
token-for-token identical to the staged path (and therefore to serial
``generate``) across every admission mode, fp and int8; a warm-prefix
admission performs ZERO staging-cache work (no staging allocation, no
gather, no scatter — asserted by poisoning the staged helpers); the
prefill-compile count is independent of how many distinct suffix lengths
are admitted; chunk boundaries always land on block boundaries and
copy-on-write never mutates a shared block mid-chunk (hypothesis
properties); and the Pallas chunk kernel matches the jnp reference.

The staged path (``prefill_mode="staged"``) stays available as the
reference baseline — several tests here run both modes over identical
recycler contents and diff the outputs, the same discipline the paged
pool uses against the dense slot pool.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import EOS
from repro.models import init_params
from repro.serving import (ContinuousBatchingScheduler, Engine,
                           PagedEngine)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CACHED = [
    "the quick brown fox jumps over the lazy dog today",
    "what is the capital of france and why",
]
REQUESTS = [
    (CACHED[0] + " and tomorrow", "exact_prefix"),
    ("the quick brown fox jumps over a red fence", "partial_block"),
    ("zzz qqq completely unrelated 12345", "miss"),
    (CACHED[1] + " is it paris", "exact_prefix"),
]


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("dialogpt-medium").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _paged(stack, *, prefill_mode, quant=False, max_new=6, max_batch=3,
           capacity=128, precache=CACHED, **kw):
    cfg, params = stack
    eng = PagedEngine(cfg, params, max_batch=max_batch, capacity=capacity,
                      max_new_tokens=max_new, block_size=8,
                      enable_partial=True, kv_quant=quant,
                      prefill_mode=prefill_mode, **kw)
    if precache:
        eng.precache(precache)
    return eng


def _run(eng, prompts, **submit_kw):
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, **submit_kw) for p in prompts]
    sched.run()
    eng.check_invariants()
    return reqs


# ---------------------------------------------------------------------------
# equivalence: chunked == staged == serial, all modes, fp and int8
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quant", [False, True], ids=["fp", "int8"])
def test_chunked_equals_staged_all_modes(stack, quant):
    """Acceptance: chunked greedy decode is token-identical to the staged
    path on the reduced DialoGPT workload, fp and int8, across
    exact/partial/miss admissions."""
    staged = _paged(stack, prefill_mode="staged", quant=quant)
    chunked = _paged(stack, prefill_mode="chunked", quant=quant)
    sreqs = _run(staged, [p for p, _ in REQUESTS])
    creqs = _run(chunked, [p for p, _ in REQUESTS])
    for (p, want), rs, rc in zip(REQUESTS, sreqs, creqs):
        # the chunked tier lookup runs at the first CHUNK (not at admit),
        # so a request can see a different snapshot of the block trie
        # than the staged engine did and upgrade/downgrade between the
        # resident and host tiers — tokens must not drift either way
        assert rc.result.mode in (rs.result.mode, want, "resident_block",
                                  "partial_block"), p
        assert rc.result.text == rs.result.text, (p, rc.result.mode)
        np.testing.assert_array_equal(rc.result.token_ids,
                                      rs.result.token_ids)
    assert chunked.stats["prefill_chunks"] > 0
    assert chunked.stats["staging_prefills"] == 0
    assert staged.stats["staging_prefills"] == len(REQUESTS)


def test_chunked_equals_serial_multi_chunk(stack):
    """A small chunk size forces every admission through SEVERAL chunk
    steps interleaved with decode; fp outputs stay identical to serial."""
    cfg, params = stack
    ser = Engine(cfg, params, max_new_tokens=6, block_size=8,
                 enable_partial=True)
    ser.precache(CACHED)
    serial = {p: ser.generate(p) for p, _ in REQUESTS}
    eng = _paged(stack, prefill_mode="chunked", prefill_chunk=16)
    reqs = _run(eng, [p for p, _ in REQUESTS])
    # 16-token chunks over ~35-62 token prompts -> >1 chunk per admission
    assert eng.stats["prefill_chunks"] > len(REQUESTS)
    for (p, _), r in zip(REQUESTS, reqs):
        np.testing.assert_array_equal(r.result.token_ids,
                                      serial[p].token_ids)


def test_chunked_early_eos_equivalence(stack, monkeypatch):
    """Early-EOS rows free their blocks mid-flight while other admissions
    are still chunking; survivors keep decoding exactly like staged."""
    import repro.serving.engine as engine_mod

    def eos_greedy(logits):
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(g % 5 == 1, jnp.int32(EOS), g)

    monkeypatch.setattr(engine_mod, "greedy", eos_greedy)
    staged = _paged(stack, prefill_mode="staged", max_new=8)
    chunked = _paged(stack, prefill_mode="chunked", max_new=8)
    sreqs = _run(staged, [p for p, _ in REQUESTS])
    creqs = _run(chunked, [p for p, _ in REQUESTS])
    assert any(r.result.gen_tokens < 8 and r.result.token_ids[-1] == EOS
               for r in sreqs), "remap produced no early EOS"
    for rs, rc in zip(sreqs, creqs):
        assert rc.result.text == rs.result.text
        assert rc.result.gen_tokens == rs.result.gen_tokens
        np.testing.assert_array_equal(rc.result.token_ids,
                                      rs.result.token_ids)


# ---------------------------------------------------------------------------
# the staging round-trip is GONE from the chunked route
# ---------------------------------------------------------------------------
def test_warm_admission_zero_staging_roundtrip(stack, monkeypatch):
    """Acceptance: a warm-prefix chunked admission performs zero
    staging-cache allocation and zero gather/scatter round-trip.  The
    staged helpers are poisoned — ANY call fails the test — and the
    admission must still serve residentially with no new host traffic."""
    eng = _paged(stack, prefill_mode="chunked")
    _run(eng, [CACHED[0] + " first pass"])
    h2d = eng.stats["h2d_copies"]

    def boom(*a, **k):
        raise AssertionError("staged admission helper called on the "
                             "chunked route")

    monkeypatch.setattr(eng, "_stage_fn", boom)
    monkeypatch.setattr(eng, "_scatter_fn", boom)
    monkeypatch.setattr(eng, "_make_cache", boom)
    monkeypatch.setattr(eng, "_tail_fn", boom)
    reqs = _run(eng, [CACHED[0] + " second pass"])
    r = reqs[0].result
    assert r.cache_hit and r.mode == "resident_block"
    assert np.isnan(r.prompt_similarity)
    assert eng.stats["h2d_copies"] == h2d
    assert eng.stats["staging_prefills"] == 0


def test_prefill_compiles_independent_of_suffix_lengths(stack):
    """Acceptance: the chunked prefill-compile count is bounded by the
    fixed chunk-shape ladder — independent of the number of distinct
    suffix lengths — while the staged path compiles one executable per
    length (the gap this PR closes)."""
    prompts = [f"prompt of a distinct length {'x' * i}" for i in
               (0, 3, 7, 11, 19)]
    more = [f"another batch of different lengths {'y' * i}" for i in
            (1, 5, 13, 23)]
    chunked = _paged(stack, prefill_mode="chunked", max_batch=2,
                     precache=None)
    _run(chunked, prompts)
    assert chunked.prefill_compiles() <= len(chunked.chunk_shapes)
    seen = chunked.prefill_compiles()
    _run(chunked, more)                    # new lengths, NO new compiles
    assert chunked.prefill_compiles() == seen
    staged = _paged(stack, prefill_mode="staged", max_batch=2,
                    precache=None)
    _run(staged, prompts)
    assert staged.prefill_compiles() > len(chunked.chunk_shapes)


def test_ttft_recorded(stack):
    eng = _paged(stack, prefill_mode="chunked")
    reqs = _run(eng, [p for p, _ in REQUESTS])
    for r in reqs:
        assert r.result.ttft_s > 0.0
        assert r.result.ttft_s <= r.result.latency_s


# ---------------------------------------------------------------------------
# speculative block pre-allocation
# ---------------------------------------------------------------------------
def test_spec_prealloc_reserves_ahead_and_stays_correct(stack):
    """With a watermark, the next block is reserved before the write
    position reaches it (reserved-but-unfilled blocks satisfy the same
    refcount invariants) and outputs are unchanged."""
    cfg, params = stack
    ser = Engine(cfg, params, max_new_tokens=20, block_size=8,
                 enable_partial=True)
    p = "a prompt long enough to cross block boundaries while decoding"
    want = ser.generate(p)
    for wm in (0, 1, 4):
        eng = _paged(stack, prefill_mode="chunked", max_new=20,
                     precache=None, prealloc_watermark=wm)
        sched = ContinuousBatchingScheduler(eng)
        r = sched.submit(p)
        while sched.pending() or sched.in_flight:
            sched.step()
            eng.check_invariants()      # holds with reserved blocks live
        np.testing.assert_array_equal(r.result.token_ids, want.token_ids)
        if wm:
            assert eng.stats["spec_preallocs"] > 0
        else:
            assert eng.stats["spec_preallocs"] == 0


# ---------------------------------------------------------------------------
# kernel == reference == oracle
# ---------------------------------------------------------------------------
def test_chunk_kernel_matches_reference_fp():
    from repro.kernels import ops
    from repro.models.attention import attend_direct, attend_paged_prefill
    rng = np.random.default_rng(11)
    NB, bs, H, hkv, dh, NBt, C = 12, 8, 4, 2, 16, 6, 16
    kp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, hkv, dh)), jnp.float32)
    tbl = jnp.asarray([3, 5, 7, 9, 0, 0], jnp.int32)
    q = jnp.asarray(rng.normal(size=(1, C, H, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(1, C, hkv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(1, C, hkv, dh)), jnp.float32)
    cache = {"k": kp, "v": vp,
             "block_tables": jnp.zeros((1, NBt), jnp.int32)}
    c0, w_eff = 16, 16
    ref = attend_paged_prefill(q, kc, vc, cache, 0, tbl, c0, w_eff)
    out = ops.paged_prefill_attention(q, kc, vc, kp, vp, tbl, c0, w_eff,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # oracle: dense attention over [history ; chunk] with explicit
    # positions — proves masking, not just kernel==reference
    k = jnp.concatenate([kp[tbl].reshape(1, -1, hkv, dh), kc], axis=1)
    v = jnp.concatenate([vp[tbl].reshape(1, -1, hkv, dh), vc], axis=1)
    hist = jnp.arange(NBt * bs, dtype=jnp.int32)
    hist = jnp.where(hist < w_eff, hist, -1)
    kv_pos = jnp.concatenate([hist, c0 + jnp.arange(C, dtype=jnp.int32)])
    oracle = attend_direct(q, k, v, c0 + jnp.arange(C, dtype=jnp.int32),
                           kv_pos, causal=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(oracle),
                               atol=1e-6)


def test_chunk_kernel_matches_reference_quant():
    from repro.kernels import ops
    from repro.models.attention import attend_paged_prefill
    rng = np.random.default_rng(12)
    NB, bs, H, hkv, dh, NBt, C, R = 12, 8, 4, 2, 16, 6, 16, 2
    kp = jnp.asarray(rng.integers(-127, 128, size=(NB, bs, hkv, dh)),
                     jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, size=(NB, bs, hkv, dh)),
                     jnp.int8)
    ks = jnp.asarray(rng.uniform(0.001, 0.02, size=(NB, bs, hkv)),
                     jnp.float32)
    vs = jnp.asarray(rng.uniform(0.001, 0.02, size=(NB, bs, hkv)),
                     jnp.float32)
    kt = jnp.asarray(rng.normal(size=(1, R * bs, hkv, dh)), jnp.float32)
    vt = jnp.asarray(rng.normal(size=(1, R * bs, hkv, dh)), jnp.float32)
    tbl = jnp.asarray([3, 5, 7, 9, 0, 0], jnp.int32)
    q = jnp.asarray(rng.normal(size=(1, C, H, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(1, C, hkv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(1, C, hkv, dh)), jnp.float32)
    cache = {"k": kp, "v": vp, "k_scale": ks, "v_scale": vs,
             "k_tail": kt, "v_tail": vt,
             "block_tables": jnp.zeros((1, NBt), jnp.int32)}
    for c0, w_eff in ((16, 16), (24, 29)):      # plain + write-floor case
        ref = attend_paged_prefill(q, kc, vc, cache, 0, tbl, c0, w_eff)
        out = ops.paged_prefill_attention_quant(
            q, kc, vc, kp, vp, ks, vs, kt[0], vt[0], tbl, c0, w_eff,
            interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


def test_chunked_pallas_engine_equivalence(stack):
    """The Pallas chunk-kernel path produces the same greedy tokens as
    the jnp reference path on a real engine workload (fp and int8)."""
    from repro.runtime import Runtime
    for quant in (False, True):
        outs = []
        for rt in (Runtime(), Runtime(use_pallas=True)):
            eng = _paged(stack, prefill_mode="chunked", quant=quant,
                         max_batch=2, max_new=5, precache=CACHED[:1],
                         rt=rt)
            reqs = _run(eng, [p for p, _ in REQUESTS[:2]])
            outs.append([r.result.text for r in reqs])
        assert outs[0] == outs[1], ("pallas vs jnp", quant)


# ---------------------------------------------------------------------------
# hypothesis properties: chunk/block alignment, CoW isolation
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    class TestChunkPlanProperty:
        @given(depth=st.integers(0, 200), m=st.integers(1, 256),
               chunk_blocks=st.integers(1, 8), bs=st.sampled_from([4, 8, 16]))
        @settings(max_examples=200, deadline=None)
        def test_chunk_boundaries_block_aligned(self, depth, m,
                                                chunk_blocks, bs):
            """For ANY (reuse depth, prompt length, chunk size): every
            chunk starts on a block boundary, chunks tile [aligned, m)
            exactly, and only the last chunk may end unaligned."""
            depth = min(depth, m - 1)
            C = chunk_blocks * bs
            aligned = (depth // bs) * bs
            assert aligned % bs == 0
            starts, c0 = [], aligned
            while c0 < m:
                n_valid = min(C, m - c0)
                starts.append((c0, n_valid))
                c0 += n_valid
            assert c0 == m
            for i, (s, n) in enumerate(starts):
                assert s % bs == 0                      # the property
                if i < len(starts) - 1:
                    assert n == C and (s + n) % bs == 0

    class TestChunkedCoWProperty:
        @given(extra=st.integers(1, 24), chunk_blocks=st.integers(2, 6),
               quant=st.booleans())
        @settings(max_examples=5, deadline=None)
        def test_cow_never_mutates_donor_blocks(self, extra, chunk_blocks,
                                                quant):
            """A sharer extending a resident prompt by ANY suffix length,
            at ANY chunk size: the donor's pool blocks (including its
            partial tail) are bitwise unchanged after the sharer's whole
            chunked admission + decode."""
            cfg = get_config("dialogpt-medium").reduced()
            params = init_params(cfg, jax.random.PRNGKey(0))
            eng = PagedEngine(cfg, params, max_batch=2, capacity=128,
                              max_new_tokens=4, block_size=8,
                              enable_partial=True, kv_quant=quant,
                              prefill_mode="chunked",
                              prefill_chunk=8 * chunk_blocks)
            donor = "the quick brown fox jumps over the lazy dog"
            _run(eng, [donor])
            donor_blocks = sorted(eng.trie.blocks())
            before = {seg: np.asarray(c["k"][:, donor_blocks])
                      for seg, c in eng.pool.items()}
            reqs = _run(eng, [donor + " " + "y" * extra])
            assert reqs[0].result.mode == "resident_block"
            for seg, c in eng.pool.items():
                np.testing.assert_array_equal(
                    np.asarray(c["k"][:, donor_blocks]), before[seg])
            eng.check_invariants()
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_chunk_properties():
        pass
