"""The paper's contribution: cross-prompt KV cache recycling ("token
recycling"), productionized.

Paper-faithful pipeline (Pandey 2025, §2–§4):
  embed prompt -> retrieve most-similar cached prompt (dot product) ->
  exact-prefix token test -> reuse serialized past_key_values, feed only
  suffix tokens.

Beyond-paper extensions (reported separately, DESIGN.md §7):
  block-granular radix prefix cache with partial-LCP reuse and ref-counted
  LRU eviction; recurrent-state snapshot recycling for SSM/hybrid archs.
"""
from repro.core.blockpool import BlockAllocator, BlockPoolExhausted, SENTINEL
from repro.core.embedder import HashEmbedder
from repro.core.index import EmbeddingIndex
from repro.core.kvstore import HostKVStore, CacheEntry
from repro.core.lsh import BlockLSH
from repro.core.quant import (dequantize_tree, is_quantized, quantize_tree)
from repro.core.recycler import GraftPlan, Recycler, RecycleResult
from repro.core.radix import BlockTrie, RadixPrefixCache
from repro.core.metrics import RunMetrics, summarize_runs

__all__ = [
    "BlockAllocator",
    "BlockPoolExhausted",
    "BlockTrie",
    "SENTINEL",
    "HashEmbedder",
    "EmbeddingIndex",
    "HostKVStore",
    "CacheEntry",
    "BlockLSH",
    "GraftPlan",
    "Recycler",
    "RecycleResult",
    "RadixPrefixCache",
    "quantize_tree",
    "dequantize_tree",
    "is_quantized",
    "RunMetrics",
    "summarize_runs",
]
