"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
experiments/dryrun/*.json.

  PYTHONPATH=src python experiments/make_tables.py > experiments/tables.md
"""
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "dryrun")
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["whisper-base", "qwen2.5-3b", "recurrentgemma-9b",
         "deepseek-v2-236b", "qwen1.5-32b", "rwkv6-3b", "qwen3-1.7b",
         "command-r-35b", "internvl2-76b", "kimi-k2-1t-a32b"]


def load(tag):
    p = os.path.join(ROOT, tag + ".json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def fmt_bytes(b):
    return f"{b / 2**30:.1f}"


def roofline_table(mesh):
    print(f"\n### Roofline — {mesh} "
          f"({'256' if mesh == 'pod16x16' else '512'} chips)\n")
    print("| arch | shape | compute s | memory s | collective s | bottleneck"
          " | useful | GB/dev | fits | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            d = load(f"{a}__{s}__{mesh}")
            if d is None or not d.get("ok"):
                print(f"| {a} | {s} | - | - | - | FAIL | - | - | - | |")
                continue
            r = d["roofline"]
            note = ""
            if not r.get("flops_consistent", True):
                note = "analytic-c"
            print(f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f}"
                  f" | {r['collective_s']:.5f} | {r['bottleneck']}"
                  f" | {min(r['useful_ratio'], 1.0):.2f}"
                  f" | {fmt_bytes(d['bytes_per_device'])}"
                  f" | {'Y' if d['fits_hbm'] else 'N'} | {note} |")


def dryrun_table(mesh):
    print(f"\n### Dry-run records — {mesh}\n")
    print("| arch | shape | lower s | compile s | args GB | temp GB |"
          " coll bytes/step (all dev) | dominant collective |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            d = load(f"{a}__{s}__{mesh}")
            if d is None or not d.get("ok"):
                continue
            m = d["memory"]
            colls = {k: v for k, v in d["collectives"].items()
                     if k != "total"}
            dom = max(colls, key=colls.get) if colls else "-"
            tot = d["collectives"].get("total", 0)
            print(f"| {a} | {s} | {d['lower_s']} | {d['compile_s']}"
                  f" | {m.get('argument_size_in_bytes', 0)/2**30:.1f}"
                  f" | {m.get('temp_size_in_bytes', 0)/2**30:.1f}"
                  f" | {tot/2**20:.0f} MB | {dom} |")


if __name__ == "__main__":
    for mesh in ("pod16x16", "pod2x16x16"):
        roofline_table(mesh)
    for mesh in ("pod16x16", "pod2x16x16"):
        dryrun_table(mesh)
