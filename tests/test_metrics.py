"""Regression tests for ``core.metrics.tpot_summary`` degenerate inputs.

The summary used to emit NaN for empty sample sets (invalid JSON under
``json.dump(..., allow_nan=False)`` and silently non-standard otherwise)
and raised ``TypeError`` when a result carried ``step_times_s=None`` /
``ttft_s=None`` (the ``getattr`` default only covers ABSENT attributes,
not present-but-None ones).  Now: no samples → None fields + a zero
sample count; a single sample → every percentile is that sample.
"""
from __future__ import annotations

import json
from types import SimpleNamespace

from repro.core.metrics import tpot_summary


def test_empty_results_is_none_not_nan():
    s = tpot_summary([])
    assert s["tpot_samples"] == 0
    for key in ("tpot_p50_s", "tpot_p95_s", "tpot_mean_s",
                "ttft_mean_s", "ttft_p95_s"):
        assert s[key] is None
    # NaN would raise here; None serializes as null
    json.dumps(s, allow_nan=False)


def test_none_step_times_do_not_raise():
    # attribute PRESENT but None — the getattr default doesn't apply
    results = [SimpleNamespace(step_times_s=None, ttft_s=None)]
    s = tpot_summary(results)
    assert s["tpot_samples"] == 0
    assert s["tpot_p50_s"] is None
    assert s["ttft_mean_s"] is None


def test_single_entry_percentiles_are_that_sample():
    results = [SimpleNamespace(step_times_s=[0.25], ttft_s=0.5)]
    s = tpot_summary(results)
    assert s["tpot_samples"] == 1
    assert s["tpot_p50_s"] == s["tpot_p95_s"] == s["tpot_mean_s"] == 0.25
    assert s["ttft_mean_s"] == s["ttft_p95_s"] == 0.5


def test_mixed_results_skip_sampleless_entries():
    results = [
        SimpleNamespace(step_times_s=[0.1, 0.3], ttft_s=0.2),
        SimpleNamespace(step_times_s=None, ttft_s=None),
        SimpleNamespace(step_times_s=[], ttft_s=0.0),   # 0 ttft = unset
    ]
    s = tpot_summary(results)
    assert s["tpot_samples"] == 2
    assert abs(s["tpot_mean_s"] - 0.2) < 1e-12
    assert abs(s["ttft_mean_s"] - 0.2) < 1e-12
    json.dumps(s, allow_nan=False)
