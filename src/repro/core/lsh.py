"""Token-block LSH: position-aligned donor-block search (beyond paper).

The paper's reuse rule is an *exact full-prefix* test; SemShareKV
(arXiv 2509.24832) shows token-level LSH can surface reusable KV runs in
semantically similar prompts that share no prefix.  This module is the
retrieval half of that idea, adapted to the repo's block granularity:

Every cached prompt's token ids are cut into fixed-size blocks; each
FULL block gets a minhash signature over its token shingles, banded
LSH-style so near-identical blocks (most shingles shared) collide in at
least one band with high probability while unrelated blocks almost never
do.  The index is **position-aligned**: block ``b`` of a query can only
match block ``b`` of a donor, because a KV block is only a plausible
stand-in at the absolute positions it was computed for (the models bake
position into K/V at embed time; we do not re-rotate like SemShareKV's
RoPE realignment — see ROADMAP known limits).

Collisions are candidates, never verdicts: the recycler re-verifies
every candidate block against the donor's actual token ids
(``match_mask``), and the engine's boundary-divergence fidelity gate has
the final say.  Identical blocks always collide (minhash of an equal
shingle set is equal), so exact matches are never missed.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np


def _h64(data: bytes, seed: int) -> int:
    return int.from_bytes(
        hashlib.blake2b(data, digest_size=8,
                        salt=seed.to_bytes(8, "little")).digest(), "little")


def _shingles(tokens: Sequence[int], n: int) -> List[bytes]:
    toks = [int(t) for t in tokens]
    if len(toks) < n:
        return [np.asarray(toks, np.int64).tobytes()]
    return [np.asarray(toks[i:i + n], np.int64).tobytes()
            for i in range(len(toks) - n + 1)]


class BlockLSH:
    """Banded-minhash index of token blocks, keyed by (block index, band).

    ``n_hashes`` minhash values per block, grouped into ``n_bands`` bands;
    two blocks sharing a fraction ``s`` of their shingles collide in at
    least one band with probability ``1 - (1 - s^r)^b`` (r rows per
    band).  With the defaults (8 hashes, 4 bands of 2) an 80%-overlapping
    block is found ~98% of the time while a 20% one fires <15% of the
    time — and the recycler verifies candidates anyway.
    """

    def __init__(self, block_size: int, *, n_hashes: int = 8,
                 n_bands: int = 4, shingle: int = 2):
        assert block_size >= 1 and n_hashes % n_bands == 0
        self.block = block_size
        self.n_hashes = n_hashes
        self.n_bands = n_bands
        self.rows = n_hashes // n_bands
        self.shingle = shingle
        # (block_idx, band, band_key) -> entry ids whose block collides
        self._buckets: Dict[Tuple[int, int, int], Set[int]] = {}
        # entry id -> per-block band keys (for removal)
        self._entry_sigs: Dict[int, List[Tuple[int, ...]]] = {}

    def __len__(self) -> int:
        return len(self._entry_sigs)

    def __contains__(self, entry_id: int) -> bool:
        return entry_id in self._entry_sigs

    # ------------------------------------------------------------------
    def block_signature(self, tokens: Sequence[int]) -> Tuple[int, ...]:
        """Band keys of ONE full block (``n_bands``-tuple)."""
        sh = _shingles(tokens, self.shingle)
        mins = [min(_h64(s, seed) for s in sh)
                for seed in range(self.n_hashes)]
        keys = []
        for b in range(self.n_bands):
            band = mins[b * self.rows:(b + 1) * self.rows]
            keys.append(_h64(np.asarray(band, np.uint64).tobytes(),
                             self.n_hashes + b))
        return tuple(keys)

    def signatures(self, token_ids, length=None) -> List[Tuple[int, ...]]:
        """Per-FULL-block band keys of ``token_ids[:length]`` (a partial
        tail block gets no signature — it is never graftable)."""
        n = len(token_ids) if length is None else min(length, len(token_ids))
        bs = self.block
        return [self.block_signature(token_ids[b0:b0 + bs])
                for b0 in range(0, (n // bs) * bs, bs)]

    # ------------------------------------------------------------------
    def add(self, entry_id: int, token_ids, length=None) -> None:
        """Index every full block of an admitted entry.  Re-adding an
        existing id replaces its signatures (no stale buckets)."""
        if entry_id in self._entry_sigs:
            self.remove(entry_id)
        sigs = self.signatures(token_ids, length)
        self._entry_sigs[entry_id] = sigs
        for bi, keys in enumerate(sigs):
            for band, key in enumerate(keys):
                self._buckets.setdefault((bi, band, key),
                                         set()).add(entry_id)

    def remove(self, entry_id: int) -> None:
        sigs = self._entry_sigs.pop(entry_id, None)
        if sigs is None:
            return
        for bi, keys in enumerate(sigs):
            for band, key in enumerate(keys):
                bucket = self._buckets.get((bi, band, key))
                if bucket is not None:
                    bucket.discard(entry_id)
                    if not bucket:
                        del self._buckets[(bi, band, key)]

    # ------------------------------------------------------------------
    def candidates(self, token_ids, length=None
                   ) -> Dict[int, Set[int]]:
        """entry_id -> block indices where the query's block collides
        with the entry's SAME-POSITION block in >= 1 band.  Candidates
        only — the caller verifies against actual token ids."""
        out: Dict[int, Set[int]] = {}
        for bi, keys in enumerate(self.signatures(token_ids, length)):
            for band, key in enumerate(keys):
                for eid in self._buckets.get((bi, band, key), ()):
                    out.setdefault(eid, set()).add(bi)
        return out


def match_mask(query_ids, donor_ids, block_size: int,
               candidate_blocks: Set[int], min_agree: float
               ) -> List[float]:
    """Per-block token agreement of query vs donor at aligned positions,
    gated to LSH-candidate blocks.  Returns agreement in [0, 1] for each
    full block both sides cover (0.0 for non-candidates — they were
    never even close enough to collide)."""
    q = np.asarray(query_ids)
    d = np.asarray(donor_ids)
    nb = min(len(q), len(d)) // block_size
    out: List[float] = []
    for b in range(nb):
        if b not in candidate_blocks:
            out.append(0.0)
            continue
        lo = b * block_size
        agree = float(np.mean(q[lo:lo + block_size]
                              == d[lo:lo + block_size]))
        out.append(agree if agree >= min_agree else 0.0)
    return out
